//! Integration: the AOT artifacts load, compile and execute through the
//! rust PJRT runtime, and the results match a host sort. Skips (with a
//! notice) when `make artifacts` has not been run.

use gpu_bucket_sort::runtime::PjrtRuntime;
use gpu_bucket_sort::workload::Distribution;

fn runtime() -> Option<PjrtRuntime> {
    match PjrtRuntime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping PJRT tests ({e})");
            None
        }
    }
}

#[test]
fn artifacts_sort_correctly() {
    let Some(mut rt) = runtime() else { return };
    for n in [1usize, 100, 4095, 4096] {
        let mut keys = Distribution::Uniform.generate(n, n as u64);
        // The fixed-shape pipeline reserves u32::MAX as sentinel.
        for k in keys.iter_mut() {
            if *k == u32::MAX {
                *k -= 1;
            }
        }
        let (sorted, cap) = rt.sort(&keys).unwrap();
        assert!(cap >= n);
        assert!(gpu_bucket_sort::is_sorted_permutation(&keys, &sorted), "n={n}");
    }
}

#[test]
fn sentinel_keys_rejected() {
    let Some(mut rt) = runtime() else { return };
    let err = rt.sort(&[1, u32::MAX, 2]).unwrap_err();
    assert!(err.to_string().contains("sentinel"), "{err}");
}

#[test]
fn oversized_requests_rejected() {
    let Some(mut rt) = runtime() else { return };
    let cap = rt.manifest().max_sort_capacity();
    let keys = vec![0u32; cap + 1];
    assert!(rt.sort(&keys).is_err());
}

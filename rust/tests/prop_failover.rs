//! Property: fault recovery never changes bytes.
//!
//! For random request mixes (u32/u64/f32 keys, with and without
//! payloads, both directions) across 1/2/4 workers and the native and
//! sharded engines, a service running under an armed fault plan —
//! device loss mid-step, contained worker panics — must return
//! responses **byte-identical** to an undisturbed service with the
//! same configuration. Failover and retry are allowed to cost time,
//! never bytes: the sorted sequence is the unique ordering of the
//! input's bit-pattern multiset, so any recovery path that completes
//! must land on it.

use gpu_bucket_sort::config::{EngineKind, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortRequest, SortService};
use gpu_bucket_sort::net::wire::key_data_to_bytes;
use gpu_bucket_sort::sim::DevicePool;
use gpu_bucket_sort::util::propcheck::forall;
use gpu_bucket_sort::{KeyData, KeyType};

/// Write a fault plan to a unique temp file; returns its path.
fn write_plan(name: &str, json: &str) -> String {
    let dir = std::env::temp_dir().join(format!("gbs_pfail_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(format!("{name}.json"));
    std::fs::write(&p, json).unwrap();
    p.display().to_string()
}

fn gen_keys(g: &mut gpu_bucket_sort::util::propcheck::Gen, kt: KeyType, n: usize) -> KeyData {
    match kt {
        KeyType::U32 => KeyData::U32((0..n).map(|_| g.u32()).collect()),
        KeyType::U64 => KeyData::U64((0..n).map(|_| g.rng().next_u64()).collect()),
        KeyType::F32 => KeyData::F32(
            (0..n)
                .map(|_| {
                    // Mix ordinary values with negatives, zeros and NaNs
                    // — recovery must preserve total-order semantics.
                    let x = g.u32();
                    match x % 17 {
                        0 => f32::NAN,
                        1 => -f32::NAN,
                        2 => 0.0,
                        3 => -0.0,
                        4 => f32::INFINITY,
                        5 => f32::NEG_INFINITY,
                        _ => f32::from_bits(x) % 1e6,
                    }
                })
                .collect(),
        ),
        other => unreachable!("matrix does not cover {other:?}"),
    }
}

fn random_request(g: &mut gpu_bucket_sort::util::propcheck::Gen, kt: KeyType) -> SortRequest {
    let n = g.usize_in(1..3_000);
    let keys = gen_keys(g, kt, n);
    let mut b = SortRequest::builder(keys).descending(g.bool(0.4));
    if g.bool(0.5) {
        b = b.payload((0..n as u64).map(|i| i.wrapping_mul(0x9E3779B9)).collect());
    }
    b.build().unwrap()
}

/// Run the same request list through a faulted and a fault-free
/// service; every pair of responses must match exactly.
fn assert_byte_identity(faulted: ServiceConfig, clean: ServiceConfig, requests: Vec<SortRequest>) {
    let chaos = SortService::start(faulted).unwrap();
    let baseline = SortService::start(clean).unwrap();
    for (i, req) in requests.into_iter().enumerate() {
        let a = chaos.sort(req.clone()).unwrap();
        let b = baseline.sort(req).unwrap();
        // Bitwise, not `==`: NaN f32 keys are byte-identical but never
        // IEEE-equal, and byte identity is the actual contract.
        assert_eq!(
            key_data_to_bytes(&a.keys),
            key_data_to_bytes(&b.keys),
            "request {i}: key bytes diverged between faulted and clean runs"
        );
        assert_eq!(
            a.payload, b.payload,
            "request {i}: payload pairing diverged between faulted and clean runs"
        );
    }
    // The plan must actually have fired — otherwise this test proves
    // nothing about recovery.
    let snap = chaos.shutdown();
    let injected: u64 = snap
        .counters
        .iter()
        .filter(|(k, _)| k.starts_with("fault_injected_"))
        .map(|(_, v)| *v)
        .sum();
    assert!(injected >= 1, "no fault fired: {:?}", snap.counters);
    let _ = baseline.shutdown();
}

/// Sharded engine, 1/2/4 workers: a device lost mid-step (and another
/// later) fails over to the surviving devices with identical bytes.
#[test]
fn sharded_device_loss_byte_identity_across_workers() {
    let key_types = [KeyType::U32, KeyType::U64, KeyType::F32];
    forall(9, "sharded failover: faulted == clean", |g| {
        let workers = *g.choose(&[1usize, 2, 4]);
        let kt = *g.choose(&key_types);
        // Targets 0/1 exist in every per-worker lease (8 devices across
        // at most 4 workers ⇒ every lease holds ≥ 2), so one loss
        // always leaves that lease a survivor to fail over to.
        let target = g.usize_in(0..2);
        let plan = write_plan(
            &format!("dev_lost_w{workers}_{target}"),
            &format!(
                r#"{{"version":1,"seed":5,"rules":[
                    {{"point":"device_lost","target":{target},"count":1}}
                ]}}"#
            ),
        );
        let mut devices = DevicePool::DEFAULT_DEVICES.to_vec();
        devices.extend_from_slice(&DevicePool::DEFAULT_DEVICES);
        let faulted = ServiceConfig {
            engine: EngineKind::Sharded,
            workers,
            devices,
            fault_plan: plan,
            verify: true,
            ..Default::default()
        };
        let clean = ServiceConfig {
            fault_plan: String::new(),
            ..faulted.clone()
        };
        let requests: Vec<SortRequest> = (0..6).map(|_| random_request(g, kt)).collect();
        assert_byte_identity(faulted, clean, requests);
    });
}

/// Native engine, 1/2/4 workers: contained worker panics retried by
/// the scheduler land on identical bytes.
#[test]
fn native_worker_panic_retry_byte_identity_across_workers() {
    let key_types = [KeyType::U32, KeyType::U64, KeyType::F32];
    forall(9, "panic retry: faulted == clean", |g| {
        let workers = *g.choose(&[1usize, 2, 4]);
        let kt = *g.choose(&key_types);
        let plan = write_plan(
            &format!("panic_w{workers}"),
            r#"{"version":1,"seed":13,"rules":[
                {"point":"worker_panic","count":1},
                {"point":"worker_panic","after":3,"count":1}
            ]}"#,
        );
        let faulted = ServiceConfig {
            engine: EngineKind::Native,
            workers,
            fault_plan: plan,
            verify: true,
            ..Default::default()
        };
        let clean = ServiceConfig {
            fault_plan: String::new(),
            ..faulted.clone()
        };
        let requests: Vec<SortRequest> = (0..8).map(|_| random_request(g, kt)).collect();
        assert_byte_identity(faulted, clean, requests);
    });
}

//! Property tests for the executed tile kernels and the scratch arena:
//!
//! * the radix kernel agrees with the comparison (bitonic-equivalent)
//!   order for every [`SortKey`] type, including `f32` NaNs, signed
//!   zeros and infinities, and is stable on key–value records;
//! * repeated sorts through a reused [`ScratchArena`] are byte-
//!   identical across 1/2/4 workers, for both kernels, through both the
//!   executed Algorithm 1 and the native PSRS engine.

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::algos::radix;
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::propcheck::{forall, Gen};
use gpu_bucket_sort::{ExecContext, KernelKind, Record, SortKey};

/// A typed vector drawn through the order-preserving raw-bits decoder,
/// mixing full-range and tie-heavy regimes (and, for f32, covering NaN
/// bit patterns by construction).
fn typed_vec<K: SortKey>(g: &mut Gen, len: usize) -> Vec<K> {
    let regime = g.rng().gen_range(4);
    (0..len)
        .map(|_| {
            let raw = match regime {
                0 => g.rng().next_u64(),
                1 => g.rng().next_u64() % 16,
                2 => g.rng().next_u64() % (1 << 10),
                // High raw values: for 4-byte keys this lands in the
                // top of the bit domain — NaN territory for f32.
                _ => u64::MAX - (g.rng().next_u64() % (1 << 12)),
            };
            K::from_raw_bits(raw)
        })
        .collect()
}

/// Sort by the comparison path — the ground truth every kernel must
/// reproduce bit-for-bit.
fn comparison_sorted<K: SortKey>(input: &[K]) -> Vec<K::Bits> {
    let mut v = input.to_vec();
    v.sort_unstable_by(K::key_cmp);
    v.into_iter().map(|k| k.to_bits()).collect()
}

fn radix_matches_comparison<K: SortKey>(g: &mut Gen) {
    let len = g.usize_in(0..3000);
    let input: Vec<K> = typed_vec(g, len);
    let mut sorted = input.clone();
    let mut scratch = Vec::new();
    radix::radix_tile_sort(&mut sorted, &mut scratch);
    let got: Vec<K::Bits> = sorted.iter().map(|k| k.to_bits()).collect();
    assert_eq!(got, comparison_sorted(&input));
}

#[test]
fn radix_kernel_agrees_with_comparison_for_every_key_type() {
    forall(60, "radix == comparison (u32)", radix_matches_comparison::<u32>);
    forall(60, "radix == comparison (u64)", radix_matches_comparison::<u64>);
    forall(60, "radix == comparison (i32)", radix_matches_comparison::<i32>);
    forall(60, "radix == comparison (i64)", radix_matches_comparison::<i64>);
    forall(60, "radix == comparison (f32)", radix_matches_comparison::<f32>);
}

#[test]
fn radix_kernel_handles_f32_specials() {
    // Deterministic coverage of the values property draws might miss.
    let specials = [
        f32::NAN,
        -f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        0.0f32,
        -0.0f32,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1.0,
        -1.0,
    ];
    let mut input = Vec::new();
    for (i, &s) in specials.iter().enumerate() {
        for j in 0..50 {
            input.push(s);
            input.push((i * 53 + j) as f32 - 250.0);
        }
    }
    let mut sorted = input.clone();
    let mut scratch = Vec::new();
    radix::radix_tile_sort(&mut sorted, &mut scratch);
    // NB: the *trait* bits (order-preserving), not the inherent raw
    // `f32::to_bits` — `comparison_sorted` is in trait-bit space.
    let got: Vec<u32> = sorted.iter().map(|&x| SortKey::to_bits(x)).collect();
    assert_eq!(got, comparison_sorted(&input));
    // NaN payload bits survive (round-trip through the kernel's moves).
    assert!(sorted.iter().filter(|x| x.is_nan()).count() >= 100);
}

#[test]
fn radix_kernel_is_stable_on_records_of_every_key_type() {
    fn check<K: SortKey>(g: &mut Gen) {
        let len = g.usize_in(1..2000);
        // Small alphabet forces heavy key ties; the index must break
        // them in original order.
        let keys: Vec<K> = (0..len)
            .map(|_| K::from_raw_bits(g.rng().next_u64() % 8))
            .collect();
        let mut recs: Vec<Record<K>> = keys
            .iter()
            .zip(0u32..)
            .map(|(&key, idx)| Record { key, idx })
            .collect();
        let mut scratch = Vec::new();
        radix::radix_tile_sort(&mut recs, &mut scratch);
        for w in recs.windows(2) {
            let (a, b) = (w[0].to_bits(), w[1].to_bits());
            assert!(a < b, "records must be strictly increasing (key, idx)");
        }
    }
    forall(40, "record stability (u32)", check::<u32>);
    forall(40, "record stability (u64)", check::<u64>);
    forall(40, "record stability (f32)", check::<f32>);
}

#[test]
fn arena_reuse_is_byte_identical_across_workers_and_kernels() {
    let sorter = BucketSort::new(BucketSortParams { tile: 256, s: 16 });
    forall(12, "bucket sort invariant to arena reuse/workers/kernel", |g| {
        let len = g.usize_in(0..20_000);
        let input: Vec<u32> = typed_vec(g, len);
        let mut reference: Option<Vec<u32>> = None;
        for kernel in [KernelKind::Bitonic, KernelKind::Radix] {
            for workers in [1usize, 2, 4] {
                let ctx = ExecContext::new(kernel, workers);
                // Two rounds through the same context: the second is
                // served from the warm arena.
                for _ in 0..2 {
                    let mut keys = input.clone();
                    let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
                    sorter.sort_in(&mut keys, &mut sim, &ctx).unwrap();
                    match &reference {
                        None => reference = Some(keys),
                        Some(r) => assert_eq!(&keys, r, "{kernel} × {workers}w"),
                    }
                }
                if len > 0 {
                    assert!(
                        ctx.arena.stats().hits > 0,
                        "warm round must reuse arena buffers"
                    );
                }
            }
        }
    });
}

#[test]
fn native_engine_invariant_to_workers_kernel_and_arena_reuse() {
    forall(8, "native engine invariant", |g| {
        let len = g.usize_in(1..60_000);
        let input: Vec<u32> = typed_vec(g, len);
        let payload: Vec<u64> = (0..len as u64).collect();
        let mut reference: Option<(Vec<u32>, Vec<u64>)> = None;
        for kernel in [KernelKind::Bitonic, KernelKind::Radix] {
            for workers in [1usize, 2, 4] {
                let e = NativeEngine::with_context(
                    NativeParams {
                        workers,
                        sequential_cutoff: 1 << 9,
                        ..Default::default()
                    },
                    ExecContext::new(kernel, 0),
                )
                .unwrap();
                for _ in 0..2 {
                    let mut k = input.clone();
                    let mut p = payload.clone();
                    e.sort_pairs(&mut k, &mut p).unwrap();
                    match &reference {
                        None => reference = Some((k, p)),
                        Some((rk, rp)) => {
                            assert_eq!(&k, rk, "{kernel} × {workers}w keys");
                            assert_eq!(&p, rp, "{kernel} × {workers}w payload");
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn scratch_arena_only_recycles_capacity_never_contents() {
    // A buffer returned dirty must come back cleared-and-refilled: sort
    // wildly different inputs through one context and verify each
    // against an arena-free reference.
    let sorter = BucketSort::new(BucketSortParams { tile: 256, s: 16 });
    let ctx = ExecContext::default();
    forall(20, "arena recycling is content-clean", |g| {
        let len = g.usize_in(0..8000);
        let input: Vec<u32> = typed_vec(g, len);
        let mut via_arena = input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort_in(&mut via_arena, &mut sim, &ctx).unwrap();
        let mut expect = input;
        expect.sort_unstable();
        assert_eq!(via_arena, expect);
    });
}

#[test]
fn planned_sort_agrees_with_comparison_for_every_key_type_and_digit_width() {
    use gpu_bucket_sort::algos::plan;
    fn check<K: SortKey>(g: &mut Gen) {
        let len = g.usize_in(0..3000);
        let input: Vec<K> = typed_vec(g, len);
        let bits = [3u32, 8, 11, 13, 16][g.usize_in(0..5)];
        let mut sorted = input.clone();
        let (mut scratch, mut counts) = (Vec::new(), Vec::new());
        plan::planned_sort(&mut sorted, &mut scratch, &mut counts, bits, None);
        let got: Vec<K::Bits> = sorted.iter().map(|k| k.to_bits()).collect();
        assert_eq!(got, comparison_sorted(&input), "digit_bits={bits}");
    }
    forall(60, "planned == comparison (u32)", check::<u32>);
    forall(60, "planned == comparison (u64)", check::<u64>);
    forall(60, "planned == comparison (i32)", check::<i32>);
    forall(60, "planned == comparison (i64)", check::<i64>);
    forall(60, "planned == comparison (f32)", check::<f32>);
}

#[test]
fn planned_sort_digit_width_never_changes_the_bytes() {
    // The planner knob is wall-time only: through the full executed
    // Algorithm 1, any digit width produces the identical output and
    // the identical ledger.
    let sorter = BucketSort::new(BucketSortParams { tile: 256, s: 16 });
    forall(10, "bucket sort invariant to digit width", |g| {
        let len = g.usize_in(0..16_000);
        let input: Vec<u32> = typed_vec(g, len);
        let mut reference: Option<(Vec<u32>, _)> = None;
        for bits in [1u32, 8, 11, 16] {
            let ctx = ExecContext::new(KernelKind::Radix, 2).with_digit_bits(bits);
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let r = sorter.sort_in(&mut keys, &mut sim, &ctx).unwrap();
            match &reference {
                None => reference = Some((keys, r.ledger)),
                Some((rk, rl)) => {
                    assert_eq!(&keys, rk, "digit_bits={bits}");
                    assert_eq!(&r.ledger, rl, "ledger must ignore digit_bits={bits}");
                }
            }
        }
    });
}

#[test]
fn coalesced_batches_byte_identical_to_solo_jobs() {
    // The coalescing determinism property at the engine level: a batch
    // of N mixed-size requests returns responses byte-identical to
    // sorting each request alone — across 1/2/4 workers, both kernels,
    // and u32/u64/f32 keys (with and without payloads).
    use gpu_bucket_sort::config::{BatchConfig, ServiceConfig};
    use gpu_bucket_sort::coordinator::{JobData, NativeSortEngine, SortEngine};
    use gpu_bucket_sort::KeyData;

    fn typed_job<K: SortKey>(g: &mut Gen, kv: bool) -> JobData
    where
        Vec<K>: Into<KeyData>,
    {
        let len = g.usize_in(1..2500);
        let keys: Vec<K> = typed_vec(g, len);
        JobData {
            keys: keys.into(),
            payload: kv.then(|| (0..len as u64).collect()),
        }
    }

    forall(8, "coalesced == solo", |g| {
        let mut jobs: Vec<JobData> = Vec::new();
        for _ in 0..g.usize_in(2..12) {
            let kv = g.rng().gen_range(2) == 0;
            match g.rng().gen_range(3) {
                0 => jobs.push(typed_job::<u32>(g, kv)),
                1 => jobs.push(typed_job::<u64>(g, kv)),
                _ => jobs.push(typed_job::<f32>(g, kv)),
            }
        }
        let mut reference: Option<Vec<JobData>> = None;
        for kernel in [KernelKind::Bitonic, KernelKind::Radix] {
            for workers in [1usize, 2, 4] {
                let cfg = ServiceConfig {
                    kernel,
                    native: gpu_bucket_sort::exec::NativeParams {
                        workers,
                        sequential_cutoff: 1 << 9,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                // Coalescing on (default cap admits every job) …
                let mut coalescing = NativeSortEngine::new(&cfg).unwrap();
                let got: Vec<JobData> = coalescing
                    .sort_batch(jobs.clone())
                    .into_iter()
                    .map(|r| r.unwrap())
                    .collect();
                // … vs per-request dispatch of the same engine config.
                let solo_cfg = ServiceConfig {
                    batch: BatchConfig {
                        coalesce_max_keys: 0,
                        ..Default::default()
                    },
                    ..cfg
                };
                let mut solo_engine = NativeSortEngine::new(&solo_cfg).unwrap();
                let solo: Vec<JobData> = solo_engine
                    .sort_batch(jobs.clone())
                    .into_iter()
                    .map(|r| r.unwrap())
                    .collect();
                for (i, (a, b)) in got.iter().zip(&solo).enumerate() {
                    assert_eq!(a.keys, b.keys, "job {i}, {kernel} × {workers}w");
                    assert_eq!(a.payload, b.payload, "job {i}, {kernel} × {workers}w");
                }
                match &reference {
                    None => reference = Some(got),
                    Some(r) => {
                        for (i, (a, b)) in got.iter().zip(r).enumerate() {
                            assert_eq!(a.keys, b.keys, "job {i}, {kernel} × {workers}w");
                            assert_eq!(a.payload, b.payload, "job {i}");
                        }
                    }
                }
            }
        }
    });
}

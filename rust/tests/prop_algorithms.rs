//! Property tests over the algorithm layer (in-tree propcheck driver):
//! correctness, the deterministic bucket guarantee, analytic↔executed
//! ledger agreement, and cross-algorithm result agreement over
//! arbitrary inputs, sizes and parameters.

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::algos::radix::{RadixParams, RadixSort};
use gpu_bucket_sort::algos::randomized::{RandomizedParams, RandomizedSampleSort};
use gpu_bucket_sort::algos::sharded::{ShardedSort, ShardedSortParams};
use gpu_bucket_sort::algos::thrust_merge::{ThrustMergeParams, ThrustMergeSort};
use gpu_bucket_sort::algos::{bitonic, Algorithm};
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::sim::{DevicePool, GpuModel, GpuSim};
use gpu_bucket_sort::util::propcheck::forall;
use gpu_bucket_sort::{is_sorted_permutation, Key};

fn sim() -> GpuSim {
    GpuSim::new(GpuModel::Gtx285_2G.spec())
}

fn gen_params(g: &mut gpu_bucket_sort::util::propcheck::Gen) -> BucketSortParams {
    let tile = *g.choose(&[64usize, 128, 256, 512]);
    let s = *g.choose(&[2usize, 4, 8, 16, 32, 64]);
    BucketSortParams { tile, s: s.min(tile) }
}

#[test]
fn bucket_sort_sorts_anything() {
    forall(60, "bucket sort = sorted permutation", |g| {
        let keys = g.vec_u32(0..6000);
        let params = gen_params(g);
        let mut out = keys.clone();
        BucketSort::new(params).sort(&mut out, &mut sim()).unwrap();
        assert!(is_sorted_permutation(&keys, &out), "params {params:?}");
    });
}

#[test]
fn bucket_guarantee_on_bounded_ties() {
    forall(40, "max bucket <= 2n/s for tie-bounded inputs", |g| {
        let params = gen_params(g);
        let n = g.usize_in(params.tile..params.tile * 40);
        // Distinct-ish keys: multiplicities stay far below n/s.
        let keys: Vec<Key> = (0..n)
            .map(|i| (i as u32).wrapping_mul(2654435761) ^ g.case as u32)
            .collect();
        let mut out = keys.clone();
        let r = BucketSort::new(params).sort(&mut out, &mut sim()).unwrap();
        // The Shi–Schaeffer bound on real keys, plus the alignment pads
        // (all equal to the MAX sentinel, they land in the last bucket;
        // at paper shapes pads ≤ tile−1 ≪ 2n/s, at toy shapes they can
        // dominate it).
        let bound = (2 * r.padded_n / r.s + (r.padded_n - n)) as u64;
        assert!(
            r.max_bucket <= bound,
            "n={n} params={params:?} max={} bound={bound}",
            r.max_bucket,
        );
    });
}

#[test]
fn analytic_ledger_equals_executed() {
    forall(40, "analytic == executed ledger (GBS)", |g| {
        let params = gen_params(g);
        let n = g.usize_in(1..params.tile * 30);
        let mut keys = g.vec_u32(n..n + 1);
        let mut sim_e = sim();
        let exec = BucketSort::new(params).sort(&mut keys, &mut sim_e).unwrap();
        let mut sim_a = sim();
        let ana = BucketSort::new(params).sort_analytic(n, &mut sim_a).unwrap();
        assert_eq!(exec.ledger, ana.ledger, "n={n} params={params:?}");
        assert_eq!(exec.peak_device_bytes, ana.peak_device_bytes);
    });
}

#[test]
fn thrust_analytic_equals_executed() {
    forall(30, "analytic == executed ledger (thrust)", |g| {
        let n = g.usize_in(1..50_000);
        let mut keys = g.vec_u32(n..n + 1);
        let sorter = ThrustMergeSort::new(ThrustMergeParams { tile: 256 });
        let mut sim_e = sim();
        let exec = sorter.sort(&mut keys, &mut sim_e).unwrap();
        let mut sim_a = sim();
        let ana = sorter.sort_analytic(n, &mut sim_a).unwrap();
        assert_eq!(exec.ledger, ana.ledger, "n={n}");
    });
}

#[test]
fn sharded_output_matches_single_device() {
    forall(30, "sharded == single-device bucket sort", |g| {
        let keys = g.vec_u32(0..30_000);
        let params = gen_params(g);
        let sharded = ShardedSort::new(ShardedSortParams {
            sort: params,
            merge_samples: *g.choose(&[1usize, 8, 64]),
        });
        let device_count = g.usize_in(1..5);
        let models: Vec<GpuModel> = (0..device_count)
            .map(|i| DevicePool::DEFAULT_DEVICES[i % 4])
            .collect();
        let mut pool = DevicePool::new(&models).unwrap();
        let mut sharded_out = keys.clone();
        sharded.sort(&mut sharded_out, &mut pool).unwrap();

        let mut single_out = keys.clone();
        BucketSort::new(params)
            .sort(&mut single_out, &mut GpuSim::new(GpuModel::TeslaC1060.spec()))
            .unwrap();

        assert!(is_sorted_permutation(&keys, &sharded_out), "params {params:?}");
        assert_eq!(sharded_out, single_out, "params {params:?}");
    });
}

#[test]
fn all_algorithms_agree() {
    forall(30, "all four algorithms produce the same output", |g| {
        let keys = g.vec_u32(0..4000);
        let mut expected = keys.clone();
        expected.sort_unstable();
        for algo in Algorithm::ALL {
            let mut out = keys.clone();
            algo.run(&mut out, &mut sim()).unwrap();
            assert_eq!(out, expected, "{algo}");
        }
    });
}

#[test]
fn randomized_sorts_with_any_seed() {
    forall(30, "randomized sample sort is seed-robust", |g| {
        let keys = g.vec_u32(0..20_000);
        let sorter = RandomizedSampleSort::new(RandomizedParams {
            k: *g.choose(&[4usize, 8, 32]),
            oversample: *g.choose(&[2usize, 8]),
            base_case: 512,
            tile: 256,
            seed: g.rng().next_u64(),
        });
        let mut out = keys.clone();
        sorter.sort(&mut out, &mut sim()).unwrap();
        assert!(is_sorted_permutation(&keys, &out));
    });
}

#[test]
fn radix_handles_extreme_values() {
    forall(30, "radix sorts boundary-valued keys", |g| {
        let mut keys = g.vec_u32(0..3000);
        // Salt with boundary values.
        keys.extend_from_slice(&[0, 1, u32::MAX, u32::MAX - 1, 1 << 31]);
        let mut out = keys.clone();
        RadixSort::new(RadixParams { tile: 256 })
            .sort(&mut out, &mut sim())
            .unwrap();
        assert!(is_sorted_permutation(&keys, &out));
    });
}

#[test]
fn native_engine_matches_std_sort() {
    let engine = NativeEngine::new(NativeParams {
        workers: 4,
        sequential_cutoff: 1 << 10,
        ..NativeParams::default()
    })
    .unwrap();
    forall(40, "native engine == std sort", |g| {
        let keys = g.vec_u32(0..100_000);
        let mut expected = keys.clone();
        expected.sort_unstable();
        let mut out = keys;
        engine.sort(&mut out);
        assert_eq!(out, expected);
    });
}

#[test]
fn bitonic_network_is_oblivious() {
    forall(40, "bitonic CE count depends only on n", |g| {
        let ln = g.usize_in(0..11);
        let n = 1usize << ln;
        let mut a = g.vec_u32(n..n + 1);
        let mut b: Vec<Key> = (0..n as u32).collect();
        let ce_a = bitonic::sort_slice(&mut a);
        let ce_b = bitonic::sort_slice(&mut b);
        assert_eq!(ce_a, ce_b);
        assert_eq!(ce_a, bitonic::ce_count(n));
        assert!(gpu_bucket_sort::is_sorted(&a));
    });
}

#[test]
fn ledger_is_input_independent_for_tie_bounded_inputs() {
    forall(25, "GBS ledger identical across tie-bounded inputs", |g| {
        let params = BucketSortParams { tile: 256, s: 16 };
        let n = g.usize_in(256..8192);
        // Two different permutations of distinct keys.
        let a: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();
        let b: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2246822519)).collect();
        let mut sim_a = sim();
        let ra = BucketSort::new(params).sort(&mut a.clone(), &mut sim_a).unwrap();
        let mut sim_b = sim();
        let rb = BucketSort::new(params).sort(&mut b.clone(), &mut sim_b).unwrap();
        assert_eq!(ra.ledger, rb.ledger);
    });
}

#[test]
fn device_capacity_is_monotone() {
    // If n keys fit a device, any smaller input also fits; if n fails,
    // larger inputs also fail.
    let sorter = BucketSort::new(BucketSortParams::default());
    for gpu in GpuModel::ALL {
        let mut last_ok = true;
        for shift in 20..31 {
            let n = 1usize << shift;
            let mut s = GpuSim::new(gpu.spec());
            let ok = sorter.sort_analytic(n, &mut s).is_ok();
            assert!(
                !(ok && !last_ok),
                "{gpu}: capacity not monotone at n=2^{shift}"
            );
            last_ok = ok;
        }
    }
}

//! Property tests over the coordinator: batcher conservation and order
//! invariants under random request sequences, and service-level
//! identity/permutation guarantees under random job mixes.

use gpu_bucket_sort::config::{BatchConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{Batcher, PendingRequest, SortRequest, SortService};
use gpu_bucket_sort::util::propcheck::forall;
use std::time::{Duration, Instant};

type OutcomeRx =
    std::sync::mpsc::Receiver<gpu_bucket_sort::Result<gpu_bucket_sort::coordinator::SortResponse>>;

fn req(id: u64, n: usize, at: Instant) -> (PendingRequest, OutcomeRx) {
    let (tx, rx) = std::sync::mpsc::channel();
    (
        PendingRequest {
            id,
            request: SortRequest::new(vec![0u32; n]),
            admitted_at: at,
            respond_to: tx,
        },
        rx,
    )
}

#[test]
fn batcher_conserves_and_orders_requests() {
    forall(60, "batcher: conservation + FIFO + budgets", |g| {
        let cfg = BatchConfig {
            max_batch_keys: g.usize_in(1..500),
            max_batch_requests: g.usize_in(1..10),
            max_wait_ms: 5,
            queue_capacity: 64,
            max_queued_keys: 1 << 20,
            ..Default::default()
        };
        let mut batcher = Batcher::new(cfg);
        let t0 = Instant::now();
        let n_reqs = g.usize_in(0..40);
        let mut admitted = Vec::new();
        let mut rxs = Vec::new();
        for id in 0..n_reqs as u64 {
            let len = g.usize_in(0..300);
            let (r, rx) = req(id, len, t0);
            if batcher.admit(r).is_ok() {
                admitted.push(id);
                rxs.push(rx);
            }
        }
        // Random interleave of polls and drains, collecting batches.
        let mut seen = Vec::new();
        let mut time = t0;
        while batcher.queued_requests() > 0 {
            time += Duration::from_millis(g.usize_in(1..10) as u64);
            let batch = if g.bool(0.3) {
                batcher.drain()
            } else {
                batcher.poll(time)
            };
            if let Some(b) = batch {
                assert!(!b.is_empty(), "batches are never empty");
                // Budgets hold unless a single oversized request forms
                // the whole batch.
                if b.len() > 1 {
                    assert!(b.total_keys <= cfg.max_batch_keys, "key budget");
                    assert!(b.len() <= cfg.max_batch_requests, "request budget");
                }
                for r in &b.requests {
                    seen.push(r.id);
                }
            }
        }
        // Conservation + FIFO: every admitted request exactly once, in
        // admission order.
        assert_eq!(seen, admitted);
    });
}

#[test]
fn batcher_restore_front_preserves_order() {
    forall(30, "restore_front round-trips", |g| {
        let cfg = BatchConfig {
            max_batch_keys: 1000,
            max_batch_requests: 8,
            max_wait_ms: 0,
            queue_capacity: 64,
            max_queued_keys: 1 << 20,
            ..Default::default()
        };
        let mut batcher = Batcher::new(cfg);
        let t0 = Instant::now();
        let n_reqs = g.usize_in(1..20);
        let mut rxs = Vec::new();
        for id in 0..n_reqs as u64 {
            let (r, rx) = req(id, g.usize_in(0..100), t0);
            batcher.admit(r).unwrap();
            rxs.push(rx);
        }
        let keys_before = batcher.queued_keys();
        let batch = batcher.poll(t0 + Duration::from_millis(1)).unwrap();
        batcher.restore_front(batch);
        assert_eq!(batcher.queued_keys(), keys_before);
        // Draining now yields ids in the original order.
        let mut ids = Vec::new();
        while let Some(b) = batcher.drain() {
            ids.extend(b.requests.iter().map(|r| r.id));
        }
        assert_eq!(ids, (0..n_reqs as u64).collect::<Vec<_>>());
    });
}

#[test]
fn service_returns_each_requests_own_keys() {
    // Random mixes of sizes and distributions, submitted in a burst
    // against a 3-worker pool: every response is the sorted permutation
    // of its own input, with matching tags, regardless of which worker
    // ran it or in what order batches completed.
    let cfg = ServiceConfig {
        verify: false,
        workers: 3,
        batch: BatchConfig {
            max_batch_keys: 1 << 18,
            max_batch_requests: 6,
            max_wait_ms: 1,
            queue_capacity: 256,
            max_queued_keys: 1 << 24,
            ..Default::default()
        },
        ..Default::default()
    };
    let client = SortService::start(cfg).unwrap();
    forall(12, "service identity + permutation", |g| {
        let jobs: Vec<Vec<u32>> = (0..g.usize_in(1..12)).map(|_| g.vec_u32(0..20_000)).collect();
        let rxs: Vec<_> = jobs
            .iter()
            .enumerate()
            .map(|(i, keys)| {
                client
                    .submit(SortRequest::tagged(keys.clone(), format!("job-{i}")))
                    .unwrap()
            })
            .collect();
        for (i, (rx, input)) in rxs.into_iter().zip(&jobs).enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.tag.as_deref(), Some(format!("job-{i}").as_str()));
            assert!(
                gpu_bucket_sort::is_sorted_permutation(input, out.keys_u32()),
                "job {i}"
            );
        }
    });
    client.shutdown();
}

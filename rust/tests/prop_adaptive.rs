//! Property tests over the adaptive front-end (PR 7): the
//! `KernelKind::Adaptive` output is byte-identical to the static
//! comparison kernel across worker counts, engines and every workload
//! distribution (including the adversarial ones the cost model was
//! built to recognise), and the sorted/reverse early exits preserve
//! `Record` payload stability exactly.

use gpu_bucket_sort::algos::adaptive::Choice;
use gpu_bucket_sort::config::{BatchConfig, EngineKind, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortRequest, SortService};
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::key::tag_records;
use gpu_bucket_sort::util::propcheck::forall;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{ExecContext, KernelKind, SortKey};

fn engine(kernel: KernelKind) -> NativeEngine {
    NativeEngine::with_context(NativeParams::default(), ExecContext::new(kernel, 0)).unwrap()
}

/// Comparison-kernel reference output for a key vector.
fn comparison_sorted(keys: &[u32]) -> Vec<u32> {
    let mut out = keys.to_vec();
    engine(KernelKind::Bitonic).sort(&mut out);
    out
}

/// The adaptive front-end behind the full batched service is
/// byte-identical to the static comparison kernel for every
/// distribution, across 1/2/4 workers on both the native and the
/// sharded engine.
#[test]
fn adaptive_service_matches_comparison_everywhere() {
    let n = 40_000;
    // Reference outputs once per distribution, from the static
    // comparison kernel (and sanity-checked against std's sort — for
    // u32 the bit order is the numeric order).
    let cases: Vec<(Distribution, Vec<u32>, Vec<u32>)> = Distribution::ALL
        .iter()
        .enumerate()
        .map(|(i, &dist)| {
            let keys = dist.generate(n, i as u64);
            let expect = comparison_sorted(&keys);
            let mut std_sorted = keys.clone();
            std_sorted.sort_unstable();
            assert_eq!(expect, std_sorted, "comparison kernel reference ({dist})");
            (dist, keys, expect)
        })
        .collect();

    for engine_kind in [EngineKind::Native, EngineKind::Sharded] {
        for workers in [1usize, 2, 4] {
            let cfg = ServiceConfig {
                engine: engine_kind,
                workers,
                kernel: KernelKind::Adaptive,
                batch: BatchConfig {
                    max_batch_keys: 1 << 20,
                    max_batch_requests: 8,
                    max_wait_ms: 1,
                    queue_capacity: 64,
                    max_queued_keys: 1 << 24,
                    ..Default::default()
                },
                ..Default::default()
            };
            let client = SortService::start(cfg).unwrap();
            for (dist, keys, expect) in &cases {
                let out = client
                    .sort(SortRequest::new(keys.clone()))
                    .unwrap_or_else(|e| panic!("{engine_kind:?}/{workers}w/{dist}: {e}"));
                assert_eq!(
                    out.keys_u32(),
                    expect.as_slice(),
                    "adaptive != comparison ({engine_kind:?}, {workers} workers, {dist})"
                );
            }
            let snap = client.shutdown();
            assert_eq!(
                snap.counters["requests_completed"],
                cases.len() as u64,
                "{engine_kind:?}/{workers}w"
            );
            if engine_kind == EngineKind::Native {
                // Native engines report adaptive decisions to metrics.
                assert!(
                    snap.counters["adaptive_requests"] >= 1,
                    "{engine_kind:?}/{workers}w: {:?}",
                    snap.counters
                );
            }
        }
    }
}

/// A `#plan`-suffixed request tag comes back extended with the
/// decision summary on the native engine.
#[test]
fn plan_tag_reports_adaptive_choice() {
    let cfg = ServiceConfig {
        kernel: KernelKind::Adaptive,
        ..Default::default()
    };
    let client = SortService::start(cfg).unwrap();
    let keys: Vec<u32> = (0..60_000u32).rev().collect();
    let out = client
        .sort(SortRequest::tagged(keys, "probe#plan"))
        .unwrap();
    let tag = out.tag.expect("tag survives");
    assert!(
        tag.starts_with("probe#plan;choice="),
        "tag carries the decision summary: {tag}"
    );
    client.shutdown();
}

/// Sorted early exit on records: an already record-sorted key–value
/// input (duplicate keys, ties by payload index) is returned untouched
/// — bitwise-equal payload order, same bytes as the comparison kernel.
#[test]
fn early_exit_sorted_preserves_record_payload_stability() {
    // Duplicate-heavy sorted keys; tagging yields ascending idx within
    // every equal-key run, so the records are fully sorted.
    let keys: Vec<u32> = (0..50_000u32).map(|i| i / 8).collect();
    let records = tag_records(&keys).unwrap();

    let adaptive = engine(KernelKind::Adaptive);
    let mut a_out = records.clone();
    adaptive.sort(&mut a_out);
    let choice = adaptive.last_plan_choice().expect("records a decision");
    assert_eq!(choice.chosen, Choice::EarlyExitSorted, "{choice:?}");

    let mut c_out = records.clone();
    engine(KernelKind::Bitonic).sort(&mut c_out);
    assert_eq!(a_out, records, "early exit returns the input untouched");
    assert_eq!(a_out, c_out, "early exit == comparison kernel");
}

/// Reverse early exit on records: strictly descending keys reverse in
/// place to exactly the comparison-kernel order; non-increasing keys
/// with duplicates are *not* reverse-sorted as records (ties carry
/// ascending indices) and must fall through to a full sort that still
/// matches the comparison kernel.
#[test]
fn early_exit_reverse_preserves_record_payload_stability() {
    let adaptive = engine(KernelKind::Adaptive);
    let comparison = engine(KernelKind::Bitonic);

    // Strictly descending: record bits (key, idx) are strictly
    // descending too, so the front-end may reverse in place.
    let strict: Vec<u32> = (0..50_000u32).rev().collect();
    let records = tag_records(&strict).unwrap();
    let mut a_out = records.clone();
    adaptive.sort(&mut a_out);
    let choice = adaptive.last_plan_choice().expect("records a decision");
    assert_eq!(choice.chosen, Choice::EarlyExitReverse, "{choice:?}");
    let mut c_out = records.clone();
    comparison.sort(&mut c_out);
    assert_eq!(a_out, c_out, "reversal == comparison kernel");
    assert!(
        a_out.windows(2).all(|w| w[0].key_le(&w[1])),
        "reversed records are sorted"
    );

    // Non-increasing with duplicates: within an equal-key run the
    // payload indices ascend, so a blind reversal would flip them —
    // the front-end must detect this and run a real sort instead.
    let dups: Vec<u32> = (0..50_000u32).rev().map(|i| i / 8).collect();
    let records = tag_records(&dups).unwrap();
    let mut a_out = records.clone();
    adaptive.sort(&mut a_out);
    let choice = adaptive.last_plan_choice().expect("records a decision");
    assert_ne!(
        choice.chosen,
        Choice::EarlyExitReverse,
        "duplicate-key ties must not blind-reverse"
    );
    let mut c_out = records.clone();
    comparison.sort(&mut c_out);
    assert_eq!(a_out, c_out, "duplicate-run fallback == comparison kernel");
    // Stability: equal keys keep ascending payload indices.
    for w in a_out.windows(2) {
        if w[0].key == w[1].key {
            assert!(w[0].idx < w[1].idx, "stable ties: {:?}", &w[..2]);
        }
    }
}

/// Arbitrary inputs (any size, any shape — including the tiny runs the
/// cost model routes to the comparison kernel): adaptive output is
/// byte-identical to the comparison kernel's.
#[test]
fn adaptive_matches_comparison_on_arbitrary_inputs() {
    let adaptive = engine(KernelKind::Adaptive);
    let comparison = engine(KernelKind::Bitonic);
    forall(60, "adaptive == comparison kernel", |g| {
        let keys = g.vec_u32(0..6000);
        let mut a_out = keys.clone();
        adaptive.sort(&mut a_out);
        let mut c_out = keys;
        comparison.sort(&mut c_out);
        assert_eq!(a_out, c_out);
    });
}

/// The three PR-7 adversarial distributions generate what their names
/// promise, at the type level the engines actually consume, and sort
/// identically under every static kernel.
#[test]
fn new_distributions_sort_identically_under_all_kernels() {
    for dist in [
        Distribution::FewUnique,
        Distribution::SplitterKiller,
        Distribution::NearlySortedBlocks,
    ] {
        let keys = dist.generate(30_000, 3);
        let expect = comparison_sorted(&keys);
        for kernel in [KernelKind::Adaptive, KernelKind::Radix, KernelKind::Bitonic] {
            let mut out = keys.clone();
            engine(kernel).sort(&mut out);
            assert_eq!(out, expect, "{dist} under {kernel:?}");
        }
    }
}

//! Sharded-engine integration: output identity against single-device
//! GPU Bucket Sort across the workload suite, Execute↔Analytic ledger
//! equality (the sharded mirror of the single-device property), the
//! beyond-any-single-device capacity demonstration, and the engine
//! behind the batched service.

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::algos::sharded::{ShardedSort, ShardedSortParams};
use gpu_bucket_sort::config::{BatchConfig, EngineKind, ServiceConfig};
use gpu_bucket_sort::coordinator::{ShardedSortEngine, SortEngine, SortRequest, SortService};
use gpu_bucket_sort::sim::{DevicePool, GpuModel, GpuSim};
use gpu_bucket_sort::util::propcheck::forall;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{is_sorted_permutation, Key};

fn small_params() -> ShardedSortParams {
    ShardedSortParams {
        sort: BucketSortParams { tile: 256, s: 16 },
        merge_samples: 32,
    }
}

/// The sharded engine's output is byte-identical to single-device
/// GPU Bucket Sort on the same input, for every distribution of the
/// robustness suite (the six-workload family of Leischner et al.).
#[test]
fn output_identical_to_single_device_across_distributions() {
    let sharded = ShardedSort::new(small_params());
    let single = BucketSort::new(small_params().sort);
    let n = 1 << 16;
    for dist in Distribution::ROBUSTNESS_SUITE {
        let input = dist.generate(n, 11);

        let mut sharded_out = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let report = sharded.sort(&mut sharded_out, &mut pool).unwrap();
        assert_eq!(report.shard_sizes.iter().sum::<usize>(), n, "{dist}");

        let mut single_out = input.clone();
        let mut sim = GpuSim::new(GpuModel::TeslaC1060.spec());
        single.sort(&mut single_out, &mut sim).unwrap();

        assert!(is_sorted_permutation(&input, &sharded_out), "{dist}");
        assert_eq!(sharded_out, single_out, "{dist}");
    }
}

/// Execute and Analytic produce identical per-device ledgers, shard
/// sizes and memory profiles — the sharded mirror of the single-device
/// `analytic_ledger_equals_executed` property.
#[test]
fn sharded_analytic_ledger_equals_executed() {
    forall(25, "sharded analytic == executed ledger", |g| {
        let pools: [&[GpuModel]; 4] = [
            &[GpuModel::Gtx285_2G, GpuModel::Gtx285_2G],
            &[GpuModel::TeslaC1060, GpuModel::Gtx260],
            &DevicePool::DEFAULT_DEVICES,
            &[GpuModel::Gtx285_1G],
        ];
        let models: &[GpuModel] = *g.choose(&pools);
        let n = g.usize_in(0..60_000);
        let mut keys = g.vec_u32(n..n + 1);
        let sorter = ShardedSort::new(small_params());

        let mut pool_e = DevicePool::new(models).unwrap();
        let exec = sorter.sort(&mut keys, &mut pool_e).unwrap();
        let mut pool_a = DevicePool::new(models).unwrap();
        let ana = sorter.sort_analytic(n, &mut pool_a).unwrap();

        assert_eq!(exec.shard_sizes, ana.shard_sizes, "n={n}");
        assert_eq!(exec.combine, ana.combine, "n={n}");
        assert_eq!(exec.merge, ana.merge, "n={n}");
        assert_eq!(exec.peak_device_bytes, ana.peak_device_bytes, "n={n}");
        for ((se, sa), d) in pool_e.sims().iter().zip(pool_a.sims()).zip(0..) {
            assert_eq!(se.ledger(), sa.ledger(), "n={n} device={d}");
            assert_eq!(se.peak_bytes(), sa.peak_bytes(), "n={n} device={d}");
        }
    });
}

/// The acceptance demonstration: 768M keys — more than any single
/// Table 1 device can hold (the 4 GB Tesla tops out at 512M) — sorts
/// in Analytic mode across the four heterogeneous devices, with every
/// shard inside its device's ceiling.
#[test]
fn analytic_sorts_beyond_any_single_device() {
    let n = 768 << 20;
    let sorter = ShardedSort::new(ShardedSortParams::default());

    // Every single device OOMs at this size.
    let single = BucketSort::new(BucketSortParams::default());
    for gpu in GpuModel::ALL {
        let mut sim = GpuSim::new(gpu.spec());
        let err = single.sort_analytic(n, &mut sim).unwrap_err();
        assert!(err.is_oom(), "{gpu} should OOM at 768M: {err}");
    }

    // The heterogeneous 4-device pool absorbs it.
    let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
    let report = sorter.sort_analytic(n, &mut pool).unwrap();
    assert_eq!(report.n, n);
    assert_eq!(report.devices(), 4);
    assert_eq!(report.shard_sizes.iter().sum::<usize>(), n);
    for (d, &share) in report.shard_sizes.iter().enumerate() {
        assert!(
            share <= pool.spec(d).max_sortable_keys(),
            "device {d} shard {share} over its ceiling"
        );
        assert!(share > 0, "device {d} idle");
    }
    let ms = report.makespan_ms(&pool);
    assert!(ms > 0.0);
    // Sanity: the pool sorts 768M faster than a (hypothetical) serial
    // concatenation of its members' workloads.
    let serial: f64 = report
        .local
        .iter()
        .enumerate()
        .map(|(d, r)| r.total_estimated_ms(pool.spec(d)))
        .sum();
    assert!(ms < serial, "makespan {ms} vs serial {serial}");
}

/// Capacity admission: the pool advertises the summed ceiling, and a
/// job past it fails with a device OOM while batch-mates succeed.
#[test]
fn sharded_engine_oom_past_pool_capacity() {
    use gpu_bucket_sort::sim::GpuSpec;
    let tiny = GpuSpec {
        name: "tiny".into(),
        global_memory_bytes: 1 << 20,
        ..GpuModel::Gtx260.spec()
    };
    let params = small_params();
    let sorter = ShardedSort::new(params);
    let mut pool = DevicePool::from_specs(vec![tiny.clone(), tiny]).unwrap();
    // Two 1 MB devices hold 2 × 128K keys; 400K cannot fit.
    let mut keys: Vec<Key> = (0..400_000u32).rev().collect();
    let err = sorter.sort(&mut keys, &mut pool).unwrap_err();
    assert!(err.is_oom(), "{err}");
}

/// The sharded engine behind the batched service: responses verify,
/// and the engine reports its kind.
#[test]
fn service_runs_on_sharded_engine() {
    let cfg = ServiceConfig {
        engine: EngineKind::Sharded,
        sort: BucketSortParams { tile: 256, s: 16 },
        verify: true,
        batch: BatchConfig {
            max_batch_keys: 1 << 20,
            max_batch_requests: 8,
            max_wait_ms: 1,
            queue_capacity: 64,
            max_queued_keys: 1 << 24,
            ..Default::default()
        },
        ..Default::default()
    };
    let engine = ShardedSortEngine::new(&cfg).unwrap();
    assert_eq!(engine.kind(), EngineKind::Sharded);
    let client = SortService::start_with_engine(cfg, engine).unwrap();
    for (i, dist) in [Distribution::Uniform, Distribution::Zipf, Distribution::Sorted]
        .into_iter()
        .enumerate()
    {
        let keys = dist.generate(120_000, i as u64);
        let out = client.sort(SortRequest::new(keys.clone())).unwrap();
        assert!(is_sorted_permutation(&keys, out.keys_u32()));
        assert_eq!(out.engine, EngineKind::Sharded);
    }
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_completed"], 3);
}

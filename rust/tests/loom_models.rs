//! Interleaving models for the sync core, run under the in-tree
//! model checker (`rust/src/util/loom.rs`).
//!
//! Two personalities:
//!
//! * `RUSTFLAGS="--cfg loom" cargo test --test loom_models` — the
//!   facade (`util::sync`) resolves to the model checker's mirrored
//!   primitives and every scenario below explores **all** bounded
//!   thread interleavings (preemption bound
//!   `GBS_LOOM_MAX_PREEMPTIONS`, default 2). A lost wakeup surfaces as
//!   a detected deadlock; an ordering bug as the failing schedule's
//!   assertion.
//! * plain `cargo test` — the same scenarios run as ordinary
//!   multi-threaded smoke tests (facade = `std::sync`). The modeled
//!   structures cannot go *untested* on the tier-1 path just because
//!   loom is a separate CI job.
//!
//! Scenarios (the tentpole list):
//! 1. worker-pool dispatch: park/unpark, nested dispatch, shutdown
//!    without lost wakeups;
//! 2. the scheduler's bounded queue: submit / drain / retire;
//! 3. scratch-arena take/put under concurrent misses;
//! 4. the net credit window: a slot must be freed **before** the
//!    `Credit` frame is written (and the checker must catch the
//!    reversed ordering).

use std::collections::VecDeque;

use gpu_bucket_sort::coordinator::queue::{BoundedQueue, PushError};
use gpu_bucket_sort::net::credit::{CreditGate, ServerWindow};
use gpu_bucket_sort::util::arena::ScratchArena;
use gpu_bucket_sort::util::pool::WorkerPool;
use gpu_bucket_sort::util::sync::{
    self as sync, lock_unpoisoned, wait_unpoisoned, Arc, AtomicUsize, Condvar, Mutex, Ordering,
};

/// A tiny blocking channel on the facade primitives — the stand-in for
/// the TCP wire in the credit models (a frame "arrives" when the
/// receiver pops it).
#[derive(Default)]
struct Chan {
    q: Mutex<VecDeque<u32>>,
    cv: Condvar,
}

impl Chan {
    fn send(&self, v: u32) {
        lock_unpoisoned(&self.q).push_back(v);
        self.cv.notify_one();
    }

    fn recv(&self) -> u32 {
        let mut q = lock_unpoisoned(&self.q);
        loop {
            if let Some(v) = q.pop_front() {
                return v;
            }
            q = wait_unpoisoned(&self.cv, q);
        }
    }
}

/// One round trip of the credit-window protocol with `reqs` pipelined
/// requests and a window of 1, exercising all four protocol actors:
/// submitter (this thread), server reader, server pump, client reader.
/// `release_first` selects the correct ordering (free the window slot,
/// then write the Credit frame) or the buggy reversal the loom model
/// must catch.
fn credit_protocol(reqs: u32, release_first: bool) {
    let gate = Arc::new(CreditGate::new(1));
    let window = Arc::new(ServerWindow::new(1));
    let begin_wire = Arc::new(Chan::default()); // client → server reader
    let pump_wire = Arc::new(Chan::default()); // server reader → pump
    let credit_wire = Arc::new(Chan::default()); // pump → client reader

    let srv_window = Arc::clone(&window);
    let srv_in = Arc::clone(&begin_wire);
    let srv_out = Arc::clone(&pump_wire);
    let server_reader = sync::thread::spawn_named("srv-reader".into(), move || {
        for _ in 0..reqs {
            let id = srv_in.recv();
            // The server's defensive check: a conforming client (one
            // that only spends granted credits) must never find the
            // window exhausted.
            assert!(
                !srv_window.is_exhausted(),
                "credit spent before window slot was freed"
            );
            srv_window.begin();
            srv_out.send(id);
        }
    });

    let pump_window = Arc::clone(&window);
    let pump_in = Arc::clone(&pump_wire);
    let pump_out = Arc::clone(&credit_wire);
    let pump = sync::thread::spawn_named("srv-pump".into(), move || {
        for _ in 0..reqs {
            let id = pump_in.recv();
            if release_first {
                pump_window.release();
                pump_out.send(id);
            } else {
                // The bug under test: credit on the wire while the
                // window slot is still occupied.
                pump_out.send(id);
                pump_window.release();
            }
        }
    });

    let client_gate = Arc::clone(&gate);
    let client_in = Arc::clone(&credit_wire);
    let client_reader = sync::thread::spawn_named("cli-reader".into(), move || {
        for _ in 0..reqs {
            let _ = client_in.recv();
            client_gate.grant(1);
        }
    });

    // The submitter: spend a credit, put a SortBegin on the wire.
    for id in 1..=reqs {
        assert!(gate.acquire(), "gate died mid-model");
        begin_wire.send(id);
    }

    server_reader.join().expect("server reader");
    pump.join().expect("pump");
    client_reader.join().expect("client reader");
}

/// Pool scenario: 1 resident + the dispatcher run a 2-task job (the
/// resident must be unparked), then the pool shuts down (the resident
/// must see the stop signal — a lost wakeup deadlocks the model).
fn pool_dispatch_and_shutdown() {
    let pool = WorkerPool::with_residents(1);
    let count = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&count);
    pool.run(2, 2, &move |_| {
        c.fetch_add(1, Ordering::SeqCst);
    });
    assert_eq!(count.load(Ordering::SeqCst), 2);
    pool.shutdown();
}

/// Pool scenario: shutdown races the resident's very first park.
fn pool_immediate_shutdown() {
    let pool = WorkerPool::with_residents(1);
    pool.shutdown();
}

/// Pool scenario: a task itself dispatches into the pool. The inner
/// dispatcher participates in its own job, so this must never deadlock
/// even with every resident busy.
fn pool_nested_dispatch() {
    let pool = WorkerPool::with_residents(1);
    let count = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&count);
    let inner_pool: &WorkerPool = &pool;
    pool.run(2, 2, &move |i| {
        if i == 0 {
            let cc = Arc::clone(&c);
            inner_pool.run(2, 2, &move |_| {
                cc.fetch_add(1, Ordering::SeqCst);
            });
        } else {
            c.fetch_add(1, Ordering::SeqCst);
        }
    });
    assert_eq!(count.load(Ordering::SeqCst), 3);
    pool.shutdown();
}

/// Queue scenario: a capacity-1 queue forces the producer to block on
/// the slots condvar; the consumer drains everything after `drain`.
fn queue_submit_drain() {
    let q = Arc::new(BoundedQueue::<u32>::new(1, 1));
    let qc = Arc::clone(&q);
    let consumer = sync::thread::spawn_named("consumer".into(), move || {
        let mut served = 0u32;
        while let Some(_item) = qc.pop(0) {
            served += 1;
            qc.finish(0);
        }
        served
    });
    q.push_blocking(1).expect("live consumer");
    q.push_blocking(2).expect("live consumer");
    q.drain();
    assert_eq!(consumer.join().expect("consumer"), 2);
}

/// Queue scenario: a producer blocked on a full queue must be woken
/// (with its item handed back) when the last consumer retires — the
/// no-lost-wakeup half of `retire`.
fn queue_retire_unblocks_producer() {
    let q = Arc::new(BoundedQueue::<u32>::new(1, 1));
    q.try_push(1).expect("first push fits");
    let qc = Arc::clone(&q);
    let retirer = sync::thread::spawn_named("retirer".into(), move || {
        qc.retire(0);
    });
    // Queue full and the only consumer retiring: this must return the
    // item, not hang. (A lost retire notification deadlocks the model.)
    assert_eq!(q.push_blocking(2), Err(2));
    match q.try_push(3) {
        Err(PushError::Dead(item)) => assert_eq!(item, 3),
        other => panic!("expected Dead, got {other:?}"),
    }
    retirer.join().expect("retirer");
}

/// Arena scenario: two threads check out and return buffers
/// concurrently; every checkout resolves to exactly one hit or miss
/// and at most two buffers end up parked.
fn arena_concurrent_take_put() {
    let arena = ScratchArena::new();
    let a2 = arena.clone();
    let peer = sync::thread::spawn_named("arena-peer".into(), move || {
        let buf = a2.take::<u32>(4, 7);
        assert_eq!(buf.len(), 4);
    });
    {
        let buf = arena.take::<u32>(4, 9);
        assert!(buf.iter().all(|&x| x == 9));
    }
    peer.join().expect("arena peer");
    let stats = arena.stats();
    assert_eq!(stats.hits + stats.misses, 2);
    assert!(stats.buffers <= 2, "{stats:?}");
}

#[cfg(loom)]
mod models {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// All models run with explicit bounds (not the env-var defaults):
    /// a generous execution cap and the standard preemption bound of 2,
    /// which catches every bug class these scenarios encode.
    fn explore<F: Fn() + Send + Sync + 'static>(f: F) {
        gpu_bucket_sort::util::loom::model_with_limits(f, 500_000, 2);
    }

    #[test]
    fn pool_dispatch_park_unpark() {
        explore(pool_dispatch_and_shutdown);
    }

    #[test]
    fn pool_shutdown_races_first_park() {
        explore(pool_immediate_shutdown);
    }

    #[test]
    fn pool_nested_dispatch_is_deadlock_free() {
        explore(pool_nested_dispatch);
    }

    #[test]
    fn bounded_queue_submit_drain() {
        explore(queue_submit_drain);
    }

    #[test]
    fn bounded_queue_retire_wakes_producer() {
        explore(queue_retire_unblocks_producer);
    }

    #[test]
    fn arena_take_put_concurrent_misses() {
        explore(arena_concurrent_take_put);
    }

    #[test]
    fn credit_window_freed_before_credit_frame() {
        // The correct ordering holds under every bounded interleaving.
        explore(|| credit_protocol(2, true));
    }

    #[test]
    fn credit_model_catches_reversed_release() {
        // Reversing the release/send order must be *caught*: some
        // schedule lets the client spend the credit while the window
        // slot is still occupied.
        let result = catch_unwind(AssertUnwindSafe(|| {
            explore(|| credit_protocol(2, false));
        }));
        let payload = result.expect_err("the checker must find the violation");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("window slot"),
            "unexpected failure payload: {msg:?}"
        );
    }
}

/// The same scenarios as plain multi-threaded smokes on std
/// primitives, so `cargo test` (tier-1) exercises this file too.
#[cfg(not(loom))]
mod smoke {
    use super::*;

    #[test]
    fn pool_dispatch_park_unpark() {
        pool_dispatch_and_shutdown();
    }

    #[test]
    fn pool_shutdown_races_first_park() {
        pool_immediate_shutdown();
    }

    #[test]
    fn pool_nested_dispatch_is_deadlock_free() {
        pool_nested_dispatch();
    }

    #[test]
    fn bounded_queue_submit_drain() {
        queue_submit_drain();
    }

    #[test]
    fn bounded_queue_retire_wakes_producer() {
        queue_retire_unblocks_producer();
    }

    #[test]
    fn arena_take_put_concurrent_misses() {
        arena_concurrent_take_put();
    }

    #[test]
    fn credit_window_round_trips() {
        // Many pipelined rounds through all four protocol actors; the
        // reader's defensive assert doubles as the invariant check.
        credit_protocol(64, true);
    }
}

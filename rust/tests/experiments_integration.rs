//! Experiment-harness integration: every table/figure function runs at
//! reduced scale, renders, and persists; the qualitative paper claims
//! encoded in the tables hold end to end.

use gpu_bucket_sort::experiments as exp;
use gpu_bucket_sort::sim::GpuModel;

#[test]
fn all_tables_generate_and_persist() {
    let dir = std::env::temp_dir().join(format!("gbs_results_{}", std::process::id()));
    let ladder = exp::paper_n_ladder(64 << 20);
    let tables = vec![
        exp::table1(),
        exp::fig3_sample_size(&[32 << 20], &exp::FIG3_S_VALUES),
        exp::fig4_devices(&ladder),
        exp::fig5_step_breakdown(&[32 << 20]),
        exp::fig6_gtx285(&ladder),
        exp::fig7_tesla(&ladder),
        exp::sort_rate_series(&ladder, GpuModel::TeslaC1060),
        exp::sharded_scaling(&ladder, &[1, 2, 4], GpuModel::Gtx285_2G),
    ];
    for t in &tables {
        assert!(!t.rows.is_empty(), "{}", t.name);
        let path = t.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() > t.rows.len(), "{}", t.name);
        // Console rendering is well-formed.
        let md = t.to_markdown();
        assert!(md.contains(&t.name));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn headline_claims_hold_at_paper_scale() {
    // The cross-figure headline: deterministic ≈ randomized (uniform),
    // both ≫ Thrust Merge, GBS alone reaches the top of the range.
    let ns = exp::paper_n_ladder(256 << 20);
    let fig6 = exp::fig6_gtx285(&ns);
    let at = |label: &str| {
        fig6.rows
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, v)| v.clone())
            .unwrap()
    };
    let r16 = at("16M");
    let (gbs, rss, thrust) = (r16[0].unwrap(), r16[1].unwrap(), r16[2].unwrap());
    assert!(
        (0.5..2.0).contains(&(rss / gbs)),
        "sample sorts comparable: {gbs} vs {rss}"
    );
    assert!(thrust > 1.5 * gbs, "thrust clearly slower: {thrust} vs {gbs}");
    assert!(at("256M")[0].is_some(), "GBS reaches 256M");
    assert!(at("64M")[1].is_none(), "RSS stops at 32M (1 GB card)");
    assert!(at("32M")[2].is_none(), "Thrust stops at 16M");
}

#[test]
fn fig3_tradeoff_is_u_shaped_at_64m() {
    let t = exp::fig3_sample_size(&[64 << 20], &exp::FIG3_S_VALUES);
    let series: Vec<f64> = t.rows.iter().map(|r| r.1[0].unwrap()).collect();
    let (min_idx, min) = series
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, v)| (i, *v))
        .unwrap();
    assert!(min_idx > 0 && min_idx < series.len() - 1, "{series:?}");
    assert!(series[0] > min * 1.05 && series[series.len() - 1] > min * 1.02);
}

#[test]
fn gbs_is_deterministic_across_runs() {
    // §5: "<1 ms observed variance" — identical estimates for repeated
    // runs on the same input class.
    let a = exp::gbs_ms(32 << 20, 64, GpuModel::Gtx285_2G).unwrap();
    let b = exp::gbs_ms(32 << 20, 64, GpuModel::Gtx285_2G).unwrap();
    assert_eq!(a, b);
}

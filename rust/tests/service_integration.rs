//! Service integration: engine fallback behaviour, verify-mode fault
//! detection, mixed success/failure batches, metrics consistency,
//! sustained concurrent load, and multi-worker scheduling (byte-level
//! determinism and counter balance under concurrency).

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::config::{BatchConfig, EngineKind, ServiceConfig};
use gpu_bucket_sort::coordinator::{
    JobData, SimSortEngine, SortEngine, SortRequest, SortService,
};
use gpu_bucket_sort::sim::{GpuModel, GpuSim, GpuSpec};
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{KeyData, KeyType};

fn cfg() -> ServiceConfig {
    ServiceConfig {
        verify: true,
        batch: BatchConfig {
            max_batch_keys: 1 << 20,
            max_batch_requests: 8,
            max_wait_ms: 1,
            queue_capacity: 256,
            max_queued_keys: 1 << 26,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn sustained_concurrent_load() {
    let client = SortService::start(cfg()).unwrap();
    let total = 64;
    std::thread::scope(|scope| {
        for w in 0..8u64 {
            let client = client.clone();
            scope.spawn(move || {
                for r in 0..total / 8 {
                    let dist = Distribution::ALL[(w as usize + r) % Distribution::ALL.len()];
                    let keys = dist.generate(5_000 + r * 997, w * 100 + r as u64);
                    let out = client.sort(SortRequest::new(keys.clone())).unwrap();
                    assert!(gpu_bucket_sort::is_sorted_permutation(
                        &keys,
                        out.keys_u32()
                    ));
                }
            });
        }
    });
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_completed"], total as u64);
    assert_eq!(snap.counters["requests_received"], total as u64);
    assert!(!snap.counters.contains_key("requests_failed"));
    // Latency histograms populated.
    assert!(snap.timers["engine_batch"].count > 0);
    assert!(snap.timers["queue_delay"].count >= total as u64);
}

#[test]
fn dropping_every_client_joins_the_service_threads() {
    // Regression for the detached-intake-thread leak: the last client
    // handle's drop must drain and *join* the intake (and, through it,
    // every worker) rather than leaving background threads running. A
    // deadlock on this path hangs the test; repeated cycles confirm the
    // teardown is complete each time.
    for round in 0..5u32 {
        let client = SortService::start(cfg()).unwrap();
        let sorted = client.sort_keys(vec![3 + round, 1, 2]).unwrap();
        assert_eq!(sorted, vec![1, 2, 3 + round]);
        let clone = client.clone();
        drop(client);
        // The service survives as long as any clone is alive.
        assert_eq!(clone.sort_keys(vec![2, 1]).unwrap(), vec![1, 2]);
        drop(clone); // last handle: sends ClientsGone, joins the intake
    }
    // Explicit shutdown followed by drop must also terminate cleanly.
    let client = SortService::start(cfg()).unwrap();
    let clone = client.clone();
    client.shutdown();
    drop(clone);
}

/// The transport-agnostic drain contract (the network tier's shutdown
/// path): `drain(&self)` through one handle completes queued work while
/// other clones stay alive, surviving clones then fail fast with the
/// typed "service stopped" error, repeated drains are idempotent, and
/// dropping the survivors still joins every thread.
#[test]
fn drain_through_one_clone_leaves_survivors_with_typed_errors() {
    let client = SortService::start(cfg()).unwrap();
    let survivor = client.clone();
    assert_eq!(client.sort_keys(vec![3, 1, 2]).unwrap(), vec![1, 2, 3]);

    let snap = client.drain();
    assert_eq!(snap.counters["requests_completed"], 1);

    // No hang, no panic — a typed rejection, exactly what a network
    // front end needs to turn into a `shutdown` error frame.
    let err = survivor.sort_keys(vec![5, 4]).unwrap_err();
    assert!(err.to_string().contains("service stopped"), "{err}");

    // Idempotent: draining an already-drained service just returns the
    // final snapshot.
    let again = survivor.drain();
    assert_eq!(again.counters["requests_completed"], 1);

    drop(client);
    drop(survivor); // last handle: joins intake + workers cleanly
}

#[test]
fn verify_mode_catches_a_corrupting_engine() {
    /// An engine that returns sorted output for the wrong keys.
    struct EvilEngine;
    impl SortEngine for EvilEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Native
        }
        fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<gpu_bucket_sort::Result<JobData>> {
            jobs.into_iter()
                .map(|mut j| {
                    if let KeyData::U32(k) = &mut j.keys {
                        k.sort_unstable();
                        if !k.is_empty() {
                            k[0] = k[0].wrapping_add(1); // corrupt
                        }
                    }
                    Ok(j)
                })
                .collect()
        }
    }
    let client = SortService::start_with_engine(cfg(), EvilEngine).unwrap();
    let err = client
        .sort(SortRequest::new(vec![5u32, 3, 8, 1]))
        .expect_err("verification must catch the corruption");
    assert!(err.to_string().contains("verification failed"), "{err}");
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_failed"], 1);
}

#[test]
fn mixed_batch_partial_failure() {
    // Sim engine on a small device: jobs over the ceiling fail with
    // OOM, batch-mates succeed — in the same batch.
    let mut config = cfg();
    config.sort = BucketSortParams { tile: 256, s: 16 };
    config.batch.max_batch_requests = 4;
    config.batch.max_wait_ms = 20;
    let spec = GpuSpec {
        name: "tiny-2MB".into(),
        global_memory_bytes: 2 << 20,
        ..GpuModel::Gtx260.spec()
    };
    let engine = SimSortEngine::from_parts(spec, config.sort).unwrap();
    let client = SortService::start_with_engine(config, engine).unwrap();

    let small = Distribution::Uniform.generate(20_000, 1);
    let big = Distribution::Uniform.generate(600_000, 2);
    let rx_small = client.submit(SortRequest::new(small.clone())).unwrap();
    let rx_big = client.submit(SortRequest::new(big)).unwrap();

    let ok = rx_small.recv().unwrap().unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&small, ok.keys_u32()));
    let err = rx_big.recv().unwrap().unwrap_err();
    assert!(err.is_oom(), "{err}");
    client.shutdown();
}

#[test]
fn engine_construction_failure_reported_synchronously() {
    let bad = ServiceConfig {
        engine: EngineKind::Pjrt,
        artifacts_dir: "/definitely/not/a/dir".into(),
        ..Default::default()
    };
    let err = SortService::start(bad).expect_err("construction must fail");
    assert!(err.to_string().contains("manifest"), "{err}");
}

#[test]
fn zero_and_giant_requests() {
    let client = SortService::start(cfg()).unwrap();
    // Zero-key request completes without touching the engine.
    let out = client.sort(SortRequest::new(Vec::<u32>::new())).unwrap();
    assert!(out.keys.is_empty());
    // A request larger than max_batch_keys forms its own batch.
    let giant = Distribution::Uniform.generate(3 << 20, 9);
    let out = client.sort(SortRequest::new(giant.clone())).unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&giant, out.keys_u32()));
    assert_eq!(out.batch_size, 1);
    client.shutdown();
}

/// The multi-worker determinism contract: N concurrent submitters,
/// mixed job sizes and distributions, responses possibly completing out
/// of order across 4 workers — yet every response is **byte-identical**
/// to a direct single-device `BucketSort` of the same input, and the
/// metrics balance exactly after the signalled shutdown.
#[test]
fn multi_worker_responses_byte_identical_to_bucket_sort() {
    let config = ServiceConfig {
        workers: 4,
        // One single-threaded native engine per worker: concurrency
        // comes from the scheduler, not from inside an engine.
        native: gpu_bucket_sort::exec::NativeParams {
            workers: 1,
            ..Default::default()
        },
        ..cfg()
    };
    let client = SortService::start(config).unwrap();

    let submitters = 6u64;
    let per_submitter = 8usize;
    std::thread::scope(|scope| {
        for s in 0..submitters {
            let client = client.clone();
            scope.spawn(move || {
                let sorter =
                    BucketSort::try_new(BucketSortParams { tile: 256, s: 16 }).unwrap();
                for r in 0..per_submitter {
                    let dist = Distribution::ALL[(s as usize + r) % Distribution::ALL.len()];
                    let n = 2_000 + 3_137 * ((s as usize + r) % 5);
                    let keys = dist.generate(n, s * 100 + r as u64);

                    // The reference: the paper's Algorithm 1, directly.
                    let mut expected = keys.clone();
                    let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
                    sorter.sort(&mut expected, &mut sim).unwrap();

                    let out = client.sort(SortRequest::new(keys)).unwrap();
                    assert_eq!(
                        out.keys_u32(),
                        expected,
                        "submitter {s} request {r} ({dist}, n={n}) diverged"
                    );
                    assert!(out.worker < 4);
                }
            });
        }
    });

    let snap = client.shutdown();
    let total = submitters * per_submitter as u64;
    assert_eq!(snap.counters["requests_received"], total);
    assert_eq!(snap.counters["requests_completed"], total);
    assert!(!snap.counters.contains_key("requests_failed"));
    assert!(!snap.counters.contains_key("requests_rejected"));
    assert_eq!(
        snap.counters["keys_received"], snap.counters["keys_sorted"],
        "every received key was sorted exactly once"
    );
    assert_eq!(snap.timers["request_latency"].count, total);
    // All four workers carry per-worker accounting; under this much
    // load at least two of them actually ran batches.
    let active_workers = (0..4)
        .filter(|w| snap.counters.contains_key(&format!("worker_{w}_batches")))
        .count();
    assert!(active_workers >= 2, "only {active_workers} workers ran");
    let batches: u64 = (0..4)
        .filter_map(|w| snap.counters.get(&format!("worker_{w}_batches")))
        .sum();
    assert_eq!(batches, snap.counters["batches_dispatched"]);
}

/// Counter balance when jobs fail mid-batch: per-worker sim engines on
/// a tiny device OOM the oversized jobs; after shutdown
/// `received == completed + failed` and key accounting covers exactly
/// the successes.
#[test]
fn multi_worker_counters_balance_with_failures() {
    let mut config = cfg();
    config.workers = 2;
    config.sort = BucketSortParams { tile: 256, s: 16 };
    let client =
        SortService::start_with_worker_factory(config, |cfg: &ServiceConfig, _worker: usize| {
            let tiny = GpuSpec {
                name: "tiny-2MB".into(),
                global_memory_bytes: 2 << 20,
                ..GpuModel::Gtx260.spec()
            };
            Ok(Box::new(SimSortEngine::from_parts(tiny, cfg.sort)?) as Box<dyn SortEngine>)
        })
        .unwrap();

    let mut rxs = Vec::new();
    let mut expect_ok = 0u64;
    let mut ok_keys = 0u64;
    for i in 0..12u64 {
        let oversized = i % 3 == 2;
        let n = if oversized { 600_000 } else { 10_000 };
        if !oversized {
            expect_ok += 1;
            ok_keys += n as u64;
        }
        let keys = Distribution::Uniform.generate(n, i);
        rxs.push((oversized, client.submit(SortRequest::new(keys)).unwrap()));
    }
    for (oversized, rx) in rxs {
        match rx.recv().unwrap() {
            Ok(out) => {
                assert!(!oversized);
                assert!(gpu_bucket_sort::is_sorted(out.keys_u32()));
            }
            Err(e) => {
                assert!(oversized, "small job failed: {e}");
                assert!(e.is_oom(), "{e}");
            }
        }
    }
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_received"], 12);
    assert_eq!(snap.counters["requests_completed"], expect_ok);
    assert_eq!(snap.counters["requests_failed"], 12 - expect_ok);
    assert_eq!(snap.counters["keys_sorted"], ok_keys);
}

/// A sharded service with 2 workers: each worker leases a disjoint half
/// of the 4-device pool and serves jobs independently.
#[test]
fn sharded_multi_worker_service() {
    let config = ServiceConfig {
        engine: EngineKind::Sharded,
        workers: 2,
        sort: BucketSortParams { tile: 256, s: 16 },
        ..cfg()
    };
    let client = SortService::start(config).unwrap();
    let mut rxs = Vec::new();
    let mut inputs = Vec::new();
    for i in 0..8u64 {
        let keys = Distribution::Staggered.generate(30_000 + (i as usize) * 1_111, i);
        rxs.push(client.submit(SortRequest::new(keys.clone())).unwrap());
        inputs.push(keys);
    }
    for (rx, input) in rxs.into_iter().zip(inputs) {
        let out = rx.recv().unwrap().unwrap();
        assert!(gpu_bucket_sort::is_sorted_permutation(
            &input,
            out.keys_u32()
        ));
        assert_eq!(out.engine, EngineKind::Sharded);
        assert!(out.worker < 2);
    }
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_completed"], 8);

    // Over-provisioned worker counts are rejected at validation time.
    let bad = ServiceConfig {
        engine: EngineKind::Sharded,
        workers: 9,
        ..ServiceConfig::default()
    };
    assert!(SortService::start(bad).is_err());
}

#[test]
fn metrics_keys_accounting_balances() {
    let client = SortService::start(cfg()).unwrap();
    let sizes = [100usize, 5000, 65_536];
    for (i, &n) in sizes.iter().enumerate() {
        let keys = Distribution::Uniform.generate(n, i as u64);
        client.sort(SortRequest::new(keys)).unwrap();
    }
    let snap = client.shutdown();
    let total: usize = sizes.iter().sum();
    assert_eq!(snap.counters["keys_received"], total as u64);
    assert_eq!(snap.counters["keys_sorted"], total as u64);
}

/// The typed-API compatibility contract: u32 key-only requests return
/// **byte-identical** results to the pre-redesign path — which, for a
/// key-only sort, is the unique sorted ordering of the input multiset —
/// across the six robustness distributions and at every worker count.
#[test]
fn u32_key_only_path_byte_identical_across_distributions_and_workers() {
    for workers in [1usize, 4] {
        let config = ServiceConfig {
            workers,
            ..cfg()
        };
        let client = SortService::start(config).unwrap();
        for (i, dist) in Distribution::ROBUSTNESS_SUITE.iter().enumerate() {
            let keys = dist.generate(20_000 + i * 1_001, i as u64);
            let mut expected = keys.clone();
            expected.sort_unstable();
            let out = client.sort(SortRequest::new(keys)).unwrap();
            assert_eq!(
                out.keys,
                KeyData::U32(expected),
                "{dist} diverged at {workers} workers"
            );
            assert!(out.payload.is_none(), "key-only jobs carry no payload");
        }
        client.shutdown();
    }
}

/// Key–value requests through the full multi-worker service: payloads
/// land with their keys, stably, and descending requests come back
/// reversed — byte-identically for any worker count.
#[test]
fn key_value_and_descending_requests_through_the_service() {
    let mut reference: Option<Vec<(u32, u64)>> = None;
    for workers in [1usize, 3] {
        let config = ServiceConfig {
            workers,
            ..cfg()
        };
        let client = SortService::start(config).unwrap();

        // Duplicate-heavy keys so stability is actually exercised.
        let keys: Vec<u32> = (0..30_000u32)
            .map(|x| x.wrapping_mul(2654435761) % 128)
            .collect();
        let payload: Vec<u64> = (0..keys.len() as u64).collect();
        let out = client
            .sort(
                SortRequest::builder(keys.clone())
                    .payload(payload.clone())
                    .self_check(true)
                    .tag("kv")
                    .build()
                    .unwrap(),
            )
            .unwrap();
        let sorted = out.keys_u32();
        let out_payload = out.payload.as_ref().expect("payload echoed");
        assert!(gpu_bucket_sort::is_sorted_permutation(&keys, sorted));
        for (k, p) in sorted.iter().zip(out_payload) {
            assert_eq!(keys[*p as usize], *k, "payload divorced from key");
        }
        for (w, pw) in sorted.windows(2).zip(out_payload.windows(2)) {
            if w[0] == w[1] {
                assert!(pw[0] < pw[1], "unstable at key {}", w[0]);
            }
        }
        // Identical bytes at every worker count.
        let pairs: Vec<(u32, u64)> = sorted
            .iter()
            .copied()
            .zip(out_payload.iter().copied())
            .collect();
        match &reference {
            None => reference = Some(pairs),
            Some(r) => assert_eq!(r, &pairs, "worker count changed the bytes"),
        }

        // Descending: the exact reverse of the ascending result.
        let desc = client
            .sort(
                SortRequest::builder(keys.clone())
                    .payload(payload.clone())
                    .descending(true)
                    .self_check(true)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        let mut re_reversed = desc.keys_u32().to_vec();
        re_reversed.reverse();
        assert_eq!(re_reversed, sorted, "descending is not the exact reverse");
        let mut rev_payload = desc.payload.clone().unwrap();
        rev_payload.reverse();
        assert_eq!(&rev_payload, out_payload);

        client.shutdown();
    }
}

/// Typed requests served by the sim and sharded engines end to end,
/// including the OOM ceiling arriving sooner for wider records.
#[test]
fn typed_requests_on_sim_and_sharded_engines() {
    // Sim engine: u64 keys cost 2× the memory, so a job that fits as
    // u32 OOMs as u64 on a device sized in between.
    let mut config = cfg();
    config.sort = BucketSortParams { tile: 256, s: 16 };
    let spec = GpuSpec {
        name: "tiny-3MB".into(),
        global_memory_bytes: 3 << 20,
        ..GpuModel::Gtx260.spec()
    };
    let engine = SimSortEngine::from_parts(spec, config.sort).unwrap();
    let client = SortService::start_with_engine(config, engine).unwrap();
    let n = 300_000;
    let keys32: Vec<u32> = (0..n as u32).rev().collect();
    let out = client.sort(SortRequest::new(keys32.clone())).unwrap();
    assert!(gpu_bucket_sort::is_sorted(out.keys_u32()));
    let keys64: Vec<u64> = (0..n as u64).rev().collect();
    let err = client.sort(SortRequest::new(keys64)).unwrap_err();
    assert!(err.is_oom(), "u64 job must hit the ceiling sooner: {err}");
    client.shutdown();

    // Sharded engine: NaN-containing f32 key–value across the pool.
    let config = ServiceConfig {
        engine: EngineKind::Sharded,
        sort: BucketSortParams { tile: 256, s: 16 },
        ..cfg()
    };
    let client = SortService::start(config).unwrap();
    let mut fkeys: Vec<f32> = (0..40_000u32)
        .map(|x| x.wrapping_mul(2654435761) as f32 - 2e9)
        .collect();
    fkeys[9] = f32::NAN;
    fkeys[10] = f32::INFINITY;
    let payload: Vec<u64> = (0..fkeys.len() as u64).collect();
    let out = client
        .sort(
            SortRequest::builder(fkeys.clone())
                .payload(payload)
                .self_check(true)
                .build()
                .unwrap(),
        )
        .unwrap();
    assert_eq!(out.keys.key_type(), KeyType::F32);
    assert!(out.keys.is_sorted(false));
    match &out.keys {
        KeyData::F32(sorted) => {
            assert!(gpu_bucket_sort::is_sorted_permutation(&fkeys, sorted));
            for (k, p) in sorted.iter().zip(out.payload.as_ref().unwrap()) {
                assert_eq!(
                    f32::to_bits(fkeys[*p as usize]),
                    f32::to_bits(*k),
                    "payload divorced from key"
                );
            }
        }
        other => panic!("wrong key type back: {:?}", other.key_type()),
    }
    client.shutdown();
}

#[test]
fn coalesced_batches_byte_identical_to_solo_requests_across_workers() {
    // The coalescing determinism contract, end to end: a burst of small
    // mixed-size, mixed-type requests (which the batcher groups and the
    // native engine coalesces into composed invocations) must return
    // responses byte-identical to sorting each request alone, at every
    // worker count. The solo references are computed through a
    // coalescing-disabled service so the two paths share nothing.
    let mk_requests = || -> Vec<SortRequest> {
        let mut reqs = Vec::new();
        for i in 0..10u64 {
            let n = 800 + 313 * i as usize;
            reqs.push(SortRequest::new(Distribution::Uniform.generate(n, i)));
            reqs.push(SortRequest::new(
                Distribution::Uniform
                    .generate(n / 2, 100 + i)
                    .into_iter()
                    .map(|x| (x as u64) << 11 | 3)
                    .collect::<Vec<u64>>(),
            ));
            let fkeys: Vec<f32> = Distribution::Uniform
                .generate(n / 4, 200 + i)
                .into_iter()
                .map(|x| x as f32 - 2e9)
                .collect();
            reqs.push(SortRequest::new(fkeys));
        }
        reqs
    };

    // Solo references: coalescing off, one worker.
    let solo_cfg = ServiceConfig {
        batch: BatchConfig {
            coalesce_max_keys: 0,
            ..cfg().batch
        },
        ..cfg()
    };
    let solo_client = SortService::start(solo_cfg).unwrap();
    let references: Vec<(KeyData, Option<Vec<u64>>)> = mk_requests()
        .into_iter()
        .map(|r| {
            let out = solo_client.sort(r).unwrap();
            (out.keys, out.payload)
        })
        .collect();
    solo_client.shutdown();

    let mut coalesced_total = 0u64;
    for workers in [1usize, 2, 4] {
        // A generous batching window so the burst actually shares
        // batches (and therefore coalesced groups).
        let coalesce_cfg = ServiceConfig {
            workers,
            batch: BatchConfig {
                max_wait_ms: 20,
                max_batch_requests: 16,
                ..cfg().batch
            },
            ..cfg()
        };
        assert!(coalesce_cfg.batch.coalesce_max_keys > 0);
        let client = SortService::start(coalesce_cfg).unwrap();
        let rxs: Vec<_> = mk_requests()
            .into_iter()
            .map(|r| client.submit(r).unwrap())
            .collect();
        for (i, (rx, (ref_keys, ref_payload))) in rxs.into_iter().zip(&references).enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(&out.keys, ref_keys, "request {i} at {workers} workers");
            assert_eq!(&out.payload, ref_payload, "request {i} at {workers} workers");
        }
        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_completed"], 30);
        coalesced_total += snap.counters.get("coalesced_requests").copied().unwrap_or(0);
    }
    // Dispatch timing decides how many requests share each batch, but
    // over three 30-request bursts the mechanism must have engaged.
    assert!(
        coalesced_total > 0,
        "coalesced dispatch never engaged across the bursts"
    );
}

#[test]
fn coalesced_key_value_requests_stay_stable_per_request() {
    // Key-value requests with heavy ties coalesce too; each response
    // must keep the per-request stable (submission-order) payload
    // pairing the uncoalesced path guarantees.
    let client = SortService::start(cfg()).unwrap();
    let mut rxs = Vec::new();
    let mut inputs = Vec::new();
    for i in 0..8u64 {
        let keys: Vec<u32> = Distribution::Uniform
            .generate(600 + 97 * i as usize, i)
            .into_iter()
            .map(|x| x % 16)
            .collect();
        let payload: Vec<u64> = (0..keys.len() as u64).collect();
        let req = SortRequest::builder(keys.clone())
            .payload(payload.clone())
            .self_check(true)
            .build()
            .unwrap();
        rxs.push(client.submit(req).unwrap());
        inputs.push((keys, payload));
    }
    for (rx, (keys_in, _)) in rxs.into_iter().zip(inputs) {
        let out = rx.recv().unwrap().unwrap();
        let sorted = out.keys.as_u32().unwrap();
        let payload = out.payload.as_ref().unwrap();
        assert!(gpu_bucket_sort::is_sorted_permutation(&keys_in, sorted));
        for (w, pw) in sorted.windows(2).zip(payload.windows(2)) {
            if w[0] == w[1] {
                assert!(pw[0] < pw[1], "tie broke submission order at key {}", w[0]);
            }
        }
        for (k, p) in sorted.iter().zip(payload) {
            assert_eq!(keys_in[*p as usize], *k, "payload divorced from key");
        }
    }
    client.shutdown();
}

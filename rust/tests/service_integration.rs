//! Service integration: engine fallback behaviour, verify-mode fault
//! detection, mixed success/failure batches, metrics consistency, and
//! sustained concurrent load.

use gpu_bucket_sort::algos::bucket_sort::BucketSortParams;
use gpu_bucket_sort::config::{BatchConfig, EngineKind, ServiceConfig};
use gpu_bucket_sort::coordinator::{SimSortEngine, SortEngine, SortJob, SortService};
use gpu_bucket_sort::sim::{GpuModel, GpuSpec};
use gpu_bucket_sort::workload::Distribution;

fn cfg() -> ServiceConfig {
    ServiceConfig {
        verify: true,
        batch: BatchConfig {
            max_batch_keys: 1 << 20,
            max_batch_requests: 8,
            max_wait_ms: 1,
            queue_capacity: 256,
            max_queued_keys: 1 << 26,
        },
        ..Default::default()
    }
}

#[test]
fn sustained_concurrent_load() {
    let client = SortService::start(cfg()).unwrap();
    let total = 64;
    std::thread::scope(|scope| {
        for w in 0..8u64 {
            let client = client.clone();
            scope.spawn(move || {
                for r in 0..total / 8 {
                    let dist = Distribution::ALL[(w as usize + r) % Distribution::ALL.len()];
                    let keys = dist.generate(5_000 + r * 997, w * 100 + r as u64);
                    let out = client.sort(SortJob::new(keys.clone())).unwrap();
                    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, &out.keys));
                }
            });
        }
    });
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_completed"], total as u64);
    assert_eq!(snap.counters["requests_received"], total as u64);
    assert!(!snap.counters.contains_key("requests_failed"));
    // Latency histograms populated.
    assert!(snap.timers["engine_batch"].count > 0);
    assert!(snap.timers["queue_delay"].count >= total as u64);
}

#[test]
fn verify_mode_catches_a_corrupting_engine() {
    /// An engine that returns sorted output for the wrong keys.
    struct EvilEngine;
    impl SortEngine for EvilEngine {
        fn kind(&self) -> EngineKind {
            EngineKind::Native
        }
        fn sort_batch(
            &mut self,
            jobs: Vec<Vec<u32>>,
        ) -> Vec<gpu_bucket_sort::Result<Vec<u32>>> {
            jobs.into_iter()
                .map(|mut k| {
                    k.sort_unstable();
                    if !k.is_empty() {
                        k[0] = k[0].wrapping_add(1); // corrupt
                    }
                    Ok(k)
                })
                .collect()
        }
    }
    let client = SortService::start_with_engine(cfg(), EvilEngine).unwrap();
    let err = client
        .sort(SortJob::new(vec![5, 3, 8, 1]))
        .expect_err("verification must catch the corruption");
    assert!(err.to_string().contains("verification failed"), "{err}");
    let snap = client.shutdown();
    assert_eq!(snap.counters["requests_failed"], 1);
}

#[test]
fn mixed_batch_partial_failure() {
    // Sim engine on a small device: jobs over the ceiling fail with
    // OOM, batch-mates succeed — in the same batch.
    let mut config = cfg();
    config.sort = BucketSortParams { tile: 256, s: 16 };
    config.batch.max_batch_requests = 4;
    config.batch.max_wait_ms = 20;
    let spec = GpuSpec {
        name: "tiny-2MB".into(),
        global_memory_bytes: 2 << 20,
        ..GpuModel::Gtx260.spec()
    };
    let engine = SimSortEngine::from_parts(spec, config.sort).unwrap();
    let client = SortService::start_with_engine(config, engine).unwrap();

    let small = Distribution::Uniform.generate(20_000, 1);
    let big = Distribution::Uniform.generate(600_000, 2);
    let rx_small = client.submit(SortJob::new(small.clone())).unwrap();
    let rx_big = client.submit(SortJob::new(big)).unwrap();

    let ok = rx_small.recv().unwrap().unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&small, &ok.keys));
    let err = rx_big.recv().unwrap().unwrap_err();
    assert!(err.is_oom(), "{err}");
    client.shutdown();
}

#[test]
fn engine_construction_failure_reported_synchronously() {
    let bad = ServiceConfig {
        engine: EngineKind::Pjrt,
        artifacts_dir: "/definitely/not/a/dir".into(),
        ..Default::default()
    };
    let err = SortService::start(bad).expect_err("construction must fail");
    assert!(err.to_string().contains("manifest"), "{err}");
}

#[test]
fn zero_and_giant_requests() {
    let client = SortService::start(cfg()).unwrap();
    // Zero-key request completes without touching the engine.
    let out = client.sort(SortJob::new(vec![])).unwrap();
    assert!(out.keys.is_empty());
    // A request larger than max_batch_keys forms its own batch.
    let giant = Distribution::Uniform.generate(3 << 20, 9);
    let out = client.sort(SortJob::new(giant.clone())).unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&giant, &out.keys));
    assert_eq!(out.batch_size, 1);
    client.shutdown();
}

#[test]
fn metrics_keys_accounting_balances() {
    let client = SortService::start(cfg()).unwrap();
    let sizes = [100usize, 5000, 65_536];
    for (i, &n) in sizes.iter().enumerate() {
        let keys = Distribution::Uniform.generate(n, i as u64);
        client.sort(SortJob::new(keys)).unwrap();
    }
    let snap = client.shutdown();
    let total: usize = sizes.iter().sum();
    assert_eq!(snap.counters["keys_received"], total as u64);
    assert_eq!(snap.counters["keys_sorted"], total as u64);
}

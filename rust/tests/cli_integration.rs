//! CLI integration: the `gbs` binary end to end (spawned as a real
//! process), covering every subcommand.

use std::process::Command;

fn gbs(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_gbs"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_and_specs() {
    let (ok, text) = gbs(&["help"]);
    assert!(ok);
    assert!(text.contains("experiment"));
    let (ok, text) = gbs(&["specs"]);
    assert!(ok, "{text}");
    assert!(text.contains("GTX 285"));
    assert!(text.contains("102")); // Tesla bandwidth
}

#[test]
fn sort_native_and_sim() {
    let (ok, text) = gbs(&["sort", "--n", "200K", "--engine", "native"]);
    assert!(ok, "{text}");
    assert!(text.contains("verified: sorted permutation"), "{text}");

    let (ok, text) = gbs(&[
        "sort", "--n", "100K", "--engine", "sim", "--device", "gtx260", "--algo", "rss",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("Randomized"), "{text}");
    assert!(text.contains("verified"), "{text}");
}

#[test]
fn sort_typed_key_types_and_payloads() {
    // f32 (NaN-containing uniform stream), key–value, descending, on
    // the native engine — the typed path, fully verified.
    let (ok, text) = gbs(&[
        "sort", "--n", "100K", "--key-type", "f32", "--payload", "true", "--descending", "true",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("typed sort (f32, key–value, descending)"), "{text}");
    assert!(text.contains("payload pairing"), "{text}");

    // u64 keys through the simulated device.
    let (ok, text) = gbs(&[
        "sort", "--n", "100K", "--key-type", "u64", "--engine", "sim", "--device", "tesla",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("verified: sorted permutation"), "{text}");

    // i64 keys across the sharded pool.
    let (ok, text) = gbs(&[
        "sort", "--n", "200K", "--key-type", "i64", "--engine", "sharded",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("sharded engine"), "{text}");

    // Unknown key type is a clean error, and --analytic stays u32-only.
    let (ok, _) = gbs(&["sort", "--n", "1K", "--key-type", "u8"]);
    assert!(!ok);
    let (ok, text) = gbs(&[
        "sort", "--n", "1K", "--key-type", "u64", "--analytic", "true",
    ]);
    assert!(!ok);
    assert!(text.contains("u32"), "{text}");
}

#[test]
fn sort_sharded_executes_and_prices_paper_scale() {
    // Executed sharded sort over an explicit heterogeneous pool.
    let (ok, text) = gbs(&[
        "sort", "--n", "200K", "--engine", "sharded", "--devices", "gtx285,tesla",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("device pool: 2 devices"), "{text}");
    assert!(text.contains("verified: sorted permutation"), "{text}");
    assert!(text.contains("makespan"), "{text}");

    // Analytic mode: 768M keys — beyond every Table 1 device — priced
    // across the default 4-device pool without generating data.
    let (ok, text) = gbs(&[
        "sort", "--n", "768M", "--engine", "sharded", "--analytic", "true",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("analytic mode"), "{text}");
    assert!(text.contains("device 3"), "{text}");
    assert!(text.contains("Mkeys/s across the pool"), "{text}");

    // An unknown device list is rejected.
    let (ok, _) = gbs(&[
        "sort", "--n", "1K", "--engine", "sharded", "--devices", "fermi",
    ]);
    assert!(!ok);
}

#[test]
fn sort_kernel_flag() {
    // Both kernels sort and verify on the native and sim engines.
    let (ok, text) = gbs(&["sort", "--n", "100K", "--kernel", "bitonic"]);
    assert!(ok, "{text}");
    assert!(text.contains("verified: sorted permutation"), "{text}");
    let (ok, text) = gbs(&[
        "sort", "--n", "100K", "--engine", "sim", "--kernel", "radix",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("verified"), "{text}");

    // Unknown kernels and kernel selection on a baseline are rejected.
    let (ok, _) = gbs(&["sort", "--n", "1K", "--kernel", "quick"]);
    assert!(!ok);
    let (ok, text) = gbs(&[
        "sort", "--n", "100K", "--engine", "sim", "--algo", "rss", "--kernel", "radix",
    ]);
    assert!(!ok);
    assert!(text.contains("bucket-sort"), "{text}");

    // Help advertises the flag.
    let (ok, text) = gbs(&["help"]);
    assert!(ok);
    assert!(text.contains("--kernel"), "{text}");
}

#[test]
fn sort_digit_bits_flag() {
    // The planner's digit width is tunable and validated; outputs
    // verify at any width.
    let (ok, text) = gbs(&["sort", "--n", "100K", "--digit-bits", "13"]);
    assert!(ok, "{text}");
    assert!(text.contains("verified: sorted permutation"), "{text}");
    let (ok, _) = gbs(&["sort", "--n", "1K", "--digit-bits", "0"]);
    assert!(!ok);
    let (ok, _) = gbs(&["sort", "--n", "1K", "--digit-bits", "17"]);
    assert!(!ok);

    // Help advertises the planner and coalescing knobs.
    let (ok, text) = gbs(&["help"]);
    assert!(ok);
    assert!(text.contains("--digit-bits"), "{text}");
    assert!(text.contains("--coalesce-max-keys"), "{text}");
}

#[test]
fn help_mentions_sharded_engine() {
    let (ok, text) = gbs(&["help"]);
    assert!(ok);
    assert!(text.contains("sharded"), "{text}");
    assert!(text.contains("--devices"), "{text}");
}

#[test]
fn sort_rejects_bad_flags() {
    let (ok, text) = gbs(&["sort", "--n", "bogus"]);
    assert!(!ok);
    assert!(text.contains("error"), "{text}");
    let (ok, _) = gbs(&["sort", "--engine", "warp-drive"]);
    assert!(!ok);
    let (ok, _) = gbs(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn experiment_fast_writes_csv() {
    let out_dir = std::env::temp_dir().join(format!("gbs_cli_{}", std::process::id()));
    let out = out_dir.to_str().unwrap();
    let (ok, text) = gbs(&["experiment", "fig4", "--fast", "true", "--out", out]);
    assert!(ok, "{text}");
    assert!(text.contains("| 1M |"), "{text}");
    let csv = std::fs::read_to_string(out_dir.join("fig4.csv")).unwrap();
    assert!(csv.starts_with("n,"));
    std::fs::remove_dir_all(&out_dir).ok();
}

#[test]
fn config_prints_valid_json() {
    let (ok, text) = gbs(&["config"]);
    assert!(ok, "{text}");
    let parsed = gpu_bucket_sort::util::Json::parse(&text).expect("valid json");
    assert_eq!(parsed.get("engine").and_then(|v| v.as_str()), Some("native"));
    assert_eq!(parsed.get("kernel").and_then(|v| v.as_str()), Some("radix"));
}

#[test]
fn serve_small_load() {
    let (ok, text) = gbs(&[
        "serve", "--requests", "8", "--concurrency", "2", "--n", "50K",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("requests_completed: 8"), "{text}");
}

#[test]
fn serve_multi_worker() {
    let (ok, text) = gbs(&[
        "serve", "--requests", "8", "--concurrency", "4", "--n", "50K", "--workers", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("2 worker(s)"), "{text}");
    assert!(text.contains("requests_completed: 8"), "{text}");

    // Invalid worker counts are rejected up front.
    let (ok, text) = gbs(&["serve", "--workers", "0"]);
    assert!(!ok);
    assert!(text.contains("workers"), "{text}");
}

//! Property tests for the network wire codec (`net::wire`).
//!
//! Two families:
//!
//! * **Round-trip totality** — every frame type, every typed message,
//!   every key type, chunked at arbitrary byte boundaries, comes back
//!   bit-exact (f32 NaN payload bits included).
//! * **Decoder hardening** — truncations at every prefix, corrupt
//!   headers, oversized length prefixes and random byte mutations all
//!   yield *typed* [`WireError`]s: no panic, no over-allocation, and a
//!   CRC-authenticated frame can never silently differ from what was
//!   sent.

use gpu_bucket_sort::config::EngineKind;
use gpu_bucket_sort::net::wire::{
    chunk_frames, crc32, decode_frame, encode_frame, key_data_from_bytes, key_data_to_bytes,
    payload_from_bytes, payload_to_bytes, read_frame, CreditMsg, ErrorCode, ErrorMsg, Frame,
    HelloAckMsg, HelloMsg, Opcode, SortBeginMsg, SortHeaderMsg, WireError, FLAG_LAST, HEADER_LEN,
};
use gpu_bucket_sort::util::propcheck::{forall, Gen};
use gpu_bucket_sort::{KeyData, KeyType};

const MAX_LEN: usize = 1 << 20;

fn random_frame(g: &mut Gen) -> Frame {
    let opcode = *g.choose(&Opcode::ALL);
    let len = g.usize_in(0..300);
    Frame {
        opcode,
        flags: (g.u32() & 0xFFFF) as u16,
        id: g.rng().next_u64(),
        payload: (0..len).map(|_| (g.u32() & 0xFF) as u8).collect(),
    }
}

fn random_key_data(g: &mut Gen) -> KeyData {
    let kt = *g.choose(&KeyType::ALL);
    let n = g.usize_in(0..200);
    match kt {
        KeyType::U32 => KeyData::U32((0..n).map(|_| g.u32()).collect()),
        KeyType::U64 => KeyData::U64((0..n).map(|_| g.rng().next_u64()).collect()),
        KeyType::I32 => KeyData::I32((0..n).map(|_| g.u32() as i32).collect()),
        KeyType::I64 => KeyData::I64((0..n).map(|_| g.rng().next_u64() as i64).collect()),
        // Raw bit patterns: hits NaNs, infinities, subnormals, -0.0.
        KeyType::F32 => KeyData::F32((0..n).map(|_| f32::from_bits(g.u32())).collect()),
    }
}

#[test]
fn every_frame_type_roundtrips() {
    forall(400, "frame encode/decode is the identity", |g| {
        let f = random_frame(g);
        let bytes = encode_frame(&f);
        assert_eq!(bytes.len(), HEADER_LEN + f.payload.len());
        let (back, used) = decode_frame(&bytes, MAX_LEN).expect("authentic frame decodes");
        assert_eq!(back, f);
        assert_eq!(used, bytes.len());
    });
}

#[test]
fn streams_of_frames_recover_and_close_cleanly() {
    forall(120, "streamed frames arrive in order, EOF is clean", |g| {
        let frames: Vec<Frame> = (0..g.usize_in(1..8)).map(|_| random_frame(g)).collect();
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&encode_frame(f));
        }
        let mut cur = std::io::Cursor::new(stream);
        for f in &frames {
            let got = read_frame(&mut cur, MAX_LEN).unwrap().expect("frame present");
            assert_eq!(&got, f);
        }
        // The stream ends exactly at a frame boundary: orderly close.
        assert!(read_frame(&mut cur, MAX_LEN).unwrap().is_none());
    });
}

#[test]
fn key_bytes_reassemble_bitwise_across_chunk_boundaries() {
    forall(300, "chunked key streams reassemble bit-exact", |g| {
        let data = random_key_data(g);
        let bytes = key_data_to_bytes(&data);
        // Chunk at an arbitrary byte granularity — chunks need not align
        // to the key width.
        let chunk = g.usize_in(1..64);
        let frames = chunk_frames(Opcode::KeyChunk, 7, &bytes, chunk);
        let mut reassembled = Vec::new();
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.opcode, Opcode::KeyChunk);
            assert_eq!(f.id, 7);
            let is_last = i + 1 == frames.len();
            assert_eq!(f.flags & FLAG_LAST != 0, is_last, "FLAG_LAST placement");
            reassembled.extend_from_slice(&f.payload);
        }
        assert_eq!(reassembled, bytes);
        let back = key_data_from_bytes(data.key_type(), &reassembled).unwrap();
        // NaN != NaN under PartialEq: compare the byte images.
        assert_eq!(key_data_to_bytes(&back), bytes);
        assert_eq!(back.key_type(), data.key_type());
        assert_eq!(back.len(), data.len());
    });
}

#[test]
fn payload_bytes_roundtrip() {
    forall(200, "u64 payload byte serialization round-trips", |g| {
        let p: Vec<u64> = (0..g.usize_in(0..200)).map(|_| g.rng().next_u64()).collect();
        let bytes = payload_to_bytes(&p);
        assert_eq!(payload_from_bytes(&bytes).unwrap(), p);
        // Any non-multiple-of-8 byte count is a typed error.
        if !bytes.is_empty() {
            let cut = bytes.len() - 1 - g.usize_in(0..8.min(bytes.len() - 1).max(1));
            if cut % 8 != 0 {
                assert!(matches!(
                    payload_from_bytes(&bytes[..cut]),
                    Err(WireError::Malformed(_))
                ));
            }
        }
    });
}

#[test]
fn typed_messages_roundtrip() {
    let engines = [
        EngineKind::Native,
        EngineKind::Sim,
        EngineKind::Pjrt,
        EngineKind::Sharded,
    ];
    forall(300, "typed message payloads round-trip", |g| {
        let tag = if g.bool(0.5) {
            Some(format!("tag-{}", g.u32()))
        } else {
            None
        };
        let begin = SortBeginMsg {
            key_type: *g.choose(&KeyType::ALL),
            descending: g.bool(0.5),
            self_check: g.bool(0.5),
            has_payload: g.bool(0.5),
            total_keys: g.rng().next_u64() >> g.usize_in(0..64),
            tag: tag.clone(),
        };
        assert_eq!(SortBeginMsg::decode(&begin.encode()).unwrap(), begin);

        let header = SortHeaderMsg {
            key_type: *g.choose(&KeyType::ALL),
            total_keys: g.rng().next_u64() >> 16,
            has_payload: g.bool(0.5),
            engine: *g.choose(&engines),
            worker: g.u32(),
            batch_size: g.u32(),
            queue_ms: g.rng().next_f64() * 1e3,
            service_ms: g.rng().next_f64() * 1e3,
            tag,
        };
        assert_eq!(SortHeaderMsg::decode(&header.encode()).unwrap(), header);

        let err = ErrorMsg {
            code: *g.choose(&ErrorCode::ALL),
            message: format!("failure {}", g.u32()),
        };
        assert_eq!(ErrorMsg::decode(&err.encode()).unwrap(), err);

        let hello = HelloMsg {
            max_frame_len: g.u32(),
            session: g.rng().next_u64(),
        };
        assert_eq!(HelloMsg::decode(&hello.encode()).unwrap(), hello);
        let ack = HelloAckMsg {
            credits: g.u32(),
            max_frame_len: g.u32(),
            max_request_keys: g.rng().next_u64(),
        };
        assert_eq!(HelloAckMsg::decode(&ack.encode()).unwrap(), ack);
        let credit = CreditMsg { credits: g.u32() };
        assert_eq!(CreditMsg::decode(&credit.encode()).unwrap(), credit);
    });
}

#[test]
fn truncation_at_every_prefix_is_typed() {
    forall(60, "every truncation is WireError::Truncated", |g| {
        let f = random_frame(g);
        let bytes = encode_frame(&f);
        for cut in 0..bytes.len() {
            assert!(
                matches!(decode_frame(&bytes[..cut], MAX_LEN), Err(WireError::Truncated)),
                "prefix of {cut} bytes must be Truncated"
            );
        }
        // Streaming path: a mid-frame close is Truncated, never Ok(None).
        let cut = g.usize_in(1..bytes.len());
        let mut cur = std::io::Cursor::new(&bytes[..cut]);
        assert!(matches!(
            read_frame(&mut cur, MAX_LEN),
            Err(WireError::Truncated)
        ));
    });
}

#[test]
fn corrupt_headers_yield_typed_errors() {
    forall(120, "header corruption is typed, never a panic", |g| {
        let good = encode_frame(&random_frame(g));

        let mut bad = good.clone();
        bad[g.usize_in(0..4)] ^= 0x40; // magic
        assert!(matches!(decode_frame(&bad, MAX_LEN), Err(WireError::BadMagic)));

        let mut bad = good.clone();
        bad[4] = bad[4].wrapping_add(1 + (g.u32() & 0x7F) as u8); // version
        assert!(matches!(
            decode_frame(&bad, MAX_LEN),
            Err(WireError::BadVersion(_))
        ));

        // Oversized length prefix: rejected before any allocation — a
        // 4 GiB declaration against a 1 MiB ceiling must fail instantly.
        let mut bad = good.clone();
        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_frame(&bad, MAX_LEN),
            Err(WireError::Oversized { len, max }) if len == u32::MAX as usize && max == MAX_LEN
        ));
        let mut cur = std::io::Cursor::new(bad);
        assert!(matches!(
            read_frame(&mut cur, MAX_LEN),
            Err(WireError::Oversized { .. })
        ));

        // An unknown opcode on an otherwise-authentic frame (CRC fixed
        // up) is UnknownOpcode — authenticated before interpreted.
        let mut bad = good.clone();
        bad[5] = 0x7E; // unassigned opcode
        let payload = bad[HEADER_LEN..].to_vec();
        let crc = crc32(&[&bad[0..20], &payload]);
        bad[20..24].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_frame(&bad, MAX_LEN),
            Err(WireError::UnknownOpcode(0x7E))
        ));
    });
}

#[test]
fn random_mutations_never_pass_authentication() {
    forall(400, "mutated frames fail closed", |g| {
        let f = random_frame(g);
        let original = encode_frame(&f);
        let mut bytes = original.clone();
        for _ in 0..g.usize_in(1..4) {
            let pos = g.usize_in(0..bytes.len());
            bytes[pos] ^= 1u8 << g.usize_in(0..8);
        }
        if bytes == original {
            return; // mutations cancelled out
        }
        // CRC32 catches every ≤ 32-bit burst, and the pre-CRC header
        // checks (magic, version, length ceiling) are all typed — so a
        // mutated frame must decode to an error, never to a frame.
        assert!(
            decode_frame(&bytes, MAX_LEN).is_err(),
            "mutated frame decoded successfully"
        );
    });
}

#[test]
fn garbage_decodes_are_error_or_faithful() {
    forall(400, "byte soup never produces an unfaithful frame", |g| {
        let n = g.usize_in(0..(2 * HEADER_LEN + 64));
        let soup: Vec<u8> = (0..n).map(|_| (g.u32() & 0xFF) as u8).collect();
        match decode_frame(&soup, MAX_LEN) {
            Err(_) => {} // typed rejection: the common case
            Ok((frame, used)) => {
                // If the decoder ever accepts, the accepted frame must
                // re-encode to exactly the bytes it consumed.
                assert_eq!(encode_frame(&frame), soup[..used].to_vec());
            }
        }
    });
}

#[test]
fn key_width_violations_are_typed() {
    forall(150, "non-multiple-of-width key bytes are Malformed", |g| {
        let kt = *g.choose(&KeyType::ALL);
        let width = kt.width_bytes();
        let n = g.usize_in(0..50);
        let mut bytes = vec![0u8; n * width];
        for b in bytes.iter_mut() {
            *b = (g.u32() & 0xFF) as u8;
        }
        assert!(key_data_from_bytes(kt, &bytes).is_ok());
        // Any ragged tail is rejected.
        let ragged = g.usize_in(1..width.max(2));
        if ragged % width != 0 {
            bytes.resize(bytes.len() + ragged, 0);
            assert!(matches!(
                key_data_from_bytes(kt, &bytes),
                Err(WireError::Malformed(_))
            ));
        }
    });
}

#[test]
fn message_decoders_reject_garbage_and_trailing_bytes() {
    forall(300, "typed message decoders fail closed", |g| {
        let n = g.usize_in(0..64);
        let soup: Vec<u8> = (0..n).map(|_| (g.u32() & 0xFF) as u8).collect();
        // None of these may panic; Ok is allowed only because a random
        // buffer can be a structurally valid message by chance — in that
        // case re-encoding must reproduce the buffer exactly.
        if let Ok(m) = SortBeginMsg::decode(&soup) {
            assert_eq!(m.encode(), soup);
        }
        if let Ok(m) = SortHeaderMsg::decode(&soup) {
            assert_eq!(m.encode(), soup);
        }
        if let Ok(m) = ErrorMsg::decode(&soup) {
            assert_eq!(m.encode(), soup);
        }
        if let Ok(m) = HelloMsg::decode(&soup) {
            assert_eq!(m.encode(), soup);
        }
        if let Ok(m) = HelloAckMsg::decode(&soup) {
            assert_eq!(m.encode(), soup);
        }
        if let Ok(m) = CreditMsg::decode(&soup) {
            assert_eq!(m.encode(), soup);
        }
        // Trailing bytes after a valid message are rejected (`done()`).
        let good = CreditMsg { credits: 5 }.encode();
        let mut padded = good.clone();
        padded.push(0);
        assert!(matches!(
            CreditMsg::decode(&padded),
            Err(WireError::Malformed(_))
        ));
    });
}

//! Network-tier integration: end-to-end byte identity over TCP,
//! fault injection (malformed frames, half-written frames, mid-stream
//! disconnects), graceful drain with in-flight work, and wire-level
//! backpressure against a saturated scheduler queue.
//!
//! Every test binds an ephemeral port (`127.0.0.1:0`) so suites can
//! run in parallel.

use gpu_bucket_sort::config::{BatchConfig, EngineKind, NetConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{JobData, SortEngine, SortRequest, SortService};
use gpu_bucket_sort::net::wire::{self, Frame, HelloMsg, Opcode, SortBeginMsg};
use gpu_bucket_sort::net::{NetClient, NetServer};
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{KeyData, KeyType};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

fn cfg() -> ServiceConfig {
    ServiceConfig {
        verify: true,
        batch: BatchConfig {
            max_batch_keys: 1 << 20,
            max_batch_requests: 8,
            max_wait_ms: 1,
            queue_capacity: 256,
            max_queued_keys: 1 << 26,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// An engine that holds every batch until the shared gate opens —
/// lets tests pin work "in flight" deterministically.
struct SlowEngine(Arc<(Mutex<bool>, Condvar)>);

impl SortEngine for SlowEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }
    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<gpu_bucket_sort::Result<JobData>> {
        let (lock, cv) = &*self.0;
        let mut go = lock.lock().unwrap();
        while !*go {
            go = cv.wait(go).unwrap();
        }
        drop(go);
        jobs.into_iter()
            .map(|mut j| {
                if let KeyData::U32(v) = &mut j.keys {
                    v.sort_unstable();
                }
                Ok(j)
            })
            .collect()
    }
}

fn release(gate: &(Mutex<bool>, Condvar)) {
    *gate.0.lock().unwrap() = true;
    gate.1.notify_all();
}

/// Raw-socket handshake: returns a stream past the `HelloAck`, ready
/// to speak arbitrary (possibly hostile) frames.
fn raw_handshake(addr: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).unwrap();
    let hello = HelloMsg { max_frame_len: 1 << 20, session: 0 };
    wire::write_frame(&mut s, &Frame::message(Opcode::Hello, 0, hello.encode())).unwrap();
    let ack = wire::read_frame(&mut s, 1 << 20).unwrap().unwrap();
    assert_eq!(ack.opcode, Opcode::HelloAck);
    s
}

/// The tentpole contract: N pipelined connections, mixed key types,
/// payloads and sort directions (including NaN f32 keys), 2 workers —
/// every TCP response is **byte-identical** to the same request served
/// through an in-process clone of the very same service handle.
#[test]
fn tcp_responses_byte_identical_to_in_process() {
    let service = SortService::start(ServiceConfig { workers: 2, ..cfg() }).unwrap();
    let local = service.clone();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let remote = NetClient::connect(&addr, 3, NetConfig::default()).unwrap();
    assert_eq!(remote.connections(), 3);

    let types = [KeyType::U32, KeyType::U64, KeyType::F32];
    let total = 18usize;
    let mk = |i: usize| -> SortRequest {
        let n = 2_000 + 1_117 * (i % 5);
        let dist = Distribution::ALL[i % Distribution::ALL.len()];
        let mut keys = dist.generate_data(types[i % types.len()], n, i as u64);
        if let KeyData::F32(v) = &mut keys {
            // NaN and infinities must survive the wire bit-exactly.
            v[0] = f32::NAN;
            v[1] = f32::NEG_INFINITY;
        }
        let mut b = SortRequest::builder(keys).descending(i % 3 == 0).tag(format!("req-{i}"));
        if i % 2 == 0 {
            b = b.payload((0..n as u64).collect::<Vec<u64>>());
        }
        b.build().unwrap()
    };

    // Pipelined: every remote request is in flight before the first
    // response is read (per-connection credits keep this bounded).
    let remote_rxs: Vec<_> = (0..total).map(|i| remote.submit(mk(i)).unwrap()).collect();
    let local_rxs: Vec<_> = (0..total).map(|i| local.submit(mk(i)).unwrap()).collect();
    for (i, (rrx, lrx)) in remote_rxs.into_iter().zip(local_rxs).enumerate() {
        let r = rrx.recv().unwrap().unwrap();
        let l = lrx.recv().unwrap().unwrap();
        // KeyData equality is NaN-poisoned; compare the wire bytes.
        assert_eq!(
            wire::key_data_to_bytes(&r.keys),
            wire::key_data_to_bytes(&l.keys),
            "request {i}: TCP keys diverged from in-process"
        );
        assert_eq!(r.payload, l.payload, "request {i}: payload diverged");
        assert_eq!(r.tag.as_deref(), Some(format!("req-{i}").as_str()));
        assert!(r.worker < 2);
        assert_eq!(r.engine, l.engine);
    }

    drop(remote);
    drop(local);
    let snap = server.shutdown();
    assert_eq!(snap.counters["net_requests"], total as u64);
    assert_eq!(snap.counters["net_responses"], total as u64);
    // Remote + local halves both completed in the one service.
    assert_eq!(snap.counters["requests_completed"], 2 * total as u64);
    assert!(!snap.counters.contains_key("net_malformed"));
    assert!(!snap.counters.contains_key("net_drain_timeout"));
}

/// Protocol torture: garbage bytes, a half-written frame followed by
/// socket close, and an oversized length prefix each kill only their
/// own connection — the listener keeps serving well-formed clients.
#[test]
fn malformed_frames_never_kill_the_listener() {
    let service = SortService::start(cfg()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // (1) Pure garbage instead of a handshake.
    {
        use std::io::Write as _;
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n\r\n this is not the protocol").unwrap();
        let _ = s.shutdown(std::net::Shutdown::Write);
    }
    // (2) Valid handshake, then a half-written frame and an abrupt close.
    {
        use std::io::Write as _;
        let mut s = raw_handshake(&addr);
        let begin = SortBeginMsg {
            key_type: KeyType::U32,
            descending: false,
            self_check: false,
            has_payload: false,
            total_keys: 64,
            tag: None,
        };
        let bytes = wire::encode_frame(&Frame::message(Opcode::SortBegin, 7, begin.encode()));
        s.write_all(&bytes[..bytes.len() / 2]).unwrap();
        drop(s); // socket closes mid-frame
    }
    // (3) A header whose length prefix claims u32::MAX bytes: the
    // decoder must refuse before allocating, and only this connection
    // dies.
    {
        use std::io::Write as _;
        let mut s = raw_handshake(&addr);
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&wire::MAGIC);
        hdr.push(wire::VERSION);
        hdr.push(0x07); // Ping
        hdr.extend_from_slice(&0u16.to_le_bytes()); // flags
        hdr.extend_from_slice(&9u64.to_le_bytes()); // id
        hdr.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd length
        hdr.extend_from_slice(&0u32.to_le_bytes()); // (checked after length)
        assert_eq!(hdr.len(), wire::HEADER_LEN);
        s.write_all(&hdr).unwrap();
        // The server answers with a typed malformed error, then closes.
        let reply = wire::read_frame(&mut s, 1 << 20).unwrap();
        if let Some(f) = reply {
            assert_eq!(f.opcode, Opcode::ErrorFrame);
        }
    }

    // After all three attacks a well-formed client is served normally.
    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    client.ping().unwrap();
    let keys = Distribution::Uniform.generate(5_000, 42);
    let out = client.sort(SortRequest::new(keys.clone())).unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, out.keys_u32()));

    drop(client);
    let snap = server.shutdown();
    assert!(snap.counters["net_malformed"] >= 2, "{:?}", snap.counters);
    assert_eq!(snap.counters["requests_completed"], 1);
    assert!(!snap.counters.contains_key("net_drain_timeout"));
}

/// A client that vanishes mid-stream (SortBegin + some chunks, no
/// Commit) leaves nothing behind: no service submission, no stuck
/// credit, and shutdown completes immediately (no 60 s drain stall).
#[test]
fn mid_stream_disconnect_leaks_nothing() {
    let service = SortService::start(cfg()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    {
        let mut s = raw_handshake(&addr);
        let begin = SortBeginMsg {
            key_type: KeyType::U32,
            descending: false,
            self_check: false,
            has_payload: false,
            total_keys: 1_000,
            tag: None,
        };
        wire::write_frame(&mut s, &Frame::message(Opcode::SortBegin, 1, begin.encode())).unwrap();
        // 25 of the declared 1000 keys, then gone.
        let chunk = Frame { opcode: Opcode::KeyChunk, flags: 0, id: 1, payload: vec![0xAB; 100] };
        wire::write_frame(&mut s, &chunk).unwrap();
        drop(s);
    }

    // An unrelated client is still served while the half-open request
    // is being abandoned.
    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    let keys = Distribution::Staggered.generate(8_000, 7);
    let out = client.sort(SortRequest::new(keys.clone())).unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, out.keys_u32()));
    drop(client);

    // The abandoned partial never reached the service, and it must not
    // count as in-flight: a leaked lease would stall this for 60 s and
    // leave a net_drain_timeout marker.
    let start = std::time::Instant::now();
    let snap = server.shutdown();
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "drain stalled on an abandoned partial request"
    );
    assert!(!snap.counters.contains_key("net_drain_timeout"));
    assert_eq!(snap.counters["net_requests"], 2); // partial + completed
    assert_eq!(snap.counters["requests_completed"], 1);
}

/// Graceful drain: a request already committed to the service finishes
/// and its response is flushed to the client, while submissions that
/// arrive after drain starts are shed with a typed shutdown error.
#[test]
fn drain_completes_in_flight_and_sheds_new_work() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let service = SortService::start_with_engine(
        ServiceConfig { verify: false, ..cfg() },
        SlowEngine(gate.clone()),
    )
    .unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    let late_client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();

    // Committed and dispatched into the gated engine: in flight.
    let keys = Distribution::Uniform.generate(4_000, 3);
    let rx = client.submit(SortRequest::new(keys.clone())).unwrap();
    std::thread::sleep(Duration::from_millis(200));

    let drainer = std::thread::spawn(move || server.shutdown());
    std::thread::sleep(Duration::from_millis(200));

    // New work on a surviving connection is shed, not hung.
    let err = late_client.sort(SortRequest::new(vec![3u32, 1, 2])).unwrap_err();
    assert!(err.to_string().contains("draining"), "{err}");

    // Open the gate: the in-flight sort completes and reaches us even
    // though the drain is already under way.
    release(&gate);
    let out = rx.recv().unwrap().unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, out.keys_u32()));

    let snap = drainer.join().unwrap();
    assert_eq!(snap.counters["requests_completed"], 1);
    assert_eq!(snap.counters["net_responses"], 1);
    assert!(snap.counters["net_shed_shutdown"] >= 1);
    assert!(!snap.counters.contains_key("net_drain_timeout"));
    drop(client);
    drop(late_client);
}

/// Backpressure over the wire: a saturated scheduler queue surfaces as
/// typed `Busy` errors (never a hang), every one of three connections
/// keeps working afterwards, and a fresh client can reconnect and sort
/// after the shed.
#[test]
fn backpressure_sheds_busy_and_recovers_fairly() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    // The coordinator's own saturation shape: 1-request batches, no
    // batching delay, a 2-deep dispatch queue.
    let service = SortService::start_with_engine(
        ServiceConfig {
            verify: false,
            batch: BatchConfig {
                max_batch_keys: 10,
                max_batch_requests: 1,
                max_wait_ms: 0,
                queue_capacity: 2,
                max_queued_keys: 1 << 20,
                ..Default::default()
            },
            ..Default::default()
        },
        SlowEngine(gate.clone()),
    )
    .unwrap();
    // Generous credits so the wire never throttles before the queue.
    let net = NetConfig { credits: 64, ..NetConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", service, net).unwrap();
    let addr = server.local_addr().to_string();

    let clients: Vec<NetClient> = (0..3)
        .map(|_| NetClient::connect(&addr, 1, net).unwrap())
        .collect();

    // Interleave 8 pipelined submissions per connection against the
    // gated engine; the 2-deep queue must shed most of them.
    let mut rxs = Vec::new();
    for round in 0..8u64 {
        for c in &clients {
            rxs.push(c.submit(SortRequest::new(vec![2u32, 1, round as u32])).unwrap());
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    release(&gate);

    let mut completed = 0u32;
    let mut busy = 0u32;
    for rx in rxs {
        match rx.recv().unwrap() {
            Ok(out) => {
                assert!(gpu_bucket_sort::is_sorted(out.keys_u32()));
                completed += 1;
            }
            Err(e) => {
                assert!(e.is_busy(), "non-busy failure under saturation: {e}");
                assert!(e.to_string().contains("backpressure"), "{e}");
                busy += 1;
            }
        }
    }
    assert!(completed >= 1, "nothing completed");
    assert!(busy >= 1, "queue never shed: {completed} completed");

    // Fairness: after the storm, every connection still serves
    // sequential requests — no wedged readers, no lost credits.
    for (i, c) in clients.iter().enumerate() {
        let keys = vec![9u32, 4, 6, 1, i as u32];
        let out = c.sort(SortRequest::new(keys.clone())).unwrap();
        assert!(
            gpu_bucket_sort::is_sorted_permutation(&keys, out.keys_u32()),
            "connection {i} wedged after shed"
        );
    }

    // Reconnect-after-shed: a brand-new client gets a fresh credit
    // window and full service.
    let fresh = NetClient::connect(&addr, 1, net).unwrap();
    fresh.ping().unwrap();
    let out = fresh.sort(SortRequest::new(vec![5u32, 2, 8])).unwrap();
    assert_eq!(out.keys_u32(), &[2, 5, 8]);

    drop(clients);
    drop(fresh);
    let snap = server.shutdown();
    assert!(snap.counters["net_shed_busy"] >= 1, "{:?}", snap.counters);
    assert!(snap.counters["requests_rejected"] >= 1);
    assert!(
        snap.counters.get("scheduler_queue_depth_peak").copied().unwrap_or(0) >= 1,
        "queue depth metric never moved"
    );
    assert!(!snap.counters.contains_key("net_drain_timeout"));
}

/// Regression for the reader/pump `unwrap` removal: garbage arriving
/// **mid-stream on an established connection** (after a served ping)
/// takes the typed malformed path — the connection dies alone and the
/// same listener keeps serving — and a pooled client that outlives the
/// server gets typed `connection closed` refusals, never a panicked
/// reader thread or a poisoned lock.
#[test]
fn garbage_mid_stream_then_clean_listener_reuse() {
    let service = SortService::start(cfg()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // An established, previously well-behaved connection goes rogue.
    {
        use std::io::{Read as _, Write as _};
        let mut s = raw_handshake(&addr);
        wire::write_frame(&mut s, &Frame::control(Opcode::Ping, 11)).unwrap();
        let pong = wire::read_frame(&mut s, 1 << 20).unwrap().unwrap();
        assert_eq!(pong.opcode, Opcode::Pong);
        assert_eq!(pong.id, 11);
        // Now garbage where the next frame header should start.
        s.write_all(b"\x00\x00\x00\x00 not a frame header at all").unwrap();
        // The server answers with a typed error frame and/or closes —
        // either way this socket reaches EOF instead of hanging.
        let mut rest = Vec::new();
        let _ = s.read_to_end(&mut rest);
    }

    // Same listener, fresh connection: full service.
    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    client.ping().unwrap();
    let keys = Distribution::Uniform.generate(6_000, 11);
    let out = client.sort(SortRequest::new(keys.clone())).unwrap();
    assert!(gpu_bucket_sort::is_sorted_permutation(&keys, out.keys_u32()));

    // The server goes away while the client lives on: its reader
    // thread exits through the shutdown path and every later call is a
    // typed refusal (a panicking reader would poison the conn locks
    // and turn this into a test abort instead).
    let snap = server.shutdown();
    assert!(snap.counters["net_malformed"] >= 1, "{:?}", snap.counters);
    assert_eq!(snap.counters["requests_completed"], 1);
    let err = client.sort(SortRequest::new(vec![4u32, 2])).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("closed") || msg.contains("connection"),
        "expected a typed connection error, got: {msg}"
    );
}

/// The CLI drain path: `Drain` frames are acknowledged, latch the
/// server-side signal that `gbs serve --listen` blocks on, and the
/// subsequent shutdown drains cleanly.
#[test]
fn drain_frame_latches_the_drain_signal() {
    let service = SortService::start(cfg()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    client.ping().unwrap();
    assert!(!server.drain_requested());
    client.drain_server().unwrap();
    assert!(server.drain_requested());
    assert!(server.wait_for_drain_request(Some(Duration::from_secs(1))));
    drop(client);

    let snap = server.shutdown();
    assert_eq!(snap.counters["net_pings"], 1);
    assert_eq!(snap.counters["net_connections"], 1);
}

//! Chaos integration: a seeded fault plan drives failures through the
//! whole stack — client socket cuts, corrupted frames, device loss —
//! and every one must recover *end to end* with byte-identical
//! results.
//!
//! The recovery chain under test:
//!
//! * client-side `socket_cut` / `frame_corrupt` → reader death →
//!   capped-backoff reconnect → idempotent resubmission under the
//!   original wire id;
//! * server-side dedup window → a resubmitted, already-completed
//!   request replays the cached response instead of re-executing;
//! * device `device_lost` mid-step → sharded failover re-plans over
//!   the surviving devices, still byte-identical;
//! * a client without reconnect gets the typed
//!   [`Error::ConnectionLost`] naming every in-flight request id.
//!
//! Every test binds an ephemeral port so suites run in parallel, and
//! every fault is attempt-counted (never wall-clock), so the schedule
//! replays exactly.
//!
//! The cluster-tier tests go one level up: real `gbs` *processes* (a
//! registry plus three nodes) with one node killed mid-load — via the
//! deterministic `node_down` probe and via a hard SIGKILL — asserting
//! zero failed client requests and byte-identical outputs, plus
//! registry lease-expiry and deregister-before-drain ordering.

use gpu_bucket_sort::config::{EngineKind, NetConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortRequest, SortService};
use gpu_bucket_sort::Error;
use gpu_bucket_sort::net::registry::{node_list, LeaseState, Registry, RegistryConfig};
use gpu_bucket_sort::net::wire::{
    self, Frame, HelloAckMsg, HelloMsg, Opcode, RegisterAckMsg, RegisterMsg, SortBeginMsg,
};
use gpu_bucket_sort::net::{
    ClientOptions, ClusterClient, ClusterOptions, NetClient, NetServer, NodeRegistration,
};
use gpu_bucket_sort::{KeyData, KeyType};
use std::io::{BufRead, BufReader};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

/// Write a fault plan to a unique temp file; returns its path.
fn write_plan(name: &str, json: &str) -> String {
    let dir = std::env::temp_dir().join(format!("gbs_chaos_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(format!("{name}.json"));
    std::fs::write(&p, json).unwrap();
    p.display().to_string()
}

/// Deterministic pseudo-random u32 keys (xorshift-mixed index).
fn keys(n: usize, seed: u64) -> Vec<u32> {
    (0..n as u64)
        .map(|i| {
            let mut x = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed;
            x ^= x >> 33;
            x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            (x >> 32) as u32
        })
        .collect()
}

fn service_cfg(fault_plan: String) -> ServiceConfig {
    ServiceConfig {
        fault_plan,
        verify: true,
        ..Default::default()
    }
}

/// A socket severed mid-submission must be invisible to the caller:
/// the client reconnects with backoff, resubmits under the original
/// wire id, and every response stays byte-identical.
#[test]
fn socket_cut_reconnects_and_stays_byte_identical() {
    let plan = write_plan(
        "socket_cut",
        r#"{"version":1,"seed":7,"rules":[
            {"point":"socket_cut","target":0,"after":1,"count":1}
        ]}"#,
    );
    let service = SortService::start(service_cfg(plan)).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let client = NetClient::connect_with(
        &addr,
        1,
        NetConfig::default(),
        ClientOptions {
            reconnect: true,
            faults: service.fault_injector(),
        },
    )
    .unwrap();
    assert!(service.fault_injector().is_some(), "plan must arm the injector");

    for r in 0..6 {
        let data = keys(2_000, 100 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = client.sort(SortRequest::new(data)).unwrap();
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
    assert!(client.reconnects() >= 1, "the cut must force a reconnect");
    assert!(client.resubmits() >= 1, "the in-flight request must resubmit");
    drop(client);

    let snap = server.shutdown();
    assert!(
        snap.counters.get("fault_injected_socket_cut").copied().unwrap_or(0) >= 1,
        "client-side injections must surface in the service totals: {:?}",
        snap.counters
    );
}

/// A corrupted frame is rejected by the server's CRC check (connection
/// closed with a typed error) — same recovery chain, same bytes.
#[test]
fn frame_corruption_recovers_via_reconnect() {
    let plan = write_plan(
        "frame_corrupt",
        r#"{"version":1,"seed":11,"rules":[
            {"point":"frame_corrupt","target":0,"count":1}
        ]}"#,
    );
    let service = SortService::start(service_cfg(plan)).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let client = NetClient::connect_with(
        &addr,
        1,
        NetConfig::default(),
        ClientOptions {
            reconnect: true,
            faults: service.fault_injector(),
        },
    )
    .unwrap();

    for r in 0..4 {
        let data = keys(1_500, 300 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = client.sort(SortRequest::new(data)).unwrap();
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
    assert!(client.reconnects() >= 1);
    assert!(client.resubmits() >= 1);
    drop(client);

    let snap = server.shutdown();
    assert!(snap.counters.get("fault_injected_frame_corrupt").copied().unwrap_or(0) >= 1);
    // The server must have counted (and survived) the bad frame.
    assert!(snap.counters.get("net_malformed").copied().unwrap_or(0) >= 1);
}

/// A device lost mid-step on the sharded engine fails over to the
/// survivors — over TCP, the response is still byte-identical.
#[test]
fn device_loss_failover_stays_byte_identical_over_tcp() {
    let plan = write_plan(
        "device_lost_tcp",
        r#"{"version":1,"seed":3,"rules":[
            {"point":"device_lost","target":1,"count":1}
        ]}"#,
    );
    let cfg = ServiceConfig {
        engine: EngineKind::Sharded,
        ..service_cfg(plan)
    };
    let service = SortService::start(cfg).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();

    for r in 0..3 {
        let data = keys(4_096, 40 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = client.sort(SortRequest::new(data)).unwrap();
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
    drop(client);

    let snap = server.shutdown();
    assert!(
        snap.counters.get("failover_events").copied().unwrap_or(0) >= 1,
        "device loss must surface as a failover: {:?}",
        snap.counters
    );
    assert_eq!(snap.counters.get("fault_injected_device_lost").copied(), Some(1));
}

/// Raw-protocol dedup check: resubmitting an already-completed request
/// id within the same session replays the cached response — the server
/// counts a `net_dedup_replays` and the bytes match the original
/// exactly (no re-execution needed for idempotency, but the window
/// spares one).
#[test]
fn dedup_window_replays_completed_requests_byte_identically() {
    let service = SortService::start(ServiceConfig::default()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let hello = HelloMsg {
        max_frame_len: 1 << 20,
        session: 0xC0FFEE, // nonzero: arms the dedup window
    };
    wire::write_frame(&mut w, &Frame::message(Opcode::Hello, 0, hello.encode())).unwrap();
    let ack_frame = wire::read_frame(&mut r, 1 << 20).unwrap().unwrap();
    assert_eq!(ack_frame.opcode, Opcode::HelloAck);
    HelloAckMsg::decode(&ack_frame.payload).unwrap();

    let data = keys(1_000, 9);
    let key_bytes = wire::key_data_to_bytes(&KeyData::U32(data.clone()));
    let submit = |w: &mut TcpStream| {
        let begin = SortBeginMsg {
            key_type: KeyType::U32,
            descending: false,
            self_check: false,
            has_payload: false,
            total_keys: data.len() as u64,
            tag: None,
        };
        wire::write_frame(w, &Frame::message(Opcode::SortBegin, 7, begin.encode())).unwrap();
        for f in wire::chunk_frames(Opcode::KeyChunk, 7, &key_bytes, 4096) {
            wire::write_frame(w, &f).unwrap();
        }
        wire::write_frame(w, &Frame::control(Opcode::Commit, 7)).unwrap();
    };
    // Read one full response (skipping Credit frames): returns the
    // concatenated result-key bytes.
    let read_response = |r: &mut BufReader<TcpStream>| -> Vec<u8> {
        let mut out = Vec::new();
        loop {
            let f = wire::read_frame(r, 1 << 20).unwrap().unwrap();
            match f.opcode {
                Opcode::ResultKeyChunk => out.extend_from_slice(&f.payload),
                Opcode::ResultEnd => return out,
                Opcode::SortHeader | Opcode::Credit => {}
                other => panic!("unexpected frame {other:?} in response"),
            }
        }
    };

    submit(&mut w);
    let first = read_response(&mut r);
    // Same id, same session, already completed: the dedup window must
    // replay, not re-execute.
    submit(&mut w);
    let second = read_response(&mut r);
    assert_eq!(first, second, "replayed response must be byte-identical");

    let mut expected = data;
    expected.sort_unstable();
    let sorted = wire::key_data_from_bytes(KeyType::U32, &first).unwrap();
    assert_eq!(sorted.as_u32().unwrap(), &expected[..]);

    let net = server.net_metrics();
    assert_eq!(net.counters.get("net_dedup_replays").copied(), Some(1));
    let _ = server.shutdown();
}

/// Without reconnect, a dead connection surfaces as the typed
/// [`Error::ConnectionLost`] naming the in-flight request ids — not a
/// stringly "connection closed".
#[test]
fn connection_lost_carries_in_flight_request_ids() {
    // A miniature "server" that handshakes, swallows one submission,
    // and hangs up without responding.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accept = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let hello = wire::read_frame(&mut r, 1 << 20).unwrap().unwrap();
        assert_eq!(hello.opcode, Opcode::Hello);
        let ack = HelloAckMsg {
            credits: 4,
            max_frame_len: 1 << 20,
            max_request_keys: 1 << 20,
        };
        wire::write_frame(&mut w, &Frame::message(Opcode::HelloAck, 0, ack.encode())).unwrap();
        // Consume the full submission, then drop the connection.
        loop {
            let f = wire::read_frame(&mut r, 1 << 20).unwrap().unwrap();
            if f.opcode == Opcode::Commit {
                break;
            }
        }
    });

    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    let rx = client.submit(SortRequest::new(keys(512, 1))).unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    match err {
        Error::ConnectionLost { ref request_ids } => {
            assert_eq!(request_ids, &[1], "the lost id list must name the request");
        }
        other => panic!("expected ConnectionLost, got {other:?}"),
    }
    assert!(err.to_string().contains("connection lost"));
    accept.join().unwrap();
}

// ---------------------------------------------------------------------------
// Cluster tier: registry + multi-node failover
// ---------------------------------------------------------------------------

/// A spawned `gbs` child whose stdout pipe is kept open (dropping it
/// would EPIPE the child's progress prints).
struct Proc {
    child: Child,
    _out: BufReader<ChildStdout>,
}

impl Proc {
    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn the real `gbs` binary and scrape the machine-readable address
/// line (`GBS_NET_ADDR` / `GBS_REGISTRY_ADDR`) from its stdout.
fn spawn_gbs(args: &[&str], scrape_prefix: &str) -> (Proc, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_gbs"))
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn gbs");
    let mut out = BufReader::new(child.stdout.take().expect("child stdout piped"));
    let mut line = String::new();
    loop {
        line.clear();
        if out.read_line(&mut line).expect("read child stdout") == 0 {
            panic!("gbs {args:?} exited before announcing {scrape_prefix}");
        }
        if let Some(rest) = line.strip_prefix(scrape_prefix) {
            return (Proc { child, _out: out }, rest.trim().to_string());
        }
    }
}

/// Poll the registry until it lists exactly `want` routable nodes.
fn wait_for_nodes(reg_addr: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let n = node_list(reg_addr).map(|v| v.len()).unwrap_or(0);
        if n == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "registry never listed {want} node(s) (currently {n})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn cluster_opts() -> ClusterOptions {
    ClusterOptions {
        connections_per_node: 1,
        max_failovers: 4,
        // Refresh only on failover: keeps the routing table
        // deterministic for the kill choreography below.
        refresh_every: 0,
        faults: None,
    }
}

/// Sort `rounds` requests through the cluster, asserting every single
/// one succeeds byte-identically (zero failed client requests).
fn sort_rounds(cluster: &ClusterClient, rounds: u64, n: usize, seed0: u64) {
    for r in 0..rounds {
        let data = keys(n, seed0 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = cluster
            .sort(SortRequest::new(data))
            .unwrap_or_else(|e| panic!("cluster request {r} failed: {e}"));
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
}

/// Kill 1 of 3 real node processes mid-load via the deterministic
/// `node_down` probe (the node exits abruptly at request admission —
/// no drain, no deregister). The cluster client must fail the
/// in-flight request over to a survivor: zero failed requests,
/// byte-identical output throughout.
#[test]
fn cluster_survives_node_down_probe_kill() {
    let (registry, reg_addr) = spawn_gbs(
        &["registry", "--listen", "127.0.0.1:0", "--heartbeat-ms", "25"],
        "GBS_REGISTRY_ADDR ",
    );
    // The victim dies on its *first* admitted request (`node_down`,
    // after 0, count 1 — attempt-counted, so the schedule replays).
    let plan = write_plan(
        "cluster_node_down",
        r#"{"version":1,"seed":5,"rules":[
            {"point":"node_down","target":0,"count":1}
        ]}"#,
    );
    let (victim, _victim_addr) = spawn_gbs(
        &[
            "serve", "--listen", "127.0.0.1:0", "--registry", &reg_addr,
            "--workers", "1", "--fault-plan", &plan,
        ],
        "GBS_NET_ADDR ",
    );
    wait_for_nodes(&reg_addr, 1);

    // Resolve while only the victim is registered: request 1 *must*
    // route to it. The survivors register before the first sort, so
    // the failover's refresh finds them.
    let cluster = ClusterClient::connect(&reg_addr, NetConfig::default(), cluster_opts())
        .expect("cluster connect");
    let (node_b, _) = spawn_gbs(
        &["serve", "--listen", "127.0.0.1:0", "--registry", &reg_addr, "--workers", "1"],
        "GBS_NET_ADDR ",
    );
    let (node_c, _) = spawn_gbs(
        &["serve", "--listen", "127.0.0.1:0", "--registry", &reg_addr, "--workers", "1"],
        "GBS_NET_ADDR ",
    );
    wait_for_nodes(&reg_addr, 3);

    sort_rounds(&cluster, 6, 2_000, 700);
    assert!(
        cluster.failovers() >= 1,
        "killing the routed node must force a failover"
    );

    // The probe's abrupt exit is the dedicated node-death code.
    let mut victim = victim;
    let status = victim.child.wait().expect("victim exits");
    assert_eq!(status.code(), Some(113), "node_down exits with code 113");

    // The dead node's lease expires; the registry stops listing it.
    wait_for_nodes(&reg_addr, 2);

    node_b.kill();
    node_c.kill();
    registry.kill();
}

/// The hard-kill variant: SIGKILL the node the cluster is routing to,
/// mid-load. No probe, no exit handler — the process just vanishes.
/// Same contract: zero failed requests, byte-identical output.
#[test]
fn cluster_survives_sigkill_of_routed_node() {
    let (registry, reg_addr) = spawn_gbs(
        &["registry", "--listen", "127.0.0.1:0", "--heartbeat-ms", "25"],
        "GBS_REGISTRY_ADDR ",
    );
    let mut nodes: Vec<(Proc, String)> = (0..3)
        .map(|_| {
            spawn_gbs(
                &["serve", "--listen", "127.0.0.1:0", "--registry", &reg_addr, "--workers", "1"],
                "GBS_NET_ADDR ",
            )
        })
        .collect();
    wait_for_nodes(&reg_addr, 3);

    let cluster = ClusterClient::connect(&reg_addr, NetConfig::default(), cluster_opts())
        .expect("cluster connect");
    // Warm-up load: with equal advertised loads the router sticks to
    // the first node in address order — which tells us whom to kill.
    sort_rounds(&cluster, 2, 2_000, 800);
    let routed = cluster.nodes().first().cloned().expect("a routed node");
    let pos = nodes
        .iter()
        .position(|(_, addr)| *addr == routed)
        .expect("routed node is one of ours");
    let (victim, _) = nodes.swap_remove(pos);
    victim.kill(); // SIGKILL — no drain, no deregister, no goodbye

    sort_rounds(&cluster, 4, 2_000, 900);
    assert!(
        cluster.failovers() >= 1,
        "requests to the SIGKILLed node must fail over"
    );

    for (node, _) in nodes {
        node.kill();
    }
    registry.kill();
}

/// Registry lease expiry over the raw wire: a node that registers and
/// then goes silent turns suspect (withheld from `NodeList`) after
/// `suspect_misses` beats and is evicted after `evict_misses`.
#[test]
fn registry_lease_expiry_suspects_then_evicts_silent_node() {
    let cfg = RegistryConfig {
        heartbeat_ms: 30,
        suspect_misses: 2,
        evict_misses: 4,
    };
    let reg = Registry::bind("127.0.0.1:0", cfg).unwrap();
    let addr = reg.local_addr().to_string();

    // Register once, then never heartbeat.
    let mut s = TcpStream::connect(&addr).unwrap();
    let msg = RegisterMsg {
        addr: "10.9.9.9:4750".into(),
    };
    wire::write_frame(&mut s, &Frame::message(Opcode::Register, 1, msg.encode())).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let ack = wire::read_frame(&mut r, 1 << 16).unwrap().unwrap();
    assert_eq!(ack.opcode, Opcode::RegisterAck);
    let ack = RegisterAckMsg::decode(&ack.payload).unwrap();
    assert_eq!(ack.heartbeat_ms, 30, "ack must echo the registry's pace");
    assert_eq!(ack.lease_ms, 120, "lease = heartbeat_ms × evict_misses");

    assert_eq!(node_list(&addr).unwrap().len(), 1, "fresh lease is routable");

    std::thread::sleep(Duration::from_millis(cfg.heartbeat_ms * (cfg.suspect_misses + 1)));
    assert!(
        node_list(&addr).unwrap().is_empty(),
        "suspect node must be withheld from routing"
    );
    let snap = reg.snapshot();
    assert_eq!(snap.len(), 1, "suspect is withheld, not yet forgotten");
    assert_eq!(snap[0].state, LeaseState::Suspect);

    std::thread::sleep(Duration::from_millis(
        cfg.heartbeat_ms * (cfg.evict_misses - cfg.suspect_misses + 1),
    ));
    assert!(reg.snapshot().is_empty(), "expired lease must be evicted");
    let metrics = reg.shutdown();
    assert!(metrics.counters.get("registry_evictions").copied().unwrap_or(0) >= 1);
}

/// Deregister-before-drain ordering: the registry removes the node (and
/// acks) *before* the node starts shedding — after the ack the node is
/// unroutable via the registry, yet still completes direct traffic
/// until its own drain begins.
#[test]
fn deregister_before_drain_stops_routing_while_node_still_serves() {
    let reg = Registry::bind("127.0.0.1:0", RegistryConfig::default()).unwrap();
    let reg_addr = reg.local_addr().to_string();
    let service = SortService::start(ServiceConfig::default()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let node_addr = server.local_addr().to_string();
    let registration = NodeRegistration::start(
        &reg_addr,
        &node_addr,
        server.load_probe(),
        Duration::from_secs(5),
    )
    .unwrap();
    wait_for_nodes(&reg_addr, 1);

    // Shutdown step one: deregister. The ack means the registry
    // already dropped the node — no NodeList reply can route here.
    assert!(registration.deregister(), "registry must ack the deregister");
    assert!(
        node_list(&reg_addr).unwrap().is_empty(),
        "deregistered node must be unroutable immediately, not lease-later"
    );

    // Ordering proof: the node has NOT drained yet — direct traffic
    // still completes after deregistration.
    let client = NetClient::connect(&node_addr, 1, NetConfig::default()).unwrap();
    let data = keys(2_048, 5);
    let mut expected = data.clone();
    expected.sort_unstable();
    let resp = client.sort(SortRequest::new(data)).unwrap();
    assert_eq!(resp.keys_u32(), &expected[..]);
    drop(client);

    // Only now does the node shed.
    let _ = server.shutdown();
    let snap = reg.shutdown();
    assert_eq!(snap.counters.get("registry_deregisters").copied(), Some(1));
}

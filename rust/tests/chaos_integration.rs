//! Chaos integration: a seeded fault plan drives failures through the
//! whole stack — client socket cuts, corrupted frames, device loss —
//! and every one must recover *end to end* with byte-identical
//! results.
//!
//! The recovery chain under test:
//!
//! * client-side `socket_cut` / `frame_corrupt` → reader death →
//!   capped-backoff reconnect → idempotent resubmission under the
//!   original wire id;
//! * server-side dedup window → a resubmitted, already-completed
//!   request replays the cached response instead of re-executing;
//! * device `device_lost` mid-step → sharded failover re-plans over
//!   the surviving devices, still byte-identical;
//! * a client without reconnect gets the typed
//!   [`Error::ConnectionLost`] naming every in-flight request id.
//!
//! Every test binds an ephemeral port so suites run in parallel, and
//! every fault is attempt-counted (never wall-clock), so the schedule
//! replays exactly.

use gpu_bucket_sort::config::{EngineKind, NetConfig, ServiceConfig};
use gpu_bucket_sort::coordinator::{SortRequest, SortService};
use gpu_bucket_sort::Error;
use gpu_bucket_sort::net::wire::{self, Frame, HelloAckMsg, HelloMsg, Opcode, SortBeginMsg};
use gpu_bucket_sort::net::{ClientOptions, NetClient, NetServer};
use gpu_bucket_sort::{KeyData, KeyType};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};

/// Write a fault plan to a unique temp file; returns its path.
fn write_plan(name: &str, json: &str) -> String {
    let dir = std::env::temp_dir().join(format!("gbs_chaos_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(format!("{name}.json"));
    std::fs::write(&p, json).unwrap();
    p.display().to_string()
}

/// Deterministic pseudo-random u32 keys (xorshift-mixed index).
fn keys(n: usize, seed: u64) -> Vec<u32> {
    (0..n as u64)
        .map(|i| {
            let mut x = i.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seed;
            x ^= x >> 33;
            x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            (x >> 32) as u32
        })
        .collect()
}

fn service_cfg(fault_plan: String) -> ServiceConfig {
    ServiceConfig {
        fault_plan,
        verify: true,
        ..Default::default()
    }
}

/// A socket severed mid-submission must be invisible to the caller:
/// the client reconnects with backoff, resubmits under the original
/// wire id, and every response stays byte-identical.
#[test]
fn socket_cut_reconnects_and_stays_byte_identical() {
    let plan = write_plan(
        "socket_cut",
        r#"{"version":1,"seed":7,"rules":[
            {"point":"socket_cut","target":0,"after":1,"count":1}
        ]}"#,
    );
    let service = SortService::start(service_cfg(plan)).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let client = NetClient::connect_with(
        &addr,
        1,
        NetConfig::default(),
        ClientOptions {
            reconnect: true,
            faults: service.fault_injector(),
        },
    )
    .unwrap();
    assert!(service.fault_injector().is_some(), "plan must arm the injector");

    for r in 0..6 {
        let data = keys(2_000, 100 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = client.sort(SortRequest::new(data)).unwrap();
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
    assert!(client.reconnects() >= 1, "the cut must force a reconnect");
    assert!(client.resubmits() >= 1, "the in-flight request must resubmit");
    drop(client);

    let snap = server.shutdown();
    assert!(
        snap.counters.get("fault_injected_socket_cut").copied().unwrap_or(0) >= 1,
        "client-side injections must surface in the service totals: {:?}",
        snap.counters
    );
}

/// A corrupted frame is rejected by the server's CRC check (connection
/// closed with a typed error) — same recovery chain, same bytes.
#[test]
fn frame_corruption_recovers_via_reconnect() {
    let plan = write_plan(
        "frame_corrupt",
        r#"{"version":1,"seed":11,"rules":[
            {"point":"frame_corrupt","target":0,"count":1}
        ]}"#,
    );
    let service = SortService::start(service_cfg(plan)).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let client = NetClient::connect_with(
        &addr,
        1,
        NetConfig::default(),
        ClientOptions {
            reconnect: true,
            faults: service.fault_injector(),
        },
    )
    .unwrap();

    for r in 0..4 {
        let data = keys(1_500, 300 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = client.sort(SortRequest::new(data)).unwrap();
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
    assert!(client.reconnects() >= 1);
    assert!(client.resubmits() >= 1);
    drop(client);

    let snap = server.shutdown();
    assert!(snap.counters.get("fault_injected_frame_corrupt").copied().unwrap_or(0) >= 1);
    // The server must have counted (and survived) the bad frame.
    assert!(snap.counters.get("net_malformed").copied().unwrap_or(0) >= 1);
}

/// A device lost mid-step on the sharded engine fails over to the
/// survivors — over TCP, the response is still byte-identical.
#[test]
fn device_loss_failover_stays_byte_identical_over_tcp() {
    let plan = write_plan(
        "device_lost_tcp",
        r#"{"version":1,"seed":3,"rules":[
            {"point":"device_lost","target":1,"count":1}
        ]}"#,
    );
    let cfg = ServiceConfig {
        engine: EngineKind::Sharded,
        ..service_cfg(plan)
    };
    let service = SortService::start(cfg).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service.clone(), NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();
    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();

    for r in 0..3 {
        let data = keys(4_096, 40 + r);
        let mut expected = data.clone();
        expected.sort_unstable();
        let resp = client.sort(SortRequest::new(data)).unwrap();
        assert_eq!(resp.keys_u32(), &expected[..], "request {r} diverged");
    }
    drop(client);

    let snap = server.shutdown();
    assert!(
        snap.counters.get("failover_events").copied().unwrap_or(0) >= 1,
        "device loss must surface as a failover: {:?}",
        snap.counters
    );
    assert_eq!(snap.counters.get("fault_injected_device_lost").copied(), Some(1));
}

/// Raw-protocol dedup check: resubmitting an already-completed request
/// id within the same session replays the cached response — the server
/// counts a `net_dedup_replays` and the bytes match the original
/// exactly (no re-execution needed for idempotency, but the window
/// spares one).
#[test]
fn dedup_window_replays_completed_requests_byte_identically() {
    let service = SortService::start(ServiceConfig::default()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", service, NetConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    let stream = TcpStream::connect(&addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let hello = HelloMsg {
        max_frame_len: 1 << 20,
        session: 0xC0FFEE, // nonzero: arms the dedup window
    };
    wire::write_frame(&mut w, &Frame::message(Opcode::Hello, 0, hello.encode())).unwrap();
    let ack_frame = wire::read_frame(&mut r, 1 << 20).unwrap().unwrap();
    assert_eq!(ack_frame.opcode, Opcode::HelloAck);
    HelloAckMsg::decode(&ack_frame.payload).unwrap();

    let data = keys(1_000, 9);
    let key_bytes = wire::key_data_to_bytes(&KeyData::U32(data.clone()));
    let submit = |w: &mut TcpStream| {
        let begin = SortBeginMsg {
            key_type: KeyType::U32,
            descending: false,
            self_check: false,
            has_payload: false,
            total_keys: data.len() as u64,
            tag: None,
        };
        wire::write_frame(w, &Frame::message(Opcode::SortBegin, 7, begin.encode())).unwrap();
        for f in wire::chunk_frames(Opcode::KeyChunk, 7, &key_bytes, 4096) {
            wire::write_frame(w, &f).unwrap();
        }
        wire::write_frame(w, &Frame::control(Opcode::Commit, 7)).unwrap();
    };
    // Read one full response (skipping Credit frames): returns the
    // concatenated result-key bytes.
    let read_response = |r: &mut BufReader<TcpStream>| -> Vec<u8> {
        let mut out = Vec::new();
        loop {
            let f = wire::read_frame(r, 1 << 20).unwrap().unwrap();
            match f.opcode {
                Opcode::ResultKeyChunk => out.extend_from_slice(&f.payload),
                Opcode::ResultEnd => return out,
                Opcode::SortHeader | Opcode::Credit => {}
                other => panic!("unexpected frame {other:?} in response"),
            }
        }
    };

    submit(&mut w);
    let first = read_response(&mut r);
    // Same id, same session, already completed: the dedup window must
    // replay, not re-execute.
    submit(&mut w);
    let second = read_response(&mut r);
    assert_eq!(first, second, "replayed response must be byte-identical");

    let mut expected = data;
    expected.sort_unstable();
    let sorted = wire::key_data_from_bytes(KeyType::U32, &first).unwrap();
    assert_eq!(sorted.as_u32().unwrap(), &expected[..]);

    let net = server.net_metrics();
    assert_eq!(net.counters.get("net_dedup_replays").copied(), Some(1));
    let _ = server.shutdown();
}

/// Without reconnect, a dead connection surfaces as the typed
/// [`Error::ConnectionLost`] naming the in-flight request ids — not a
/// stringly "connection closed".
#[test]
fn connection_lost_carries_in_flight_request_ids() {
    // A miniature "server" that handshakes, swallows one submission,
    // and hangs up without responding.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accept = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let hello = wire::read_frame(&mut r, 1 << 20).unwrap().unwrap();
        assert_eq!(hello.opcode, Opcode::Hello);
        let ack = HelloAckMsg {
            credits: 4,
            max_frame_len: 1 << 20,
            max_request_keys: 1 << 20,
        };
        wire::write_frame(&mut w, &Frame::message(Opcode::HelloAck, 0, ack.encode())).unwrap();
        // Consume the full submission, then drop the connection.
        loop {
            let f = wire::read_frame(&mut r, 1 << 20).unwrap().unwrap();
            if f.opcode == Opcode::Commit {
                break;
            }
        }
    });

    let client = NetClient::connect(&addr, 1, NetConfig::default()).unwrap();
    let rx = client.submit(SortRequest::new(keys(512, 1))).unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    match err {
        Error::ConnectionLost { ref request_ids } => {
            assert_eq!(request_ids, &[1], "the lost id list must name the request");
        }
        other => panic!("expected ConnectionLost, got {other:?}"),
    }
    assert!(err.to_string().contains("connection lost"));
    accept.join().unwrap();
}

//! Property tests for the [`SortKey`] laws — the foundation the typed
//! sort surface stands on:
//!
//! * `to_bits`/`from_bits` is a bit-exact round trip (including `f32`
//!   NaN payloads, `-0.0`, infinities, and negative `i32`/`i64`);
//! * the bijection is order-preserving: comparing bits agrees with the
//!   type's semantic order wherever one exists (integers everywhere,
//!   floats outside NaN);
//! * sorting by bits through the real engines therefore sorts the keys,
//!   for every key type and every engine.
//!
//! NB: `f32` has inherent `to_bits`/`from_bits` (raw IEEE bits) that
//! shadow the trait methods on the concrete type — the helpers below
//! are generic, which sidesteps the ambiguity.

use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
use gpu_bucket_sort::exec::{NativeEngine, NativeParams};
use gpu_bucket_sort::sim::{GpuModel, GpuSim};
use gpu_bucket_sort::util::propcheck::forall;
use gpu_bucket_sort::util::Rng;
use gpu_bucket_sort::workload::Distribution;
use gpu_bucket_sort::{is_sorted_permutation, Record, SortKey};

fn roundtrip<K: SortKey>(k: K) -> K {
    K::from_bits(K::to_bits(k))
}

/// Bit-exact equality (f32 NaN-safe: compares raw IEEE bytes).
fn bit_eq<K: SortKey>(a: K, b: K) -> bool {
    K::to_bits(a) == K::to_bits(b)
}

#[test]
fn bits_round_trip_for_every_type() {
    forall(300, "SortKey round trip", |g| {
        let raw = g.rng().next_u64();
        fn check<K: SortKey>(raw: u64) {
            let k = K::from_raw_bits(raw);
            assert!(bit_eq(roundtrip(k), k), "{k:?} did not round-trip");
            // from_raw_bits truncates to the key width, so the
            // key ↦ bits ↦ key ↦ bits chain is stable too.
            let b = K::to_bits(k);
            assert_eq!(K::to_bits(K::from_bits(b)), b);
        }
        check::<u32>(raw);
        check::<u64>(raw);
        check::<i32>(raw);
        check::<i64>(raw);
        check::<f32>(raw);
        check::<Record<u32>>(raw);
        check::<Record<i64>>(raw);
    });
}

#[test]
fn special_values_round_trip_bit_exactly() {
    // The adversarial corners the laws call out by name.
    let f32_specials = [
        f32::NAN,
        -f32::NAN,
        f32::from_bits(0x7FC0_0001), // NaN with payload
        f32::from_bits(0xFFFF_FFFF), // negative NaN, all-ones payload
        f32::INFINITY,
        f32::NEG_INFINITY,
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        f32::MAX,
        f32::MIN,
    ];
    for &x in &f32_specials {
        assert_eq!(
            f32::to_bits(roundtrip(x)),
            f32::to_bits(x),
            "f32 {x:?} lost bits"
        );
    }
    // -0.0 and +0.0 are distinct keys, ordered -0.0 < +0.0.
    assert!((-0.0f32).key_lt(&0.0f32));
    for &x in &[i64::MIN, i64::MIN + 1, -1, 0, 1, i64::MAX] {
        assert_eq!(roundtrip(x), x, "i64 {x} lost bits");
    }
    for &x in &[i32::MIN, -1, 0, i32::MAX] {
        assert_eq!(roundtrip(x), x, "i32 {x} lost bits");
    }
}

#[test]
fn bit_order_agrees_with_semantic_order() {
    forall(500, "order preservation", |g| {
        // Integers: bits order == integer order, everywhere.
        let (a, b) = (g.rng().next_u64() as i64, g.rng().next_u64() as i64);
        assert_eq!(a.cmp(&b), a.key_cmp(&b), "i64 {a} vs {b}");
        let (a, b) = (g.u32() as i32, g.u32() as i32);
        assert_eq!(a.cmp(&b), a.key_cmp(&b), "i32 {a} vs {b}");
        let (a, b) = (g.rng().next_u64(), g.rng().next_u64());
        assert_eq!(a.cmp(&b), a.key_cmp(&b));

        // f32: outside NaN, bits order == partial_cmp (with the single
        // refinement -0.0 < +0.0, excluded below by bit inequality).
        let (x, y) = (
            f32::from_raw_bits(g.rng().next_u64()),
            f32::from_raw_bits(g.rng().next_u64()),
        );
        if !x.is_nan() && !y.is_nan() && f32::to_bits(x) != f32::to_bits(y) && x != y {
            assert_eq!(
                x.partial_cmp(&y).unwrap(),
                x.key_cmp(&y),
                "f32 {x} vs {y}"
            );
        }
        // NaNs always sort after every non-NaN of the same sign side's
        // top: positive NaN is the global maximum region.
        if x.is_nan() && f32::to_bits(x) & 0x8000_0000 == 0 && !y.is_nan() {
            assert!(y.key_lt(&x), "positive NaN must sort last ({y})");
        }

        // Records: key order first, index breaks ties.
        let k = g.u32();
        let r1 = Record { key: k, idx: 1 };
        let r2 = Record { key: k, idx: 2 };
        assert!(r1.key_lt(&r2));
    });
}

#[test]
fn pad_is_the_maximum_for_every_type() {
    fn check<K: SortKey>(samples: usize) {
        let mut rng = Rng::new(42);
        for _ in 0..samples {
            let k = K::from_raw_bits(rng.next_u64());
            assert!(
                k.key_le(&K::PAD),
                "{k:?} sorts after PAD {:?}",
                K::PAD
            );
        }
    }
    check::<u32>(2000);
    check::<u64>(2000);
    check::<i32>(2000);
    check::<i64>(2000);
    check::<f32>(2000);
    check::<Record<f32>>(2000);
}

#[test]
fn every_engine_sorts_every_key_type() {
    // BucketSort (sim) and the native engine over small random typed
    // inputs, all distributions' bit-space mapping included.
    let sorter = BucketSort::new(BucketSortParams { tile: 256, s: 16 });
    let native = NativeEngine::new(NativeParams {
        workers: 4,
        sequential_cutoff: 1 << 10,
        ..Default::default()
    })
    .unwrap();
    fn run_case<K: SortKey>(sorter: &BucketSort, native: &NativeEngine, input: Vec<K>) {
        let mut a = input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort(&mut a, &mut sim).unwrap();
        assert!(is_sorted_permutation(&input, &a));
        let mut b = input.clone();
        native.sort(&mut b);
        assert!(is_sorted_permutation(&input, &b));
        // Both engines agree bit-for-bit (the unique sorted ordering).
        assert!(a.iter().zip(&b).all(|(x, y)| x.key_cmp(y).is_eq()));
    }
    for dist in [
        Distribution::Uniform,
        Distribution::Zipf,
        Distribution::NearlySorted,
    ] {
        run_case::<u32>(&sorter, &native, dist.generate_typed(5_000, 3));
        run_case::<u64>(&sorter, &native, dist.generate_typed(5_000, 3));
        run_case::<i32>(&sorter, &native, dist.generate_typed(5_000, 3));
        run_case::<i64>(&sorter, &native, dist.generate_typed(5_000, 3));
        run_case::<f32>(&sorter, &native, dist.generate_typed(5_000, 3));
    }
}

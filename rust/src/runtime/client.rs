//! PJRT client wrapper: load AOT-compiled HLO-text artifacts and execute
//! them from the rust request path.
//!
//! Pattern (see /opt/xla-example/load_hlo and DESIGN.md): HLO **text** is
//! the interchange format — jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids. Flow per artifact:
//!
//! ```text
//! HloModuleProto::from_text_file → XlaComputation::from_proto
//!     → PjRtClient::compile → PjRtLoadedExecutable::execute
//! ```
//!
//! Executables are compiled once and cached; execution marshals `u32`
//! keys through untyped-byte literals (the xla crate's `NativeType`
//! convenience constructors don't cover u32, the element type itself
//! does).

use super::manifest::{ArtifactEntry, Manifest};
use crate::error::{Error, Result};
use crate::{Key, SortKey};
use std::collections::HashMap;
use std::path::PathBuf;

/// The fixed-shape pipeline's padding sentinel — the key type's own
/// [`SortKey::PAD`] (`u32::MAX` for the classic artifacts); see the
/// trait docs for why fixed-shape execution must reserve it.
const PAD: Key = <Key as SortKey>::PAD;

/// A PJRT CPU runtime holding compiled executables for the artifact set.
///
/// Not `Send`/`Sync` by design — the coordinator owns it from a single
/// engine thread.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime")
            .field("dir", &self.dir)
            .field("entries", &self.manifest.entries.len())
            .field("compiled", &self.cache.len())
            .finish()
    }
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
        Ok(PjrtRuntime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string of the PJRT client (e.g. "cpu"). Useful for
    /// diagnostics.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `entry`.
    fn executable(&mut self, entry: &ArtifactEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&entry.name) {
            let path = self.manifest.path_of(&self.dir, entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", entry.name)))?;
            self.cache.insert(entry.name.clone(), exe);
        }
        Ok(&self.cache[&entry.name])
    }

    /// Eagerly compile every full-sort artifact (service warm-up).
    pub fn warm_up(&mut self) -> Result<usize> {
        let entries: Vec<ArtifactEntry> = self
            .manifest
            .entries
            .iter()
            .filter(|e| e.kind == super::manifest::ArtifactKind::FullSort)
            .cloned()
            .collect();
        for e in &entries {
            self.executable(e)?;
        }
        Ok(entries.len())
    }

    /// Sort `keys` with the AOT pipeline: pick the smallest compiled
    /// capacity ≥ n, pad with the key type's [`SortKey::PAD`] sentinel,
    /// execute, unpad.
    ///
    /// Returns the sorted keys and the capacity used. Fails if the input
    /// contains the sentinel (the fixed-shape pipeline cannot represent
    /// it) or exceeds every compiled capacity.
    pub fn sort(&mut self, keys: &[Key]) -> Result<(Vec<Key>, usize)> {
        if keys.contains(&PAD) {
            return Err(Error::InvalidInput(
                "the key type's SortKey::PAD sentinel (u32::MAX) is reserved by the \
                 fixed-shape AOT pipeline"
                    .into(),
            ));
        }
        let entry = self
            .manifest
            .best_sort_entry(keys.len())
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no compiled artifact fits n={} (max capacity {})",
                    keys.len(),
                    self.manifest.max_sort_capacity()
                ))
            })?
            .clone();
        let n = keys.len();
        let cap = entry.n;

        let mut padded: Vec<Key> = Vec::with_capacity(cap);
        padded.extend_from_slice(keys);
        padded.resize(cap, PAD);

        let input = literal_from_u32(&padded)?;
        let exe = self.executable(&entry)?;
        let result = exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| Error::Runtime(format!("execute {}: {e}", entry.name)))?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime("executable returned no outputs".into()))?
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = out
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("unwrap tuple: {e}")))?;
        let mut sorted = out
            .to_vec::<u32>()
            .map_err(|e| Error::Runtime(format!("read result: {e}")))?;
        if sorted.len() != cap {
            return Err(Error::Runtime(format!(
                "artifact {} returned {} keys, expected {cap}",
                entry.name,
                sorted.len()
            )));
        }
        sorted.truncate(n);
        Ok((sorted, cap))
    }
}

/// Build a rank-1 U32 literal from a key slice.
fn literal_from_u32(data: &[Key]) -> Result<xla::Literal> {
    // SAFETY: the pointer and length come from a live `&[u32]`, so the
    // region is valid, initialized and borrowed for this scope;
    // `size_of_val` gives its exact byte length, and any alignment
    // satisfies `u8`'s. The view is read-only and never outlives
    // `data`.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U32, &[data.len()], bytes)
        .map_err(|e| Error::Runtime(format!("build literal: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u32> = vec![5, 1, 4, 1, 5, 9, 2, 6];
        let lit = literal_from_u32(&data).unwrap();
        assert_eq!(lit.element_count(), 8);
        assert_eq!(lit.to_vec::<u32>().unwrap(), data);
    }

    #[test]
    fn missing_artifacts_dir_is_manifest_error() {
        let err = PjrtRuntime::new("/nonexistent/artifacts").unwrap_err();
        assert!(matches!(err, Error::Manifest(_)), "{err}");
    }
}

//! The AOT artifact manifest.
//!
//! `make artifacts` (python/compile/aot.py) lowers the L2 JAX pipeline —
//! which embeds the L1 Pallas kernels — to HLO text, one file per
//! (variant, shape) configuration, and writes `manifest.json` describing
//! them. XLA executables are shape-static, so the runtime picks the
//! smallest compiled size that fits a request and pads with the
//! `u32::MAX` sentinel.

use crate::error::{Error, Result};
use crate::util::Json;
use std::path::{Path, PathBuf};

/// What a compiled artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// The full Algorithm-1 pipeline: u32[n] → sorted u32[n].
    FullSort,
    /// Steps 1–3 only: u32[n] → (tiles sorted, local samples) — used by
    /// the hybrid coordinator path.
    TileSort,
    /// Steps 6–8 only: (sorted tiles, splitters) → relocated buckets.
    RankPrefix,
}

impl ArtifactKind {
    /// Stable manifest name.
    pub fn id(&self) -> &'static str {
        match self {
            ArtifactKind::FullSort => "full_sort",
            ArtifactKind::TileSort => "tile_sort",
            ArtifactKind::RankPrefix => "rank_prefix",
        }
    }

    /// Parse a manifest name.
    pub fn parse(s: &str) -> Option<ArtifactKind> {
        match s {
            "full_sort" => Some(ArtifactKind::FullSort),
            "tile_sort" => Some(ArtifactKind::TileSort),
            "rank_prefix" => Some(ArtifactKind::RankPrefix),
            _ => None,
        }
    }
}

/// One compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Unique name, e.g. `sort_16384`.
    pub name: String,
    /// Variant.
    pub kind: ArtifactKind,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Key count the executable was compiled for.
    pub n: usize,
    /// Tile size baked into the pipeline.
    pub tile: usize,
    /// Sample count baked into the pipeline.
    pub s: usize,
}

/// The artifact set produced by one `make artifacts` run.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Schema version.
    pub version: u32,
    /// Key dtype (always `"u32"` for this library).
    pub key_dtype: String,
    /// All compiled artifacts.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "{} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let m = Self::from_json(&text)?;
        m.validate(dir.as_ref())?;
        Ok(m)
    }

    /// Parse manifest JSON.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let version = v
            .req("version")?
            .as_u64()
            .ok_or_else(|| Error::Manifest("version must be an integer".into()))?
            as u32;
        let key_dtype = v
            .req("key_dtype")?
            .as_str()
            .ok_or_else(|| Error::Manifest("key_dtype must be a string".into()))?
            .to_string();
        let entries_json = v
            .req("entries")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("entries must be an array".into()))?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let field_str = |k: &str| -> Result<String> {
                e.req(k)?
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Manifest(format!("entry field {k} must be a string")))
            };
            let field_usize = |k: &str| -> Result<usize> {
                e.req(k)?
                    .as_usize()
                    .ok_or_else(|| Error::Manifest(format!("entry field {k} must be an integer")))
            };
            let kind_s = field_str("kind")?;
            entries.push(ArtifactEntry {
                name: field_str("name")?,
                kind: ArtifactKind::parse(&kind_s)
                    .ok_or_else(|| Error::Manifest(format!("unknown artifact kind {kind_s:?}")))?,
                file: field_str("file")?,
                n: field_usize("n")?,
                tile: field_usize("tile")?,
                s: field_usize("s")?,
            });
        }
        Ok(Manifest {
            version,
            key_dtype,
            entries,
        })
    }

    /// Serialize to JSON (mirrors what aot.py writes).
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("version", Json::num(self.version as f64)),
            ("key_dtype", Json::str(self.key_dtype.clone())),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("name", Json::str(e.name.clone())),
                                ("kind", Json::str(e.kind.id())),
                                ("file", Json::str(e.file.clone())),
                                ("n", Json::num(e.n as f64)),
                                ("tile", Json::num(e.tile as f64)),
                                ("s", Json::num(e.s as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string_pretty()
    }

    /// Check schema invariants and that every referenced file exists.
    pub fn validate(&self, dir: &Path) -> Result<()> {
        if self.version != 1 {
            return Err(Error::Manifest(format!(
                "unsupported manifest version {}",
                self.version
            )));
        }
        if self.key_dtype != "u32" {
            return Err(Error::Manifest(format!(
                "unsupported key dtype {:?}",
                self.key_dtype
            )));
        }
        for e in &self.entries {
            if e.n == 0 || !e.tile.is_power_of_two() || e.s == 0 || e.n % e.tile != 0 {
                return Err(Error::Manifest(format!(
                    "entry {:?} has invalid shape",
                    e.name
                )));
            }
            let p = dir.join(&e.file);
            if !p.is_file() {
                return Err(Error::Manifest(format!(
                    "artifact file missing: {}",
                    p.display()
                )));
            }
        }
        Ok(())
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, dir: &Path, entry: &ArtifactEntry) -> PathBuf {
        dir.join(&entry.file)
    }

    /// The smallest [`ArtifactKind::FullSort`] entry with capacity ≥ `n`.
    pub fn best_sort_entry(&self, n: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::FullSort && e.n >= n)
            .min_by_key(|e| e.n)
    }

    /// Largest full-sort capacity available.
    pub fn max_sort_capacity(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::FullSort)
            .map(|e| e.n)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Manifest {
        Manifest {
            version: 1,
            key_dtype: "u32".into(),
            entries: vec![
                ArtifactEntry {
                    name: "sort_4096".into(),
                    kind: ArtifactKind::FullSort,
                    file: "sort_4096.hlo.txt".into(),
                    n: 4096,
                    tile: 256,
                    s: 16,
                },
                ArtifactEntry {
                    name: "sort_16384".into(),
                    kind: ArtifactKind::FullSort,
                    file: "sort_16384.hlo.txt".into(),
                    n: 16384,
                    tile: 256,
                    s: 16,
                },
                ArtifactEntry {
                    name: "tile_4096".into(),
                    kind: ArtifactKind::TileSort,
                    file: "tile_4096.hlo.txt".into(),
                    n: 4096,
                    tile: 256,
                    s: 16,
                },
            ],
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gbs_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn best_entry_selection() {
        let m = sample_manifest();
        assert_eq!(m.best_sort_entry(100).unwrap().n, 4096);
        assert_eq!(m.best_sort_entry(4096).unwrap().n, 4096);
        assert_eq!(m.best_sort_entry(4097).unwrap().n, 16384);
        assert!(m.best_sort_entry(1 << 20).is_none());
        assert_eq!(m.max_sort_capacity(), 16384);
    }

    #[test]
    fn json_roundtrip() {
        let m = sample_manifest();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn load_and_validate() {
        let dir = temp_dir("load");
        let m = sample_manifest();
        std::fs::write(dir.join("manifest.json"), m.to_json()).unwrap();
        // Files missing → validation error.
        assert!(Manifest::load(&dir).is_err());
        for e in &m.entries {
            std::fs::write(dir.join(&e.file), "HloModule x").unwrap();
        }
        let loaded = Manifest::load(&dir).unwrap();
        assert_eq!(loaded, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_schema() {
        let dir = temp_dir("bad");
        let mut m = sample_manifest();
        m.version = 9;
        std::fs::write(dir.join("manifest.json"), m.to_json()).unwrap();
        assert!(Manifest::load(&dir).is_err());

        let mut m2 = sample_manifest();
        m2.entries[0].tile = 100; // not a power of two
        for e in &m2.entries {
            std::fs::write(dir.join(&e.file), "x").unwrap();
        }
        std::fs::write(dir.join("manifest.json"), m2.to_json()).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(Manifest::from_json("{").is_err());
        assert!(Manifest::from_json(r#"{"version":1}"#).is_err());
        assert!(Manifest::from_json(
            r#"{"version":1,"key_dtype":"u32","entries":[{"name":"x","kind":"bogus","file":"f","n":1,"tile":1,"s":1}]}"#
        )
        .is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let dir = temp_dir("missing");
        std::fs::remove_dir_all(&dir).ok();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn kind_roundtrip() {
        for k in [
            ArtifactKind::FullSort,
            ArtifactKind::TileSort,
            ArtifactKind::RankPrefix,
        ] {
            assert_eq!(ArtifactKind::parse(k.id()), Some(k));
        }
        assert_eq!(ArtifactKind::parse("nope"), None);
    }
}

//! The PJRT runtime: loading and executing the AOT-compiled JAX/Pallas
//! artifacts from rust, with python never on the request path.
//!
//! * [`manifest`] — the `artifacts/manifest.json` schema and lookup.
//! * [`client`] — PJRT CPU client, executable cache, u32 marshalling.

#[cfg(feature = "xla")]
pub mod client;
#[cfg(not(feature = "xla"))]
#[path = "client_stub.rs"]
pub mod client;
pub mod manifest;

pub use client::PjrtRuntime;
pub use manifest::{ArtifactEntry, ArtifactKind, Manifest};

//! Stub PJRT client — compiled when the `xla` feature is **off** (the
//! default, matching the offline build environment, which carries no
//! vendored `xla` bindings crate).
//!
//! The stub keeps the full public surface of the real client
//! (`client.rs`) so every caller — the coordinator's PJRT engine, the
//! CLI's `artifacts` command, the examples — compiles unchanged. It
//! still loads and validates the artifact manifest (so manifest errors
//! are reported exactly as the real runtime would), then fails
//! construction with a [`Error::Runtime`] explaining how to enable real
//! execution. Callers already treat PJRT construction failure as "skip
//! / fall back" (see `rust/tests/pjrt_roundtrip.rs`), so behaviour
//! degrades gracefully.

use super::manifest::Manifest;
use crate::error::{Error, Result};
use crate::Key;
use std::path::PathBuf;

/// Stub runtime: holds the validated manifest but cannot execute.
///
/// [`PjrtRuntime::new`] always returns an error after manifest
/// validation, so instances of this type are never observed by callers;
/// the inherent methods exist to keep the API surface identical to the
/// `xla`-featured build.
#[derive(Debug)]
pub struct PjrtRuntime {
    dir: PathBuf,
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Load the manifest from `dir`, then fail: this build carries no
    /// PJRT bindings. Missing/invalid artifact directories still report
    /// [`Error::Manifest`], as with the real client.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let _ = PjrtRuntime { dir, manifest };
        Err(Error::Runtime(
            "built without the `xla` feature: PJRT execution is unavailable \
             (vendor the xla bindings crate and rebuild with `--features xla`)"
                .into(),
        ))
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Platform string; the stub reports "unavailable".
    pub fn platform(&self) -> String {
        let _ = &self.dir;
        "unavailable".to_string()
    }

    /// Warm-up is unavailable without the `xla` feature.
    pub fn warm_up(&mut self) -> Result<usize> {
        Err(Error::Runtime(
            "built without the `xla` feature: cannot compile artifacts".into(),
        ))
    }

    /// Sorting through artifacts is unavailable without the `xla`
    /// feature.
    pub fn sort(&mut self, _keys: &[Key]) -> Result<(Vec<Key>, usize)> {
        Err(Error::Runtime(
            "built without the `xla` feature: cannot execute artifacts".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifacts_dir_is_manifest_error() {
        let err = PjrtRuntime::new("/nonexistent/artifacts").unwrap_err();
        assert!(matches!(err, Error::Manifest(_)), "{err}");
    }

    #[test]
    fn present_manifest_reports_missing_feature() {
        let dir = std::env::temp_dir().join(format!("gbs_stub_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"key_dtype":"u32","entries":[]}"#,
        )
        .unwrap();
        let err = PjrtRuntime::new(&dir).unwrap_err();
        assert!(matches!(err, Error::Runtime(_)), "{err}");
        assert!(err.to_string().contains("xla"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The crate's **sync facade** — the one import path for the
//! primitives the concurrency core is built on.
//!
//! Normally everything re-exports `std::sync`; under `--cfg loom` the
//! same names resolve to the in-tree model checker's mirrored types
//! ([`crate::util::loom`]), so the worker pool, scratch arena, bounded
//! scheduler queue and net credit window can be compiled into
//! exhaustive interleaving models (`rust/tests/loom_models.rs`)
//! without any source changes. The `xtask lint` job enforces that
//! facade-covered modules never import `std::sync::{Mutex, Condvar}`
//! or `std::sync::atomic` directly.
//!
//! The facade also centralizes the repo's poison policy: a panicking
//! task must not cascade into `PoisonError` unwraps on unrelated
//! threads (the pool re-raises the original panic instead), so lock
//! and wait sites go through [`lock_unpoisoned`] /
//! [`wait_unpoisoned`] / [`wait_timeout_unpoisoned`] rather than
//! `.lock().unwrap()`.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use crate::util::loom::{
    Arc, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard,
};

/// Memory orderings are shared: the model accepts and ignores them
/// (it is sequentially consistent), std honours them.
pub use std::sync::atomic::Ordering;

use std::time::Duration;

/// Lock a mutex, recovering the guard from a poisoned lock. Poisoning
/// only happens after another thread panicked while holding the guard;
/// every structure behind the facade keeps its invariants across
/// panics (counters are adjusted before work runs, queues hold owned
/// values), so continuing with the inner guard is sound and keeps one
/// task's panic from cascading into unrelated threads.
pub fn lock_unpoisoned<T: ?Sized>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    match mutex.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Condvar wait with the same poison policy as [`lock_unpoisoned`].
pub fn wait_unpoisoned<'a, T: ?Sized>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Timed condvar wait; returns the reacquired guard and whether the
/// wait timed out. Under `--cfg loom` this degrades to an untimed wait
/// (the model has no clock), so timed paths must not be the only thing
/// preventing a modeled deadlock.
#[cfg(not(loom))]
pub fn wait_timeout_unpoisoned<'a, T: ?Sized>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, timeout) {
        Ok((guard, result)) => (guard, result.timed_out()),
        Err(poisoned) => {
            let (guard, result) = poisoned.into_inner();
            (guard, result.timed_out())
        }
    }
}

/// Model-side timed wait: no clock, so it never reports a timeout.
#[cfg(loom)]
pub fn wait_timeout_unpoisoned<'a, T: ?Sized>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, bool) {
    let _ = timeout;
    (wait_unpoisoned(cv, guard), false)
}

/// Thread spawning for facade-covered modules: real named OS threads
/// normally, model threads under `--cfg loom` (where thread identity
/// feeds the scheduler and names are dropped).
#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::JoinHandle;

    /// Spawn a named thread; panics only if the OS refuses to spawn
    /// (same behaviour the pool has always had).
    pub fn spawn_named<F, T>(name: String, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new()
            .name(name)
            .spawn(f)
            .expect("spawn thread")
    }
}

/// Model-side thread spawning (see the non-loom twin above).
#[cfg(loom)]
pub mod thread {
    pub use crate::util::loom::thread::JoinHandle;

    pub fn spawn_named<F, T>(name: String, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let _ = name;
        crate::util::loom::thread::spawn(f)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn unpoisoned_lock_recovers_after_panic() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().expect("first lock");
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*lock_unpoisoned(&m), 7);
    }

    #[test]
    fn timed_wait_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let mut guard = lock_unpoisoned(&m);
        // Spurious wakeups report `timed_out == false`; loop until the
        // timeout genuinely fires (nobody ever notifies).
        loop {
            let (reacquired, timed_out) =
                wait_timeout_unpoisoned(&cv, guard, Duration::from_millis(1));
            if timed_out {
                break;
            }
            guard = reacquired;
        }
    }

    #[test]
    fn spawn_named_runs_and_joins() {
        let h = thread::spawn_named("gbs-sync-test".into(), || 5usize);
        assert_eq!(h.join().expect("named thread"), 5);
    }
}

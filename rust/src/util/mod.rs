//! In-tree substrates. The build environment is offline, so everything a
//! comparable project would pull from crates.io is implemented here:
//!
//! * [`rng`] — xoshiro256++ PRNG + normal/zipf samplers (⇒ rand).
//! * [`json`] — full JSON parse/serialize (⇒ serde_json).
//! * [`pool`] — structured std-thread parallelism (⇒ rayon).
//! * [`bench`] — warmup/sampling benchmark harness (⇒ criterion).
//! * [`propcheck`] — seeded property-test driver (⇒ proptest).

pub mod bench;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

//! In-tree substrates. The build environment is offline, so everything a
//! comparable project would pull from crates.io is implemented here:
//!
//! * [`rng`] — xoshiro256++ PRNG + normal/zipf samplers (⇒ rand).
//! * [`json`] — full JSON parse/serialize (⇒ serde_json).
//! * [`pool`] — a **resident worker pool** with structured, borrow-
//!   friendly dispatch (⇒ rayon). Threads are spawned once and parked
//!   on a condvar; steady-state dispatch costs a queue push + signal,
//!   not a thread spawn.
//! * [`arena`] — recyclable scratch buffers keyed by element type, so
//!   the executed sort pipeline allocates nothing after warm-up.
//! * [`backoff`] — attempt-counted exponential retry pacing; the only
//!   sanctioned `thread::sleep` retry site (xtask lint R6).
//! * [`bench`] — warmup/sampling benchmark harness (⇒ criterion).
//! * [`propcheck`] — seeded property-test driver (⇒ proptest).
//! * [`loom`] — deterministic interleaving model checker (⇒ loom).
//! * [`sync`] — the sync facade the concurrency core imports from:
//!   `std::sync` normally, the [`loom`] mirror under `--cfg loom`.

pub mod arena;
pub mod backoff;
pub mod bench;
pub mod json;
pub mod loom;
pub mod pool;
pub mod propcheck;
pub mod rng;
pub mod sync;

pub use arena::{ArenaStats, ScratchArena, ScratchBuf};
pub use json::Json;
pub use rng::Rng;

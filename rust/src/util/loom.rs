//! A miniature, in-tree **model checker** for the crate's sync core —
//! the offline stand-in for the `loom` crate (this build has no
//! crates.io access, so the exploration engine lives here).
//!
//! [`model`] runs a closure repeatedly, exploring every distinct
//! scheduling of the model threads it spawns (bounded by a preemption
//! budget). The closure builds its concurrent scenario out of the
//! mirrored primitives in this module — [`Mutex`], [`Condvar`], the
//! atomics, and [`thread::spawn`] — which all route through a
//! deterministic token scheduler instead of the OS:
//!
//! * Exactly **one** model thread runs at a time. Every sync operation
//!   is a *choice point* where the scheduler may hand the token to any
//!   runnable thread; DFS over those choices enumerates interleavings.
//! * Memory is sequentially consistent (a sound over-approximation for
//!   the repo, whose hot-path atomics are `SeqCst`/`Relaxed` counters
//!   guarded by the dispatch protocol itself).
//! * If no thread can run and some are still blocked, the iteration
//!   **deadlocks** and `model` panics with the blocked set — this is
//!   how lost wakeups surface.
//!
//! Exploration is bounded two ways: `GBS_LOOM_MAX_PREEMPTIONS`
//! (default 2) caps involuntary context switches per execution, the
//! standard state-space reduction from CHESS-style checkers, and
//! `GBS_LOOM_MAX_ITER` (default 50 000) caps total executions —
//! exceeding it panics rather than silently truncating coverage.
//!
//! The crate's production code reaches these types through the
//! [`crate::util::sync`] facade under `--cfg loom`; the models
//! themselves live in `rust/tests/loom_models.rs`. Two rules keep the
//! checker sound: create every modeled object *inside* the closure
//! (object identity is per-execution), and keep the closure
//! deterministic apart from scheduling (no time, no OS randomness).

use std::any::Any;
use std::cell::{RefCell, UnsafeCell};
use std::collections::{HashMap, VecDeque};
use std::ops::{Deref, DerefMut};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::Ordering as StdOrdering;
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex, OnceLock};

/// `Arc` needs no modeling (its refcounts never order user memory the
/// models care about under SeqCst); re-exported so facade users can
/// import everything from one place.
pub use std::sync::Arc;
/// Orderings are accepted and ignored — the model is SeqCst-only.
pub use std::sync::atomic::Ordering;

const DEFAULT_MAX_ITER: usize = 50_000;
const DEFAULT_MAX_PREEMPTIONS: usize = 2;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Panic payload used to unwind model threads when an execution is
/// aborted (deadlock or a user panic elsewhere). Swallowed by the
/// per-thread catch handler; never escapes to the test.
struct AbortExec;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ThreadSt {
    Runnable,
    Blocked,
    Finished,
}

/// One decision point with more than one runnable candidate. The DFS
/// path is the sequence of these; single-candidate points are not
/// recorded (they replay deterministically).
struct Branch {
    choices: Vec<usize>,
    index: usize,
}

struct SchedState {
    threads: Vec<ThreadSt>,
    active: Option<usize>,
    live: usize,
    path: Vec<Branch>,
    /// Decision index within `path` for the current execution.
    depth: usize,
    preemptions: usize,
    abort: bool,
    deadlock: Option<String>,
    panic: Option<Box<dyn Any + Send>>,
    mutexes: HashMap<usize, MutexSt>,
    cv_waiters: HashMap<usize, VecDeque<usize>>,
    join_waiters: HashMap<usize, Vec<usize>>,
    next_obj_id: usize,
    os_handles: Vec<std::thread::JoinHandle<()>>,
}

#[derive(Default)]
struct MutexSt {
    held: bool,
    waiters: VecDeque<usize>,
}

struct Sched {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    max_preemptions: usize,
}

thread_local! {
    static CURRENT: RefCell<Option<(StdArc<Sched>, usize)>> = const { RefCell::new(None) };
}

fn current() -> Option<(StdArc<Sched>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

impl Sched {
    /// Poison-tolerant state access — the checker must keep working
    /// while model threads unwind (their guard drops re-enter here).
    fn st(&self) -> std::sync::MutexGuard<'_, SchedState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Pick the next thread to run. `prev` is the thread giving up the
    /// token (None when it just finished). Called with the state lock
    /// held; must not panic while holding it.
    fn reschedule(&self, st: &mut SchedState, prev: Option<usize>) {
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| **t == ThreadSt::Runnable)
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            if st.live == 0 {
                st.active = None;
            } else if !st.abort {
                let blocked: Vec<usize> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| **t == ThreadSt::Blocked)
                    .map(|(i, _)| i)
                    .collect();
                st.deadlock = Some(format!(
                    "loom model: deadlock — no runnable thread, blocked threads {blocked:?} \
                     (a lost wakeup or missing notify)"
                ));
                st.abort = true;
            }
            self.cv.notify_all();
            return;
        }
        let prev_runnable = prev.is_some_and(|p| st.threads[p] == ThreadSt::Runnable);
        let mut choices = runnable;
        if prev_runnable {
            // Explore "keep running" first; preempting costs budget.
            let p = prev.expect("prev_runnable implies prev");
            choices.retain(|&t| t != p);
            choices.insert(0, p);
            if st.preemptions >= self.max_preemptions {
                choices.truncate(1);
            }
        }
        let next = if choices.len() == 1 {
            choices[0]
        } else if st.depth < st.path.len() {
            let b = &st.path[st.depth];
            if b.choices != choices {
                // The closure behaved differently on replay — give a
                // diagnosable failure instead of exploring garbage.
                st.abort = true;
                st.deadlock = Some(format!(
                    "loom model: nondeterministic closure — replay expected choices \
                     {:?} at decision {}, got {choices:?}",
                    b.choices, st.depth
                ));
                self.cv.notify_all();
                return;
            }
            let n = b.choices[b.index];
            st.depth += 1;
            n
        } else {
            let n = choices[0];
            st.path.push(Branch { choices, index: 0 });
            st.depth += 1;
            n
        };
        if prev_runnable && Some(next) != prev {
            st.preemptions += 1;
        }
        st.active = Some(next);
        self.cv.notify_all();
    }

    /// Park the calling OS thread until the scheduler hands `me` the
    /// token. On abort, unwinds via [`AbortExec`] — unless this thread
    /// is already panicking (a guard drop mid-unwind), where a second
    /// panic would abort the process; then it simply returns and the
    /// unwind continues under the (discarded) aborted execution.
    fn wait_for_turn(&self, me: usize) {
        let mut st = self.st();
        loop {
            if st.abort {
                drop(st);
                if std::thread::panicking() {
                    return;
                }
                panic::panic_any(AbortExec);
            }
            if st.active == Some(me) {
                return;
            }
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// A voluntary choice point: offer the token to any runnable
    /// thread (including `me`), then wait to be scheduled again.
    fn explore_point(&self, me: usize) {
        {
            let mut st = self.st();
            if st.abort {
                return;
            }
            self.reschedule(&mut st, Some(me));
        }
        self.wait_for_turn(me);
    }

    /// Block `me` after registering it in a waiter queue, atomically
    /// with respect to the scheduler. Returns once `me` is runnable
    /// again *and* holds the token.
    fn block_on<F: FnOnce(&mut SchedState)>(&self, me: usize, register: F) {
        {
            let mut st = self.st();
            if st.abort {
                drop(st);
                if std::thread::panicking() {
                    return;
                }
                panic::panic_any(AbortExec);
            }
            register(&mut st);
            st.threads[me] = ThreadSt::Blocked;
            self.reschedule(&mut st, Some(me));
        }
        self.wait_for_turn(me);
    }

    fn mutex_acquire(&self, me: usize, id: usize) {
        loop {
            {
                let mut st = self.st();
                let abort = st.abort;
                let ms = st.mutexes.entry(id).or_default();
                if abort || !ms.held {
                    // Under abort the grant is unconditional: lockers on
                    // the unwind path must make progress, and the
                    // execution's data is discarded anyway.
                    ms.held = true;
                    return;
                }
            }
            self.block_on(me, |st| {
                st.mutexes.entry(id).or_default().waiters.push_back(me);
            });
            // Woken by a release — retry; another thread may have
            // grabbed the lock in between.
        }
    }

    fn release_mutex_locked(st: &mut SchedState, id: usize) {
        let ms = st.mutexes.entry(id).or_default();
        ms.held = false;
        if let Some(w) = ms.waiters.pop_front() {
            st.threads[w] = ThreadSt::Runnable;
        }
    }

    fn mutex_release(&self, id: usize) {
        let mut st = self.st();
        Self::release_mutex_locked(&mut st, id);
        // No choice point on release: the next shared-memory operation
        // of every thread carries its own pre-operation point, which
        // explores the post-release interleavings.
    }

    /// Atomically release the mutex and enqueue on the condvar, then
    /// block — the wait half of `Condvar::wait`.
    fn condvar_wait(&self, me: usize, cv_id: usize, mutex_id: usize) {
        self.block_on(me, |st| {
            st.cv_waiters.entry(cv_id).or_default().push_back(me);
            Self::release_mutex_locked(st, mutex_id);
        });
    }

    fn notify(&self, me: usize, cv_id: usize, all: bool) {
        self.explore_point(me);
        let mut st = self.st();
        if let Some(q) = st.cv_waiters.get_mut(&cv_id) {
            let n = if all { q.len() } else { 1.min(q.len()) };
            let woken: Vec<usize> = q.drain(..n).collect();
            for w in woken {
                st.threads[w] = ThreadSt::Runnable;
            }
        }
    }

    fn obj_id(&self, cell: &OnceLock<usize>) -> usize {
        *cell.get_or_init(|| {
            let mut st = self.st();
            st.next_obj_id += 1;
            st.next_obj_id
        })
    }
}

/// Choice point for the calling thread, if it is a model thread.
fn point() {
    if let Some((sched, me)) = current() {
        sched.explore_point(me);
    }
}

fn thread_main(sched: StdArc<Sched>, me: usize, body: impl FnOnce()) {
    CURRENT.with(|c| *c.borrow_mut() = Some((StdArc::clone(&sched), me)));
    let result = panic::catch_unwind(AssertUnwindSafe(|| {
        sched.wait_for_turn(me);
        body();
    }));
    CURRENT.with(|c| *c.borrow_mut() = None);
    let mut st = sched.st();
    st.threads[me] = ThreadSt::Finished;
    st.live -= 1;
    if let Some(ws) = st.join_waiters.remove(&me) {
        for w in ws {
            st.threads[w] = ThreadSt::Runnable;
        }
    }
    if let Err(payload) = result {
        if !payload.is::<AbortExec>() && st.panic.is_none() {
            st.panic = Some(payload);
        }
        st.abort = true;
        sched.cv.notify_all();
    } else {
        sched.reschedule(&mut st, None);
    }
    if st.live == 0 {
        sched.cv.notify_all();
    }
}

/// Advance the DFS path to the next unexplored schedule. Returns false
/// when the space is exhausted.
fn advance(path: &mut Vec<Branch>) -> bool {
    while let Some(b) = path.last_mut() {
        if b.index + 1 < b.choices.len() {
            b.index += 1;
            return true;
        }
        path.pop();
    }
    false
}

/// Run `f` under every distinct bounded schedule. Panics (with the
/// first failing schedule's payload) if any interleaving panics,
/// deadlocks, or exceeds the iteration cap. Bounds come from
/// `GBS_LOOM_MAX_ITER` / `GBS_LOOM_MAX_PREEMPTIONS`.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    model_with_limits(
        f,
        env_usize("GBS_LOOM_MAX_ITER", DEFAULT_MAX_ITER),
        env_usize("GBS_LOOM_MAX_PREEMPTIONS", DEFAULT_MAX_PREEMPTIONS),
    );
}

/// [`model`] with explicit bounds — for callers (and the checker's own
/// tests) that must not depend on process-global env vars.
pub fn model_with_limits<F>(f: F, max_iter: usize, max_preemptions: usize)
where
    F: Fn() + Send + Sync + 'static,
{
    let max_pre = max_preemptions;
    let f = StdArc::new(f);
    let mut path: Vec<Branch> = Vec::new();
    let mut iterations = 0usize;
    loop {
        iterations += 1;
        assert!(
            iterations <= max_iter,
            "loom model: exceeded {max_iter} executions (raise GBS_LOOM_MAX_ITER or \
             shrink the model)"
        );
        let sched = StdArc::new(Sched {
            state: StdMutex::new(SchedState {
                threads: vec![ThreadSt::Runnable],
                active: Some(0),
                live: 1,
                path: std::mem::take(&mut path),
                depth: 0,
                preemptions: 0,
                abort: false,
                deadlock: None,
                panic: None,
                mutexes: HashMap::new(),
                cv_waiters: HashMap::new(),
                join_waiters: HashMap::new(),
                next_obj_id: 0,
                os_handles: Vec::new(),
            }),
            cv: StdCondvar::new(),
            max_preemptions: max_pre,
        });
        let body = StdArc::clone(&f);
        let s2 = StdArc::clone(&sched);
        let root = std::thread::Builder::new()
            .name("loom-0".into())
            .spawn(move || thread_main(s2, 0, move || body()))
            .expect("spawn loom root thread");
        sched.st().os_handles.push(root);
        {
            let mut st = sched.st();
            while st.live > 0 && !st.abort {
                st = match sched.cv.wait(st) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
        }
        // Join every OS thread of this execution (aborted ones unwind
        // out of their parks) so no thread leaks into the next one.
        loop {
            let handle = sched.st().os_handles.pop();
            match handle {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
        let mut st = sched.st();
        if let Some(p) = st.panic.take() {
            drop(st);
            panic::resume_unwind(p);
        }
        if let Some(d) = st.deadlock.take() {
            drop(st);
            panic!("{d} (execution {iterations})");
        }
        path = std::mem::take(&mut st.path);
        drop(st);
        drop(sched);
        if !advance(&mut path) {
            break;
        }
    }
}

/// Mutual exclusion under the model scheduler. API mirrors
/// `std::sync::Mutex` (lock never reports poison — an in-model panic
/// aborts the whole execution instead). Objects must be created inside
/// the [`model`] closure; outside a model the lock degenerates to an
/// unchecked grant (single-threaded use only).
pub struct Mutex<T: ?Sized> {
    id: OnceLock<usize>,
    data: UnsafeCell<T>,
}

// SAFETY: the scheduler's `held` flag grants at most one live guard at
// a time while a model runs (only one model thread executes at any
// instant, and the flag is toggled under the scheduler lock); outside
// a model the type is documented single-threaded. `T: Send` bounds
// match std's Mutex.
unsafe impl<T: ?Sized + Send> Send for Mutex<T> {}
// SAFETY: as above — shared references only hand out data through the
// exclusion protocol.
unsafe impl<T: ?Sized + Send> Sync for Mutex<T> {}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Mutex {
            id: OnceLock::new(),
            data: UnsafeCell::new(value),
        }
    }
}

// The mirrored types print opaquely (no data access — a `Debug` format
// must not become a scheduler choice point) so facade structs can keep
// `#[derive(Debug)]` under `--cfg loom`.
impl<T: ?Sized> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad("Mutex { .. }")
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> std::sync::LockResult<MutexGuard<'_, T>> {
        if let Some((sched, me)) = current() {
            let id = sched.obj_id(&self.id);
            sched.explore_point(me);
            sched.mutex_acquire(me, id);
        }
        Ok(MutexGuard { lock: self })
    }
}

pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: the guard exists only while the model scheduler (or
        // documented single-threaded use) grants exclusive access.
        unsafe { &*self.lock.data.get() }
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: as in `deref` — exclusive access for the guard's
        // lifetime.
        unsafe { &mut *self.lock.data.get() }
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((sched, _)) = current() {
            if let Some(&id) = self.lock.id.get() {
                sched.mutex_release(id);
            }
        }
    }
}

/// Condition variable under the model scheduler. `notify_one` wakes
/// the FIFO-first waiter; waits never wake spuriously and never time
/// out (the facade's timed-wait helper degrades to a plain wait under
/// `--cfg loom`).
#[derive(Default)]
pub struct Condvar {
    id: OnceLock<usize>,
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad("Condvar { .. }")
    }
}

impl Condvar {
    pub fn new() -> Self {
        Condvar { id: OnceLock::new() }
    }

    pub fn wait<'a, T: ?Sized>(
        &self,
        guard: MutexGuard<'a, T>,
    ) -> std::sync::LockResult<MutexGuard<'a, T>> {
        if let Some((sched, me)) = current() {
            let lock = guard.lock;
            let cv_id = sched.obj_id(&self.id);
            let mutex_id = sched.obj_id(&lock.id);
            // The manual release below replaces the guard's unlock.
            std::mem::forget(guard);
            sched.condvar_wait(me, cv_id, mutex_id);
            sched.mutex_acquire(me, mutex_id);
            Ok(MutexGuard { lock })
        } else {
            // Outside a model there is no scheduler to block on;
            // return as a spurious wakeup (callers loop on predicates).
            Ok(guard)
        }
    }

    pub fn notify_one(&self) {
        if let Some((sched, me)) = current() {
            let id = sched.obj_id(&self.id);
            sched.notify(me, id, false);
        }
    }

    pub fn notify_all(&self) {
        if let Some((sched, me)) = current() {
            let id = sched.obj_id(&self.id);
            sched.notify(me, id, true);
        }
    }
}

macro_rules! model_atomic_int {
    ($name:ident, $std:ty, $ty:ty) => {
        /// Sequentially consistent model atomic; every access is a
        /// scheduler choice point. Ordering arguments are ignored.
        pub struct $name {
            v: $std,
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                // Opaque on purpose: reading the value would be a
                // scheduler choice point.
                f.pad(concat!(stringify!($name), " { .. }"))
            }
        }

        impl $name {
            pub const fn new(v: $ty) -> Self {
                Self { v: <$std>::new(v) }
            }
            pub fn load(&self, _order: Ordering) -> $ty {
                point();
                self.v.load(StdOrdering::SeqCst)
            }
            pub fn store(&self, val: $ty, _order: Ordering) {
                point();
                self.v.store(val, StdOrdering::SeqCst);
            }
            pub fn swap(&self, val: $ty, _order: Ordering) -> $ty {
                point();
                self.v.swap(val, StdOrdering::SeqCst)
            }
            pub fn fetch_add(&self, val: $ty, _order: Ordering) -> $ty {
                point();
                self.v.fetch_add(val, StdOrdering::SeqCst)
            }
            pub fn fetch_sub(&self, val: $ty, _order: Ordering) -> $ty {
                point();
                self.v.fetch_sub(val, StdOrdering::SeqCst)
            }
            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                _success: Ordering,
                _failure: Ordering,
            ) -> Result<$ty, $ty> {
                point();
                self.v
                    .compare_exchange(current, new, StdOrdering::SeqCst, StdOrdering::SeqCst)
            }
        }
    };
}

model_atomic_int!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
model_atomic_int!(AtomicU32, std::sync::atomic::AtomicU32, u32);
model_atomic_int!(AtomicU64, std::sync::atomic::AtomicU64, u64);

/// Sequentially consistent model `AtomicBool`; every access is a
/// scheduler choice point.
pub struct AtomicBool {
    v: std::sync::atomic::AtomicBool,
}

impl std::fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad("AtomicBool { .. }")
    }
}

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self {
            v: std::sync::atomic::AtomicBool::new(v),
        }
    }
    pub fn load(&self, _order: Ordering) -> bool {
        point();
        self.v.load(StdOrdering::SeqCst)
    }
    pub fn store(&self, val: bool, _order: Ordering) {
        point();
        self.v.store(val, StdOrdering::SeqCst);
    }
    pub fn swap(&self, val: bool, _order: Ordering) -> bool {
        point();
        self.v.swap(val, StdOrdering::SeqCst)
    }
}

/// Model threads — `spawn`/`JoinHandle` mirroring `std::thread` for
/// code routed through the facade. Outside a model, spawns fall back
/// to real OS threads.
pub mod thread {
    use super::{
        current, panic, point, thread_main, AbortExec, Any, StdArc, StdMutex, ThreadSt,
    };

    enum Inner<T> {
        Model {
            sched: StdArc<super::Sched>,
            id: usize,
            slot: StdArc<StdMutex<Option<T>>>,
        },
        Os(std::thread::JoinHandle<T>),
    }

    pub struct JoinHandle<T>(Inner<T>);

    impl<T> std::fmt::Debug for JoinHandle<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            // Mirrors `std::thread::JoinHandle`'s `Debug` so facade
            // structs can keep `#[derive(Debug)]` under `--cfg loom`.
            f.pad("JoinHandle { .. }")
        }
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self.0 {
                Inner::Os(h) => h.join(),
                Inner::Model { sched, id, slot } => {
                    let (_, me) = current().expect("model JoinHandle joined outside its model");
                    loop {
                        {
                            let st = sched.st();
                            if st.abort {
                                drop(st);
                                if std::thread::panicking() {
                                    return Err(Box::new(AbortExec) as Box<dyn Any + Send>);
                                }
                                panic::panic_any(AbortExec);
                            }
                            if st.threads[id] == ThreadSt::Finished {
                                break;
                            }
                        }
                        sched.block_on(me, |st| {
                            st.join_waiters.entry(id).or_default().push(me);
                        });
                    }
                    point();
                    let value = match slot.lock() {
                        Ok(mut g) => g.take(),
                        Err(p) => p.into_inner().take(),
                    };
                    Ok(value.expect("joined model thread stored no result"))
                }
            }
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        match current() {
            None => JoinHandle(Inner::Os(std::thread::spawn(f))),
            Some((sched, me)) => {
                let id = {
                    let mut st = sched.st();
                    if st.abort {
                        drop(st);
                        panic::panic_any(AbortExec);
                    }
                    let id = st.threads.len();
                    st.threads.push(ThreadSt::Runnable);
                    st.live += 1;
                    id
                };
                let slot = StdArc::new(StdMutex::new(None));
                let slot2 = StdArc::clone(&slot);
                let s2 = StdArc::clone(&sched);
                let os = std::thread::Builder::new()
                    .name(format!("loom-{id}"))
                    .spawn(move || {
                        thread_main(s2, id, move || {
                            let value = f();
                            match slot2.lock() {
                                Ok(mut g) => *g = Some(value),
                                Err(p) => *p.into_inner() = Some(value),
                            }
                        });
                    })
                    .expect("spawn model thread");
                sched.st().os_handles.push(os);
                // The new thread is now schedulable — choice point.
                sched.explore_point(me);
                JoinHandle(Inner::Model { sched, id, slot })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Mutex as TestMutex;

    #[test]
    fn explores_both_store_orders() {
        // Two racing stores: exhaustive exploration must observe both
        // final values across iterations.
        let seen = StdArc::new(TestMutex::new(HashSet::new()));
        let record = StdArc::clone(&seen);
        model(move || {
            let x = Arc::new(AtomicUsize::new(0));
            let x2 = Arc::clone(&x);
            let t = thread::spawn(move || x2.store(1, Ordering::SeqCst));
            x.store(2, Ordering::SeqCst);
            t.join().expect("store thread");
            record
                .lock()
                .expect("recorder")
                .insert(x.load(Ordering::SeqCst));
        });
        let seen = seen.lock().expect("recorder");
        assert!(seen.contains(&1) && seen.contains(&2), "saw {seen:?}");
    }

    #[test]
    fn mutex_provides_exclusion() {
        model(|| {
            let m = Arc::new(Mutex::new(0usize));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let m = Arc::clone(&m);
                    thread::spawn(move || {
                        for _ in 0..2 {
                            let mut g = m.lock().expect("model mutex");
                            let v = *g;
                            *g = v + 1;
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("incrementer");
            }
            assert_eq!(*m.lock().expect("model mutex"), 4);
        });
    }

    #[test]
    fn condvar_handoff_completes() {
        // Correct predicate-loop handoff: no interleaving deadlocks.
        model(|| {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
            let t = thread::spawn(move || {
                *m2.lock().expect("flag") = true;
                cv2.notify_one();
            });
            let mut g = m.lock().expect("flag");
            while !*g {
                g = cv.wait(g).expect("wait");
            }
            drop(g);
            t.join().expect("producer");
        });
    }

    #[test]
    fn detects_lost_wakeup_as_deadlock() {
        // Buggy consumer: reads the flag *outside* the mutex, so the
        // producer can set-and-notify between the read and the wait —
        // a classic lost wakeup the checker must flag as a deadlock.
        let result = panic::catch_unwind(|| {
            model(|| {
                let flag = Arc::new(AtomicUsize::new(0));
                let m = Arc::new(Mutex::new(()));
                let cv = Arc::new(Condvar::new());
                let (flag2, m2, cv2) = (Arc::clone(&flag), Arc::clone(&m), Arc::clone(&cv));
                let t = thread::spawn(move || {
                    flag2.store(1, Ordering::SeqCst);
                    cv2.notify_one();
                });
                if flag.load(Ordering::SeqCst) == 0 {
                    let g = m.lock().expect("gate");
                    let _g = cv.wait(g).expect("wait");
                }
                t.join().expect("producer");
            });
        });
        let err = result.expect_err("lost wakeup must be detected");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("deadlock"), "unexpected panic: {msg:?}");
    }

    #[test]
    fn join_returns_value() {
        model(|| {
            let t = thread::spawn(|| 41usize + 1);
            assert_eq!(t.join().expect("worker"), 42);
        });
    }

    #[test]
    fn model_panics_propagate() {
        let result = panic::catch_unwind(|| {
            model(|| {
                let t = thread::spawn(|| panic!("model thread exploded"));
                let _ = t.join();
            });
        });
        let err = result.expect_err("panic must propagate");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("exploded"), "unexpected payload {msg:?}");
    }

    #[test]
    fn iteration_cap_is_enforced() {
        // Racing atomics need far more than 2 schedules — the checker
        // must refuse to silently under-explore when capped that low.
        let result = panic::catch_unwind(|| {
            model_with_limits(
                || {
                    let x = Arc::new(AtomicUsize::new(0));
                    let hs: Vec<_> = (0..2)
                        .map(|_| {
                            let x = Arc::clone(&x);
                            thread::spawn(move || {
                                x.fetch_add(1, Ordering::SeqCst);
                                x.fetch_add(1, Ordering::SeqCst);
                            })
                        })
                        .collect();
                    for h in hs {
                        h.join().expect("adder");
                    }
                },
                2,
                DEFAULT_MAX_PREEMPTIONS,
            );
        });
        let err = result.expect_err("tiny cap must trip");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("exceeded 2 executions"), "got {msg:?}");
    }
}

//! Benchmark harness — the in-tree stand-in for criterion (offline
//! build): warmup, adaptive iteration counts, robust statistics, and
//! CSV/console reporting. Every `benches/*.rs` target builds on this.

use std::time::Instant;

/// Statistics over one benchmark's samples.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id, e.g. `fig4/gtx285/n=33554432`.
    pub name: String,
    /// Per-sample wall milliseconds (each sample may aggregate several
    /// iterations; values are per-iteration).
    pub samples_ms: Vec<f64>,
}

impl BenchResult {
    /// Arithmetic mean (ms).
    pub fn mean_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// Median (ms) — the headline number (robust to scheduler noise).
    pub fn median_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mid = v.len() / 2;
        if v.len() % 2 == 0 {
            (v[mid - 1] + v[mid]) / 2.0
        } else {
            v[mid]
        }
    }

    /// Sample standard deviation (ms).
    pub fn stddev_ms(&self) -> f64 {
        let n = self.samples_ms.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean_ms();
        let var = self
            .samples_ms
            .iter()
            .map(|x| (x - mean) * (x - mean))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    /// Minimum sample (ms).
    pub fn min_ms(&self) -> f64 {
        self.samples_ms.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// One console line.
    pub fn line(&self) -> String {
        format!(
            "{:<52} median {:>10.3} ms  mean {:>10.3} ms  σ {:>8.3} ms  ({} samples)",
            self.name,
            self.median_ms(),
            self.mean_ms(),
            self.stddev_ms(),
            self.samples_ms.len()
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    /// Warmup wall-time budget per benchmark (ms).
    pub warmup_ms: f64,
    /// Samples to collect.
    pub samples: usize,
    /// Minimum wall time per sample (ms) — iterations are batched until
    /// a sample takes at least this long.
    pub min_sample_ms: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_ms: 100.0,
            samples: 8,
            min_sample_ms: 10.0,
        }
    }
}

impl Bencher {
    /// A faster profile for CI / quick runs (honours the
    /// `GBS_BENCH_FAST=1` environment toggle).
    pub fn from_env() -> Self {
        if std::env::var("GBS_BENCH_FAST").as_deref() == Ok("1") {
            Bencher {
                warmup_ms: 20.0,
                samples: 4,
                min_sample_ms: 2.0,
            }
        } else {
            Bencher::default()
        }
    }

    /// Run one benchmark: `f` is invoked repeatedly; its return value is
    /// black-boxed.
    pub fn bench<O>(&self, name: impl Into<String>, mut f: impl FnMut() -> O) -> BenchResult {
        let name = name.into();
        // Warmup + calibration.
        let mut iters_per_sample = 1usize;
        let warmup_start = Instant::now();
        let mut one = {
            let t = Instant::now();
            black_box(f());
            t.elapsed().as_secs_f64() * 1e3
        };
        while warmup_start.elapsed().as_secs_f64() * 1e3 < self.warmup_ms {
            let t = Instant::now();
            black_box(f());
            one = 0.5 * one + 0.5 * (t.elapsed().as_secs_f64() * 1e3);
            if one > self.warmup_ms {
                break;
            }
        }
        if one > 0.0 && one < self.min_sample_ms {
            iters_per_sample = (self.min_sample_ms / one).ceil() as usize;
        }

        // Sampling.
        let mut samples_ms = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            samples_ms.push(t.elapsed().as_secs_f64() * 1e3 / iters_per_sample as f64);
        }
        let r = BenchResult { name, samples_ms };
        println!("{}", r.line());
        r
    }
}

/// Opaque-to-the-optimizer identity (std::hint::black_box wrapper, so
/// benches don't get constant-folded away).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write results as CSV (`name,median_ms,mean_ms,stddev_ms,min_ms,samples`).
pub fn write_csv(path: &std::path::Path, results: &[BenchResult]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::from("name,median_ms,mean_ms,stddev_ms,min_ms,samples\n");
    for r in results {
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6},{}\n",
            r.name,
            r.median_ms(),
            r.mean_ms(),
            r.stddev_ms(),
            r.min_ms(),
            r.samples_ms.len()
        ));
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_by_hand() {
        let r = BenchResult {
            name: "t".into(),
            samples_ms: vec![1.0, 2.0, 3.0, 4.0, 100.0],
        };
        assert_eq!(r.median_ms(), 3.0);
        assert_eq!(r.mean_ms(), 22.0);
        assert_eq!(r.min_ms(), 1.0);
        assert!(r.stddev_ms() > 40.0);
        let even = BenchResult {
            name: "e".into(),
            samples_ms: vec![1.0, 3.0],
        };
        assert_eq!(even.median_ms(), 2.0);
    }

    #[test]
    fn bench_collects_samples() {
        let b = Bencher {
            warmup_ms: 1.0,
            samples: 5,
            min_sample_ms: 0.1,
        };
        let mut count = 0u64;
        let r = b.bench("noop", || {
            count += 1;
            count
        });
        assert_eq!(r.samples_ms.len(), 5);
        assert!(count >= 5);
        assert!(r.median_ms() >= 0.0);
    }

    #[test]
    fn csv_output() {
        let dir = std::env::temp_dir().join(format!("gbs_bench_{}", std::process::id()));
        let path = dir.join("out.csv");
        let r = BenchResult {
            name: "x".into(),
            samples_ms: vec![1.0, 2.0],
        };
        write_csv(&path, &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,median_ms"));
        assert!(text.contains("x,1.5"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

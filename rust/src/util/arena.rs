//! Scratch-buffer arena — warm buffer reuse for the executed hot path.
//!
//! Every sort used to allocate its working set from scratch: the
//! tile-aligned work buffer, the relocation target, the Step-9 bucket
//! scratch, the record vector of a key–value job. At service rates that
//! is page-faulting allocator traffic on every request. A
//! [`ScratchArena`] keeps those buffers warm instead: [`checkout`]
//! hands out a zero-capacity-or-recycled `Vec<T>` wrapped in a
//! [`ScratchBuf`] guard, and dropping the guard returns the (cleared)
//! buffer to the arena. After one warm-up run per shape, the
//! steady-state path performs **no heap allocation**.
//!
//! Buffers are shelved by element type (one shelf per `Vec<T>` type,
//! which groups exactly by element width class: all 4-byte keys share
//! the `u32`-shaped capacity curve, 8-byte keys the `u64` one, and so
//! on — the stats report per-shelf retained bytes). Checkouts are
//! per caller: concurrent workers each pop a distinct buffer, so a
//! shelf naturally grows to the engine's worker count and no further
//! (a cap bounds pathological growth).
//!
//! The arena is `Clone` (shared handle) and `Send + Sync`; a lock is
//! taken only at checkout/return, never while caller code runs.
//!
//! [`checkout`]: ScratchArena::take
//!
//! Determinism: the arena only recycles *capacity*. Every checkout is
//! cleared and refilled by the caller, so outputs are byte-identical to
//! the allocate-fresh behaviour (property-tested in
//! `rust/tests/prop_kernels.rs`).

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::fmt;
use std::ops::{Deref, DerefMut};

use crate::util::sync::{lock_unpoisoned, Arc, Mutex, MutexGuard};

/// Free buffers retained per shelf — enough for every worker of a
/// large engine to hold one plus spares, small enough that a
/// pathological caller cannot pin unbounded memory.
const MAX_FREE_PER_SHELF: usize = 64;

/// Capacity bytes retained per shelf. Buffers whose return would push
/// the shelf past this are freed instead of parked, so one burst of
/// huge jobs cannot pin peak-sized memory for the engine's lifetime
/// (steady-state large-job traffic still reuses: the cap holds several
/// paper-scale 16M-key working buffers).
const MAX_RETAINED_BYTES_PER_SHELF: usize = 512 << 20;

struct Shelf {
    free: Vec<Box<dyn Any + Send>>,
    /// Bytes per element of this shelf's `Vec<T>` (the width class).
    elem_bytes: usize,
    /// Σ capacity·elem_bytes over the free buffers.
    retained_bytes: usize,
}

#[derive(Default)]
struct ArenaInner {
    shelves: HashMap<TypeId, Shelf>,
    hits: u64,
    misses: u64,
}

/// Counters describing an arena's reuse behaviour (see
/// [`ScratchArena::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaStats {
    /// Checkouts served from a recycled buffer.
    pub hits: u64,
    /// Checkouts that had to start from an empty `Vec`.
    pub misses: u64,
    /// Bytes of capacity currently parked in the arena.
    pub retained_bytes: usize,
    /// Free buffers currently parked in the arena.
    pub buffers: usize,
}

/// A shared pool of recyclable scratch buffers. See the module docs.
#[derive(Clone, Default)]
pub struct ScratchArena {
    inner: Arc<Mutex<ArenaInner>>,
}

impl fmt::Debug for ScratchArena {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let stats = self.stats();
        f.debug_struct("ScratchArena")
            .field("hits", &stats.hits)
            .field("misses", &stats.misses)
            .field("retained_bytes", &stats.retained_bytes)
            .field("buffers", &stats.buffers)
            .finish()
    }
}

impl ScratchArena {
    /// New, empty arena.
    pub fn new() -> Self {
        ScratchArena::default()
    }

    fn lock(&self) -> MutexGuard<'_, ArenaInner> {
        lock_unpoisoned(&self.inner)
    }

    /// Check out an empty buffer (recycled capacity when available).
    /// One lock acquisition per checkout, hit or miss — the planned
    /// radix kernel checks out two buffers (ping-pong keys + counting
    /// table) per tile, so the checkout path is itself hot.
    pub fn take_empty<T: Send + 'static>(&self) -> ScratchBuf<T> {
        let mut g = self.lock();
        let popped = g
            .shelves
            .get_mut(&TypeId::of::<Vec<T>>())
            .and_then(|shelf| {
                let boxed = shelf.free.pop()?;
                let vec = *boxed.downcast::<Vec<T>>().unwrap_or_default();
                let bytes = vec.capacity() * std::mem::size_of::<T>();
                shelf.retained_bytes = shelf.retained_bytes.saturating_sub(bytes);
                Some(vec)
            });
        let vec = match popped {
            Some(v) => {
                g.hits += 1;
                v
            }
            None => {
                g.misses += 1;
                Vec::new()
            }
        };
        drop(g);
        ScratchBuf {
            vec,
            home: Arc::clone(&self.inner),
        }
    }

    /// Check out a buffer of `len` elements, every element `fill`.
    pub fn take<T: Send + Clone + 'static>(&self, len: usize, fill: T) -> ScratchBuf<T> {
        let mut buf = self.take_empty::<T>();
        buf.vec.resize(len, fill);
        buf
    }

    /// Check out a buffer holding a copy of `src`.
    pub fn take_from<T: Send + Clone + 'static>(&self, src: &[T]) -> ScratchBuf<T> {
        let mut buf = self.take_empty::<T>();
        buf.vec.extend_from_slice(src);
        buf
    }

    /// Point-in-time reuse counters.
    pub fn stats(&self) -> ArenaStats {
        let g = self.lock();
        ArenaStats {
            hits: g.hits,
            misses: g.misses,
            retained_bytes: g.shelves.values().map(|s| s.retained_bytes).sum(),
            buffers: g.shelves.values().map(|s| s.free.len()).sum(),
        }
    }
}

/// A checked-out scratch buffer; derefs to its `Vec<T>` and returns the
/// (cleared) buffer to its arena on drop.
pub struct ScratchBuf<T: Send + 'static> {
    vec: Vec<T>,
    home: Arc<Mutex<ArenaInner>>,
}

impl<T: Send + 'static> Deref for ScratchBuf<T> {
    type Target = Vec<T>;

    fn deref(&self) -> &Vec<T> {
        &self.vec
    }
}

impl<T: Send + 'static> DerefMut for ScratchBuf<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        &mut self.vec
    }
}

impl<T: Send + 'static> Drop for ScratchBuf<T> {
    fn drop(&mut self) {
        let mut vec = std::mem::take(&mut self.vec);
        if vec.capacity() == 0 {
            return;
        }
        vec.clear();
        let bytes = vec.capacity() * std::mem::size_of::<T>();
        let mut g = lock_unpoisoned(&self.home);
        let shelf = g
            .shelves
            .entry(TypeId::of::<Vec<T>>())
            .or_insert_with(|| Shelf {
                free: Vec::new(),
                elem_bytes: std::mem::size_of::<T>(),
                retained_bytes: 0,
            });
        debug_assert_eq!(shelf.elem_bytes, std::mem::size_of::<T>());
        if shelf.free.len() < MAX_FREE_PER_SHELF
            && shelf.retained_bytes + bytes <= MAX_RETAINED_BYTES_PER_SHELF
        {
            shelf.retained_bytes += bytes;
            shelf.free.push(Box::new(vec));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_return_reuses_capacity() {
        let arena = ScratchArena::new();
        let ptr = {
            let mut buf = arena.take::<u32>(1000, 7);
            assert_eq!(buf.len(), 1000);
            assert!(buf.iter().all(|&x| x == 7));
            buf.push(9);
            buf.as_ptr() as usize
        };
        // Same allocation comes back (capacity ≥ 1001 retained).
        let buf2 = arena.take::<u32>(500, 1);
        assert_eq!(buf2.as_ptr() as usize, ptr);
        assert_eq!(buf2.len(), 500);
        assert!(buf2.iter().all(|&x| x == 1));
        let stats = arena.stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
    }

    #[test]
    fn shelves_are_typed() {
        let arena = ScratchArena::new();
        drop(arena.take::<u32>(10, 0));
        drop(arena.take::<u64>(10, 0));
        // A u64 checkout never receives the u32 buffer.
        let b64 = arena.take::<u64>(4, 1);
        let b32 = arena.take::<u32>(4, 1);
        assert_eq!(b64.len(), 4);
        assert_eq!(b32.len(), 4);
        assert_eq!(arena.stats().hits, 2);
    }

    #[test]
    fn take_from_copies() {
        let arena = ScratchArena::new();
        let src = vec![3u32, 1, 2];
        let buf = arena.take_from(&src);
        assert_eq!(&buf[..], &[3, 1, 2]);
    }

    #[test]
    fn stats_track_retained_bytes() {
        let arena = ScratchArena::new();
        drop(arena.take::<u32>(1024, 0));
        let stats = arena.stats();
        assert!(stats.retained_bytes >= 1024 * 4, "{stats:?}");
        assert_eq!(stats.buffers, 1);
        // Checking the buffer out again empties the shelf.
        let _held = arena.take_empty::<u32>();
        assert_eq!(arena.stats().buffers, 0);
        assert_eq!(arena.stats().retained_bytes, 0);
    }

    #[test]
    fn concurrent_checkouts_are_distinct() {
        let arena = ScratchArena::new();
        // Warm two buffers.
        {
            let a = arena.take::<u32>(8, 0);
            let b = arena.take::<u32>(8, 0);
            assert_ne!(a.as_ptr(), b.as_ptr());
        }
        let a = arena.take::<u32>(8, 1);
        let b = arena.take::<u32>(8, 2);
        assert_ne!(a.as_ptr(), b.as_ptr());
        assert!(a.iter().all(|&x| x == 1));
        assert!(b.iter().all(|&x| x == 2));
    }

    #[test]
    fn oversized_buffers_are_freed_not_parked() {
        // A buffer beyond the per-shelf byte cap is dropped on return
        // rather than pinned for the arena's lifetime. (The reserve is
        // virtual address space only — the pages are never touched.)
        let arena = ScratchArena::new();
        let mut buf = arena.take_empty::<u8>();
        buf.reserve(MAX_RETAINED_BYTES_PER_SHELF + 1);
        drop(buf);
        assert_eq!(arena.stats().buffers, 0);
        assert_eq!(arena.stats().retained_bytes, 0);
    }

    #[test]
    fn shared_handle_shares_shelves() {
        let arena = ScratchArena::new();
        let clone = arena.clone();
        drop(arena.take::<u32>(64, 0));
        assert_eq!(clone.stats().buffers, 1);
        let _buf = clone.take_empty::<u32>();
        assert_eq!(arena.stats().hits, 1);
    }
}

//! Minimal JSON: parser, writer, and typed accessors.
//!
//! In-tree substrate (the build is offline, no serde): covers the full
//! JSON grammar — objects, arrays, strings with escapes, numbers,
//! booleans, null — which is all the manifest, config files and result
//! tables need. Object key order is preserved (Vec of pairs) so emitted
//! files diff cleanly.

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64; integers round-trip exactly up to
    /// 2^53, far beyond any count this library stores).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with preserved key order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- constructors ----

    /// Object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Number from anything numeric.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors ----

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing field {key:?}")))
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    // ---- serialization ----

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ----

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(err_at("trailing characters", pos));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn err_at(msg: &str, pos: usize) -> Error {
    Error::Manifest(format!("json: {msg} at byte {pos}"))
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err(err_at("unexpected end of input", *pos)),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Json::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(err_at("invalid literal", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if matches!(b.get(*pos), Some(b'-')) {
        *pos += 1;
    }
    while matches!(b.get(*pos), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| err_at("bad utf8", start))?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| err_at("invalid number", start))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err(err_at("unterminated string", *pos)),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| err_at("short \\u escape", *pos))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| err_at("bad \\u", *pos))?,
                            16,
                        )
                        .map_err(|_| err_at("bad \\u", *pos))?;
                        // Surrogate pairs are not needed by our files;
                        // map unpaired surrogates to the replacement char.
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(err_at("bad escape", *pos)),
                }
                *pos += 1;
            }
            Some(&c) => {
                // Collect a UTF-8 run.
                let len = utf8_len(c);
                let chunk = b
                    .get(*pos..*pos + len)
                    .ok_or_else(|| err_at("truncated utf8", *pos))?;
                out.push_str(std::str::from_utf8(chunk).map_err(|_| err_at("bad utf8", *pos))?);
                *pos += len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if matches!(b.get(*pos), Some(b']')) {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(err_at("expected ',' or ']'", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut pairs = Vec::new();
    skip_ws(b, pos);
    if matches!(b.get(*pos), Some(b'}')) {
        *pos += 1;
        return Ok(Json::Obj(pairs));
    }
    loop {
        skip_ws(b, pos);
        if !matches!(b.get(*pos), Some(b'"')) {
            return Err(err_at("expected object key", *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if !matches!(b.get(*pos), Some(b':')) {
            return Err(err_at("expected ':'", *pos));
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        pairs.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            _ => return Err(err_at("expected ',' or '}'", *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::obj(vec![("k\"ey", Json::str("line\nbreak\ttab \\ \u{1F600}"))]);
        let text = original.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(original, back);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn pretty_and_compact_roundtrip() {
        let v = Json::obj(vec![
            ("version", Json::num(1)),
            ("entries", Json::Arr(vec![Json::num(1), Json::num(2)])),
            ("flag", Json::Bool(true)),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(vec![])),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn integers_exact() {
        let v = Json::parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_f64(), Some(9007199254740992.0));
        let n = Json::num(536870912u32 as f64); // 512M
        assert_eq!(n.to_string_compact(), "536870912");
        assert_eq!(Json::parse("536870912").unwrap().as_usize(), Some(536870912));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
    }

    #[test]
    fn errors_are_positioned() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("truex").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn accessor_types() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize(), Some(7));
        assert!(v.req("missing").is_err());
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("s").unwrap().as_f64().is_none());
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
    }
}

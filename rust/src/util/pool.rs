//! Structured parallelism on a **resident worker pool** — the in-tree
//! stand-in for a data-parallel runtime (the build is offline; no
//! rayon).
//!
//! Historically every call here spawned fresh OS threads through
//! `std::thread::scope` (~10 µs per spawn on Linux, paid again for every
//! phase of every request). The pool is now *resident*: worker threads
//! are spawned once, parked on a condvar, and dispatched jobs for the
//! lifetime of the process — steady-state dispatch is one mutex push +
//! one condvar signal, with no thread creation on the hot path. The
//! borrow-friendly call surface is unchanged:
//!
//! * [`parallel_chunks_mut`] / [`parallel_slices_mut`] — disjoint
//!   mutable regions (tile sorts, per-bucket output slices);
//! * [`parallel_map`] / [`parallel_for`] — owned items through a dynamic
//!   queue (skewed work like variable-size service batches).
//!
//! Closures may still borrow stack data: a dispatch blocks until every
//! task of its job has finished, so borrows captured by the job provably
//! outlive all worker access (the same guarantee `thread::scope` gave,
//! enforced by the completion wait instead of the scope).
//!
//! The dispatching thread *participates* in its own job — it claims
//! tasks like any worker until the job is drained, then waits for
//! stragglers. That keeps the caller's core busy, makes a
//! one-worker dispatch run entirely inline, and makes nested dispatch
//! (a pool task that itself calls into the pool) deadlock-free: the
//! inner job always has at least its own dispatcher driving it.
//!
//! Work distribution is dynamic (tasks are claimed with an atomic
//! cursor), but every API assigns task *index* `i` to input region `i`,
//! so outputs never depend on which thread ran what — byte-determinism
//! at any worker count.
//!
//! Synchronization goes through the [`crate::util::sync`] facade, so a
//! `--cfg loom` build runs the park/unpark, nested-dispatch and
//! shutdown protocols under the exhaustive interleaving checker
//! (`rust/tests/loom_models.rs`). Model runs use private
//! [`WorkerPool::with_residents`] pools and [`WorkerPool::shutdown`]
//! so every execution terminates; the process-wide [`WorkerPool::global`]
//! pool never stops.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::OnceLock;

use crate::util::sync::{
    self as sync, lock_unpoisoned, wait_unpoisoned, Arc, AtomicBool, AtomicUsize, Condvar, Mutex,
    Ordering,
};

/// Default worker count: logical cores.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
}

/// Growth ceiling for resident threads — callers asking for more
/// parallelism than this share the existing residents.
const MAX_RESIDENT_THREADS: usize = 256;

/// Type-erased pointer to the job's task closure. Only dereferenced
/// while the dispatching [`WorkerPool::run`] call is blocked on the
/// job's completion, which is what keeps the erased lifetime honest.
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are
// fine) and is only dereferenced during the dispatcher's `run` call,
// which outlives every worker access by construction.
unsafe impl Send for TaskPtr {}
// SAFETY: as above — `&TaskPtr` only exposes a pointer to a `Sync`
// closure that outlives the job.
unsafe impl Sync for TaskPtr {}

/// Completion state of one job, under the job's mutex.
struct JobDone {
    /// Tasks not yet finished (claimed-but-running tasks count).
    pending: usize,
    /// First panic payload observed in a task, re-raised by the
    /// dispatcher (the behaviour `thread::scope` join gave).
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// One dispatched job: `num_tasks` indexed calls of `task`.
struct Job {
    task: TaskPtr,
    /// Claim cursor; a fetch-add ≥ `num_tasks` means the job is drained.
    next: AtomicUsize,
    num_tasks: usize,
    done: Mutex<JobDone>,
    finished: Condvar,
}

/// Claim and run one task of `job`, recording completion (and any
/// panic) in the job's done state.
fn run_task(job: &Job, index: usize) {
    // SAFETY: see `TaskPtr` — the dispatcher is blocked in `run` until
    // `pending` reaches zero, so the closure is alive here.
    let task = unsafe { &*job.task.0 };
    let result = panic::catch_unwind(AssertUnwindSafe(|| task(index)));
    let mut done = lock_unpoisoned(&job.done);
    if let Err(payload) = result {
        if done.panic.is_none() {
            done.panic = Some(payload);
        }
    }
    done.pending -= 1;
    if done.pending == 0 {
        job.finished.notify_all();
    }
}

struct PoolShared {
    /// FIFO of live jobs; a job is popped once fully claimed.
    queue: Mutex<VecDeque<Arc<Job>>>,
    /// Signals residents that a job arrived (or that the pool stops).
    work: Condvar,
    /// Set by [`WorkerPool::shutdown`]; residents exit once the queue
    /// is drained. Checked under the queue lock before parking, and
    /// the setter notifies while holding that lock, so the stop signal
    /// can never be lost between the check and the wait.
    stop: AtomicBool,
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let (job, index) = {
            let mut queue = lock_unpoisoned(&shared.queue);
            loop {
                let mut claimed = None;
                while let Some(job) = queue.front() {
                    let i = job.next.fetch_add(1, Ordering::Relaxed);
                    if i < job.num_tasks {
                        claimed = Some((Arc::clone(job), i));
                        break;
                    }
                    // Fully claimed: retire it and look at the next job.
                    queue.pop_front();
                }
                match claimed {
                    Some(c) => break c,
                    None => {
                        if shared.stop.load(Ordering::SeqCst) {
                            return;
                        }
                        queue = wait_unpoisoned(&shared.work, queue);
                    }
                }
            }
        };
        run_task(&job, index);
    }
}

/// The resident worker pool. One process-wide instance
/// ([`WorkerPool::global`]) serves every caller: the native PSRS
/// engine, the executed Algorithm 1 (Steps 2 and 9), and the
/// coordinator's engine workers all dispatch into the same resident
/// threads. Private instances ([`WorkerPool::with_residents`]) exist
/// for tests and interleaving models, which need a pool they can
/// [`WorkerPool::shutdown`].
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Resident thread count (grow-only, capped).
    resident: Mutex<usize>,
    /// Join handles of resident threads, consumed by `shutdown`.
    handles: Mutex<Vec<sync::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    fn new() -> Self {
        WorkerPool {
            shared: Arc::new(PoolShared {
                queue: Mutex::new(VecDeque::new()),
                work: Condvar::new(),
                stop: AtomicBool::new(false),
            }),
            resident: Mutex::new(0),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// The process-wide pool. Threads are spawned lazily on first use
    /// and live for the rest of the process (they are parked on the
    /// condvar whenever idle).
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(WorkerPool::new)
    }

    /// A private pool with `workers` residents spawned eagerly. Unlike
    /// [`WorkerPool::global`] it is meant to be torn down: call
    /// [`WorkerPool::shutdown`] to stop and join the residents. This
    /// is what the loom models dispatch into, so every modeled
    /// execution terminates.
    pub fn with_residents(workers: usize) -> WorkerPool {
        let pool = WorkerPool::new();
        pool.ensure_residents(workers);
        pool
    }

    /// Number of resident worker threads currently alive.
    pub fn resident_threads(&self) -> usize {
        *lock_unpoisoned(&self.resident)
    }

    /// Stop the residents once the queue drains and join them.
    /// Idempotent; only meaningful for private pools.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        {
            // Notify under the queue lock: a resident that just saw
            // `stop == false` is either still holding the lock (it
            // will re-check after we notify) or already parked (the
            // notify reaches it). Notifying without the lock could
            // slip between its check and its wait and be lost.
            let _queue = lock_unpoisoned(&self.shared.queue);
            self.shared.work.notify_all();
        }
        let handles: Vec<_> = {
            let mut guard = lock_unpoisoned(&self.handles);
            guard.drain(..).collect()
        };
        for handle in handles {
            let _ = handle.join();
        }
    }

    /// Grow the resident set so at least `want` workers exist (the
    /// dispatcher itself is the +1 that completes the requested
    /// parallelism). Steady-state calls find the count already
    /// satisfied and spawn nothing.
    fn ensure_residents(&self, want: usize) {
        let want = want.min(MAX_RESIDENT_THREADS);
        let mut count = lock_unpoisoned(&self.resident);
        while *count < want {
            let shared = Arc::clone(&self.shared);
            let handle =
                sync::thread::spawn_named(format!("gbs-pool-{}", *count), move || {
                    worker_loop(shared)
                });
            lock_unpoisoned(&self.handles).push(handle);
            *count += 1;
        }
    }

    /// Run `task(i)` for every `i < num_tasks` with up to `parallelism`
    /// concurrent executors (residents plus the calling thread), and
    /// return once all tasks finished. Task panics are re-raised here
    /// after the job drains.
    pub fn run(&self, num_tasks: usize, parallelism: usize, task: &(dyn Fn(usize) + Sync)) {
        if num_tasks == 0 {
            return;
        }
        let parallelism = parallelism.max(1).min(num_tasks);
        if parallelism <= 1 || num_tasks == 1 {
            for i in 0..num_tasks {
                task(i);
            }
            return;
        }
        self.ensure_residents(parallelism - 1);
        let job = Arc::new(Job {
            task: TaskPtr(task as *const (dyn Fn(usize) + Sync)),
            next: AtomicUsize::new(0),
            num_tasks,
            done: Mutex::new(JobDone {
                pending: num_tasks,
                panic: None,
            }),
            finished: Condvar::new(),
        });
        lock_unpoisoned(&self.shared.queue).push_back(Arc::clone(&job));
        self.shared.work.notify_all();

        // Participate in our own job until its tasks are all claimed.
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.num_tasks {
                break;
            }
            run_task(&job, i);
        }
        // Wait for tasks claimed by residents to finish.
        let mut done = lock_unpoisoned(&job.done);
        while done.pending > 0 {
            done = wait_unpoisoned(&job.finished, done);
        }
        let panicked = done.panic.take();
        drop(done);
        if let Some(payload) = panicked {
            panic::resume_unwind(payload);
        }
    }
}

/// Raw pointer that may cross threads; every use in this module hands
/// each task a disjoint region (chunk `i`, slice `i`, or slot `i`), so
/// no two threads ever alias the same elements.
struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

// SAFETY: see the type docs — regions are disjoint by construction and
// the pointee outlives the dispatch (the dispatcher blocks in `run`).
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: as above — sharing the pointer is fine because tasks index
// disjoint regions through it.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Run `f(index, chunk)` over `chunk_len`-sized chunks of `data` on up
/// to `workers` executors of the resident pool. Chunk `index` is always
/// the chunk's position in `data`, so results are independent of thread
/// assignment.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunks = n.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    WorkerPool::global().run(chunks, workers, &move |i| {
        let start = i * chunk_len;
        let len = chunk_len.min(n - start);
        // SAFETY: chunk regions [start, start+len) are disjoint per
        // task index, within bounds, and `data` outlives the dispatch.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        f(i, chunk);
    });
}

/// Run `f(index, slice)` over an explicit list of disjoint mutable
/// slices (e.g. per-bucket output regions).
pub fn parallel_slices_mut<T, F>(mut slices: Vec<&mut [T]>, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = slices.len();
    if n == 0 {
        return;
    }
    let base = SendPtr(slices.as_mut_ptr());
    WorkerPool::global().run(n, workers, &move |i| {
        // SAFETY: each task reborrows only element `i` of the slice
        // list; the list itself outlives the dispatch.
        let slice: &mut [T] = unsafe { &mut **base.0.add(i) };
        f(i, slice);
    });
}

/// Frees an input buffer whose elements have all been moved out —
/// including on the unwind path. `WorkerPool::run` drains every task
/// (even after one panics) before returning or re-raising, so by the
/// time this guard drops, every element was consumed by exactly one
/// task (a task that panicked dropped its item during its own unwind).
struct ConsumedBuf<I> {
    vec: std::mem::ManuallyDrop<Vec<I>>,
}

impl<I> Drop for ConsumedBuf<I> {
    fn drop(&mut self) {
        // SAFETY: all elements moved out (see type docs); free the
        // allocation without running element destructors.
        unsafe {
            self.vec.set_len(0);
            std::mem::ManuallyDrop::drop(&mut self.vec);
        }
    }
}

/// Map owned items to outputs on up to `workers` executors; output
/// order matches input order. Panic-safe: a panicking task propagates
/// after the job drains, with every consumed input and produced output
/// dropped normally (outputs live in `Option` slots until collection).
pub fn parallel_map<I, O, F>(items: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(I) -> O + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let mut slots: Vec<Option<O>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let items = ConsumedBuf {
        vec: std::mem::ManuallyDrop::new(items),
    };
    let src = SendPtr(items.vec.as_ptr() as *mut I);
    let dst = SendPtr(slots.as_mut_ptr());
    WorkerPool::global().run(n, workers, &move |i| {
        // SAFETY: task indices are unique, so each input is moved out
        // exactly once and each `None` slot overwritten at most once
        // (plain assignment — dropping a `None` is free, and a panic
        // before the write leaves a droppable `None` behind).
        let item = unsafe { std::ptr::read(src.0.add(i)) };
        let value = f(item);
        // SAFETY: slot `i` belongs to this task alone; see above.
        unsafe { *dst.0.add(i) = Some(value) };
    });
    drop(items); // frees the consumed input buffer
    slots
        .into_iter()
        .map(|o| o.expect("every task writes its slot"))
        .collect()
}

/// Run `n_tasks` indexed closures in parallel, collecting outputs in
/// index order (the "parallel for" shape).
pub fn parallel_for<O, F>(n_tasks: usize, workers: usize, f: F) -> Vec<O>
where
    O: Send,
    F: Fn(usize) -> O + Sync,
{
    let workers = workers.max(1).min(n_tasks.max(1));
    if workers <= 1 || n_tasks <= 1 {
        return (0..n_tasks).map(f).collect();
    }
    let mut slots: Vec<Option<O>> = Vec::with_capacity(n_tasks);
    slots.resize_with(n_tasks, || None);
    let dst = SendPtr(slots.as_mut_ptr());
    WorkerPool::global().run(n_tasks, workers, &move |i| {
        let value = f(i);
        // SAFETY: unique slot per task index; see `parallel_map`.
        unsafe { *dst.0.add(i) = Some(value) };
    });
    slots
        .into_iter()
        .map(|o| o.expect("every task writes its slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Heavy/timing-sensitive cases opt out under `GBS_MIRI=1` — the
    /// Miri CI job sets it so the UB-relevant pool paths still run
    /// while wall-clock assertions (meaningless under the interpreter)
    /// are skipped.
    fn under_miri() -> bool {
        std::env::var_os("GBS_MIRI").is_some()
    }

    #[test]
    fn chunks_cover_everything() {
        let mut data: Vec<u32> = vec![0; 1000];
        parallel_chunks_mut(&mut data, 64, 4, |i, c| {
            for x in c.iter_mut() {
                *x = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        // Chunk 0 covers [0,64), chunk 15 covers [960,1000).
        assert_eq!(data[0], 1);
        assert_eq!(data[999], 16);
    }

    #[test]
    fn slices_mut_disjoint() {
        let mut data: Vec<u32> = vec![0; 100];
        let (a, b) = data.split_at_mut(30);
        parallel_slices_mut(vec![a, b], 2, |i, s| {
            for x in s.iter_mut() {
                *x = i as u32 + 7;
            }
        });
        assert!(data[..30].iter().all(|&x| x == 7));
        assert!(data[30..].iter().all(|&x| x == 8));
    }

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_actually_parallel() {
        if under_miri() {
            return; // wall-clock assertion is meaningless under Miri
        }
        // With 4 workers and 4 sleepy tasks, wall time ≈ 1 task.
        let t0 = std::time::Instant::now();
        parallel_for(4, 4, |_| std::thread::sleep(std::time::Duration::from_millis(50)));
        let elapsed = t0.elapsed().as_millis();
        assert!(elapsed < 150, "elapsed {elapsed} ms — not parallel");
    }

    #[test]
    fn single_worker_fallback() {
        let counter = AtomicUsize::new(0);
        let mut data = vec![0u8; 10];
        parallel_chunks_mut(&mut data, 3, 1, |_, c| {
            counter.fetch_add(c.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 10);
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_inputs() {
        let mut data: Vec<u8> = vec![];
        parallel_chunks_mut(&mut data, 4, 4, |_, _| panic!("no chunks"));
        let out: Vec<u8> = parallel_map(Vec::<u8>::new(), 4, |x| x);
        assert!(out.is_empty());
        parallel_slices_mut(Vec::<&mut [u8]>::new(), 4, |_, _| panic!("no slices"));
    }

    #[test]
    fn pool_threads_are_resident() {
        // Two dispatches at the same parallelism reuse the same
        // residents — the count does not grow with call count.
        let counter = AtomicUsize::new(0);
        parallel_for(8, 3, |_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        let after_first = WorkerPool::global().resident_threads();
        for _ in 0..32 {
            parallel_for(8, 3, |_| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 8 * 33);
        // Parallelism 3 needs 2 residents (the dispatcher is the third
        // executor). Other tests sharing the global pool may have grown
        // it further, but repeated dispatches never grow it themselves.
        assert!(after_first >= 2);
        assert!(WorkerPool::global().resident_threads() < MAX_RESIDENT_THREADS);
    }

    #[test]
    fn private_pool_runs_and_shuts_down() {
        let pool = WorkerPool::with_residents(2);
        assert_eq!(pool.resident_threads(), 2);
        let counter = AtomicUsize::new(0);
        pool.run(8, 3, &|_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 8);
        pool.shutdown();
        // Idempotent: a second shutdown has nothing left to join.
        pool.shutdown();
    }

    #[test]
    fn nested_dispatch_does_not_deadlock() {
        // A pool task that itself dispatches into the pool (the native
        // engine inside a parallel_map batch) must always make
        // progress: the inner dispatcher participates in its own job.
        let total = AtomicUsize::new(0);
        let out = parallel_for(4, 4, |_| {
            let inner: usize = parallel_for(8, 4, |j| j).into_iter().sum();
            total.fetch_add(inner, Ordering::Relaxed);
            inner
        });
        assert_eq!(out, vec![28usize; 4]);
        assert_eq!(total.load(Ordering::Relaxed), 4 * 28);
    }

    #[test]
    fn task_panics_propagate_to_dispatcher() {
        let result = std::panic::catch_unwind(|| {
            parallel_for(8, 4, |i| {
                if i == 5 {
                    panic!("task 5 exploded");
                }
                i
            })
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("exploded"), "unexpected payload {msg:?}");
    }

    #[test]
    fn borrowed_stack_data_survives() {
        // The scope-style guarantee: tasks may borrow the caller's
        // stack because dispatch blocks until the job drains.
        let local: Vec<u64> = (0..100).collect();
        let sums = parallel_for(10, 4, |i| local[i * 10..(i + 1) * 10].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), 4950);
    }
}

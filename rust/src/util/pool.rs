//! Structured parallelism on std threads — the in-tree stand-in for a
//! data-parallel runtime (the build is offline; no rayon).
//!
//! Built on `std::thread::scope`, so closures may borrow stack data.
//! Two scheduling modes:
//! * [`parallel_chunks_mut`] / [`parallel_slices_mut`] — static
//!   round-robin assignment (right for uniform work like tile sorts);
//! * [`parallel_map`] — dynamic queue (right for skewed work like
//!   variable-size service batches or bucket sorts).
//!
//! Thread spawn costs ~10 µs on Linux; callers gate on input size (the
//! native engine's `sequential_cutoff`) so the overhead stays ≪ 1% of
//! useful work.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Default worker count: logical cores.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
}

/// Run `f(index, chunk)` over `chunk_len`-sized chunks of `data` on
/// `workers` threads (static round-robin assignment).
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    parallel_indexed_slices(chunks, workers, &f);
}

/// Run `f(index, slice)` over an explicit list of disjoint mutable
/// slices (e.g. per-bucket output regions).
pub fn parallel_slices_mut<T, F>(slices: Vec<&mut [T]>, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let indexed: Vec<(usize, &mut [T])> = slices.into_iter().enumerate().collect();
    parallel_indexed_slices(indexed, workers, &f);
}

fn parallel_indexed_slices<T, F>(chunks: Vec<(usize, &mut [T])>, workers: usize, f: &F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let workers = workers.max(1).min(chunks.len().max(1));
    if workers <= 1 || chunks.len() <= 1 {
        for (i, c) in chunks {
            f(i, c);
        }
        return;
    }
    let mut per_worker: Vec<Vec<(usize, &mut [T])>> =
        (0..workers).map(|_| Vec::new()).collect();
    for (pos, item) in chunks.into_iter().enumerate() {
        per_worker[pos % workers].push(item);
    }
    std::thread::scope(|s| {
        for list in per_worker {
            s.spawn(move || {
                for (i, c) in list {
                    f(i, c);
                }
            });
        }
    });
}

/// Map owned items to outputs on `workers` threads with a dynamic work
/// queue; output order matches input order.
pub fn parallel_map<I, O, F>(items: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(I) -> O + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue: Mutex<VecDeque<(usize, I)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let results: Mutex<Vec<Option<O>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = queue.lock().unwrap().pop_front();
                match next {
                    Some((i, item)) => {
                        let out = f(item);
                        results.lock().unwrap()[i] = Some(out);
                    }
                    None => break,
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every item processed"))
        .collect()
}

/// Run `n_tasks` indexed closures in parallel, collecting outputs in
/// index order (the "parallel for" shape).
pub fn parallel_for<O, F>(n_tasks: usize, workers: usize, f: F) -> Vec<O>
where
    O: Send,
    F: Fn(usize) -> O + Sync,
{
    parallel_map((0..n_tasks).collect(), workers, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_everything() {
        let mut data: Vec<u32> = vec![0; 1000];
        parallel_chunks_mut(&mut data, 64, 4, |i, c| {
            for x in c.iter_mut() {
                *x = i as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        // Chunk 0 covers [0,64), chunk 15 covers [960,1000).
        assert_eq!(data[0], 1);
        assert_eq!(data[999], 16);
    }

    #[test]
    fn slices_mut_disjoint() {
        let mut data: Vec<u32> = vec![0; 100];
        let (a, b) = data.split_at_mut(30);
        parallel_slices_mut(vec![a, b], 2, |i, s| {
            for x in s.iter_mut() {
                *x = i as u32 + 7;
            }
        });
        assert!(data[..30].iter().all(|&x| x == 7));
        assert!(data[30..].iter().all(|&x| x == 8));
    }

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_actually_parallel() {
        // With 4 workers and 4 sleepy tasks, wall time ≈ 1 task.
        let t0 = std::time::Instant::now();
        parallel_for(4, 4, |_| std::thread::sleep(std::time::Duration::from_millis(50)));
        let elapsed = t0.elapsed().as_millis();
        assert!(elapsed < 150, "elapsed {elapsed} ms — not parallel");
    }

    #[test]
    fn single_worker_fallback() {
        let counter = AtomicUsize::new(0);
        let mut data = vec![0u8; 10];
        parallel_chunks_mut(&mut data, 3, 1, |_, c| {
            counter.fetch_add(c.len(), Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 10);
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_inputs() {
        let mut data: Vec<u8> = vec![];
        parallel_chunks_mut(&mut data, 4, 4, |_, _| panic!("no chunks"));
        let out: Vec<u8> = parallel_map(Vec::<u8>::new(), 4, |x| x);
        assert!(out.is_empty());
        parallel_slices_mut(Vec::<&mut [u8]>::new(), 4, |_, _| panic!("no slices"));
    }
}

//! Property-test driver — the in-tree stand-in for proptest (offline
//! build): seeded case generation with failure reporting and simple
//! input shrinking for vector-shaped cases.
//!
//! ```no_run
//! use gpu_bucket_sort::util::propcheck::{forall, Gen};
//!
//! forall(100, "sorting is idempotent", |g| {
//!     let mut v = g.vec_u32(0..2000);
//!     v.sort_unstable();
//!     let once = v.clone();
//!     v.sort_unstable();
//!     assert_eq!(v, once);
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Case generator handed to the property body.
pub struct Gen {
    rng: Rng,
    /// Case index (0-based) — useful for size-scaling inputs.
    pub case: usize,
}

impl Gen {
    /// Raw RNG access.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform usize in `range`.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end);
        range.start + self.rng.gen_range(range.end - range.start)
    }

    /// Uniform u32.
    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    /// Uniform u32 below `bound` (small-alphabet inputs provoke ties).
    pub fn u32_below(&mut self, bound: u32) -> u32 {
        (self.rng.gen_range(bound.max(1) as usize)) as u32
    }

    /// A u32 vector with length drawn from `len_range`; values mix
    /// full-range and small-alphabet regimes to exercise duplicates.
    pub fn vec_u32(&mut self, len_range: Range<usize>) -> Vec<u32> {
        let len = if len_range.is_empty() {
            len_range.start
        } else {
            self.usize_in(len_range)
        };
        let regime = self.rng.gen_range(4);
        (0..len)
            .map(|_| match regime {
                0 => self.rng.next_u32(),
                1 => self.u32_below(16),
                2 => self.u32_below(1 << 10),
                _ => self.rng.next_u32() % 1_000_000,
            })
            .collect()
    }

    /// One of the listed values.
    pub fn choose<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        &options[self.rng.gen_range(options.len())]
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }
}

/// Run `body` for `cases` generated cases. Panics (with the failing seed
/// and case index) if any case panics. Honours `GBS_PROP_CASES` to scale
/// effort and `GBS_PROP_SEED` to reproduce a failure.
pub fn forall(cases: usize, name: &str, body: impl Fn(&mut Gen)) {
    let cases = std::env::var("GBS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    let base_seed: u64 = std::env::var("GBS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        // AssertUnwindSafe: the driver aborts on first failure, so
        // observing state poisoned by the panicking case is impossible.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Rng::new(seed),
                case,
            };
            body(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed at case {case} (reproduce with GBS_PROP_SEED={base_seed} GBS_PROP_CASES={}): {msg}",
                case + 1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_a_true_property() {
        forall(50, "reverse twice is identity", |g| {
            let v = g.vec_u32(0..100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    fn reports_failures_with_seed() {
        let result = std::panic::catch_unwind(|| {
            forall(50, "all vectors are short", |g| {
                let v = g.vec_u32(0..100);
                assert!(v.len() < 5, "got length {}", v.len());
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("GBS_PROP_SEED"), "{msg}");
        assert!(msg.contains("all vectors are short"), "{msg}");
    }

    #[test]
    fn generators_cover_regimes() {
        let mut tie_heavy = 0;
        forall(40, "inspect", |g| {
            let v = g.vec_u32(50..100);
            assert!(v.len() >= 50 && v.len() < 100);
        });
        // Direct generator checks.
        let mut g = Gen {
            rng: Rng::new(1),
            case: 0,
        };
        for _ in 0..100 {
            let v = g.vec_u32(100..101);
            let distinct = {
                let mut s = v.clone();
                s.sort_unstable();
                s.dedup();
                s.len()
            };
            if distinct < 20 {
                tie_heavy += 1;
            }
        }
        assert!(tie_heavy > 5, "small-alphabet regime never generated");
        assert!(*g.choose(&[1, 2, 3]) <= 3);
        let _ = g.bool(0.5);
        assert!(g.u32_below(10) < 10);
    }
}

//! Deterministic, attempt-counted exponential backoff.
//!
//! Every retry loop in the crate computes its delay here and sleeps
//! through [`sleep_backoff`] — the **only** place outside tests where a
//! retry is allowed to call `std::thread::sleep` (enforced by xtask lint
//! R6). Centralising the sleep keeps two invariants easy to audit:
//!
//! * **Decisions are attempt-counted, never wall-clock.** The delay for
//!   attempt `k` is a pure function of `k` and the policy — no
//!   `Instant::now()` feeds back into whether or how long to retry, so a
//!   retry schedule is replayable and the R4 lint (no wall-clock in
//!   kernels) stays honest one layer up.
//! * **Delays are capped.** Exponential growth stops at `max`, so a
//!   misbehaving dependency produces bounded, predictable pressure
//!   instead of an unbounded sleep.

use std::time::Duration;

/// An attempt-counted exponential backoff policy: attempt `k` (0-based)
/// waits `min(base << k, max)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backoff {
    /// Delay before the first retry (attempt 0).
    pub base: Duration,
    /// Hard cap on any single delay.
    pub max: Duration,
}

impl Backoff {
    /// Policy used by the scheduler's bounded in-process retry loop:
    /// 2 ms doubling to a 50 ms cap. Short, because the failure it
    /// covers (device lost, contained panic) is resolved by re-planning,
    /// not by waiting for an external system.
    pub const SCHEDULER: Backoff = Backoff {
        base: Duration::from_millis(2),
        max: Duration::from_millis(50),
    };

    /// Policy used by the TCP client's reconnect loop: 10 ms doubling to
    /// a 500 ms cap — long enough to ride out a server restart without
    /// hammering the listener.
    pub const RECONNECT: Backoff = Backoff {
        base: Duration::from_millis(10),
        max: Duration::from_millis(500),
    };

    /// The delay before retry `attempt` (0-based): `min(base << attempt,
    /// max)`. Saturates instead of overflowing for absurd attempt counts.
    pub fn delay(&self, attempt: u32) -> Duration {
        let shifted = self
            .base
            .checked_mul(1u32.checked_shl(attempt).unwrap_or(u32::MAX))
            .unwrap_or(self.max);
        shifted.min(self.max)
    }
}

/// Sleep for the policy's delay at `attempt`. This is the one sanctioned
/// `thread::sleep` retry site (xtask lint R6); callers decide *whether*
/// to retry from typed [`crate::error::FailureClass`] values and an
/// attempt counter, then come here to pace the retry.
pub fn sleep_backoff(policy: &Backoff, attempt: u32) {
    let d = policy.delay(attempt);
    if !d.is_zero() {
        std::thread::sleep(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_double_then_cap() {
        let b = Backoff {
            base: Duration::from_millis(2),
            max: Duration::from_millis(50),
        };
        assert_eq!(b.delay(0), Duration::from_millis(2));
        assert_eq!(b.delay(1), Duration::from_millis(4));
        assert_eq!(b.delay(2), Duration::from_millis(8));
        assert_eq!(b.delay(4), Duration::from_millis(32));
        assert_eq!(b.delay(5), Duration::from_millis(50)); // 64 -> cap
        assert_eq!(b.delay(30), Duration::from_millis(50));
        assert_eq!(b.delay(200), Duration::from_millis(50)); // shift sat
    }

    #[test]
    fn delay_is_attempt_pure() {
        // Same attempt, same delay — the schedule is replayable.
        for k in 0..12 {
            assert_eq!(Backoff::SCHEDULER.delay(k), Backoff::SCHEDULER.delay(k));
        }
        assert_eq!(Backoff::RECONNECT.delay(0), Duration::from_millis(10));
        assert_eq!(Backoff::RECONNECT.delay(10), Duration::from_millis(500));
    }

    #[test]
    fn zero_base_never_sleeps() {
        let b = Backoff {
            base: Duration::ZERO,
            max: Duration::ZERO,
        };
        sleep_backoff(&b, 7); // must return immediately
        assert_eq!(b.delay(7), Duration::ZERO);
    }
}

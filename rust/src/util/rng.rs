//! Deterministic pseudo-random number generation and the samplers the
//! workload generators need (uniform, normal, zipf).
//!
//! In-tree substrate (the build is offline): a SplitMix64-seeded
//! xoshiro256++ generator — the modern default for non-cryptographic
//! simulation use — plus Box–Muller gaussians and an inverse-CDF-free
//! rejection sampler for bounded Zipf.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as usize;
            }
            // Reject the biased low region.
            let threshold = bound.wrapping_neg() % bound;
            if lo >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Zipf(s=1) over {1, …, n} by rejection from the 1/x envelope
    /// (Devroye): returns values with P(k) ∝ 1/k.
    pub fn next_zipf(&mut self, n: u64) -> u64 {
        debug_assert!(n >= 1);
        // Envelope CDF ∝ 1 + ln x on [1, n]; invert to sample x, then
        // accept k = ⌊x⌋ with probability ∝ f_target(k)/f_envelope(x)
        // = x/k, normalized by its supremum (k+1)/k ≤ 2 ⇒ accept with
        // x/(2k).
        let h_n = 1.0 + (n as f64).ln();
        loop {
            let u = self.next_f64() * h_n;
            let x = if u <= 1.0 { 1.0 } else { (u - 1.0).exp() };
            let k = (x.floor().min(n as f64) as u64).max(1);
            if self.next_f64() < x / (2.0 * k as f64) {
                return k;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_f64_in_range_and_centered() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_head_heavy_and_bounded() {
        let mut r = Rng::new(4);
        let n = 1u64 << 20;
        let mut ones = 0usize;
        let mut total = 0usize;
        for _ in 0..50_000 {
            let k = r.next_zipf(n);
            assert!((1..=n).contains(&k));
            total += 1;
            if k == 1 {
                ones += 1;
            }
        }
        // P(1) = 1/H(n) ≈ 1/14.5 ≈ 6.9%.
        let frac = ones as f64 / total as f64;
        assert!(frac > 0.03 && frac < 0.15, "P(k=1)={frac}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        let orig = v.clone();
        r.shuffle(&mut v);
        assert_ne!(v, orig);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, orig);
    }
}

//! Lightweight service metrics: counters and latency histograms.
//!
//! The coordinator records per-request and per-phase observations here;
//! `gbs serve`'s shutdown summary and the examples print snapshots. No
//! external metrics stack — the service must stay self-contained.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Number of power-of-two latency buckets (µs scale): bucket i counts
/// observations in [2^i, 2^{i+1}) µs, up to ~17 minutes.
const BUCKETS: usize = 30;

/// A histogram over microsecond latencies with power-of-two buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Observation count.
    pub count: u64,
    /// Sum of observations (µs).
    pub sum_us: u64,
    /// Minimum observation (µs).
    pub min_us: u64,
    /// Maximum observation (µs).
    pub max_us: u64,
    /// Power-of-two buckets.
    pub buckets: Vec<u64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
            buckets: vec![0; BUCKETS],
        }
    }
}

impl Histogram {
    /// Record one observation in microseconds.
    pub fn observe_us(&mut self, us: u64) {
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx] += 1;
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1e3
    }

    /// Approximate quantile (bucket upper edge), q in [0,1].
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64 / 1e3;
            }
        }
        self.max_us as f64 / 1e3
    }
}

/// A point-in-time copy of all metrics.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Monotonic counters.
    pub counters: BTreeMap<String, u64>,
    /// Latency histograms.
    pub timers: BTreeMap<String, Histogram>,
}

impl MetricsSnapshot {
    /// Render a compact human-readable summary.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, h) in &self.timers {
            out.push_str(&format!(
                "{k}: n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms max={:.3}ms\n",
                h.count,
                h.mean_ms(),
                h.quantile_ms(0.5),
                h.quantile_ms(0.99),
                h.max_us as f64 / 1e3
            ));
        }
        out
    }
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<MetricsSnapshot>,
}

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Add `delta` to counter `name`.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Raise counter `name` to `value` if it is below it (high-water
    /// marks: peak queue depth, peak in-flight batches).
    pub fn record_max(&self, name: &str, value: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(name.to_string()).or_insert(0);
        *e = (*e).max(value);
    }

    /// Record a duration under timer `name`.
    pub fn observe(&self, name: &str, duration: std::time::Duration) {
        let mut g = self.inner.lock().unwrap();
        g.timers
            .entry(name.to_string())
            .or_default()
            .observe_us(duration.as_micros() as u64);
    }

    /// Record milliseconds under timer `name`.
    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.observe(name, std::time::Duration::from_secs_f64(ms / 1e3));
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.inner.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        m.incr("errors", 1);
        let s = m.snapshot();
        assert_eq!(s.counters["requests"], 3);
        assert_eq!(s.counters["errors"], 1);
    }

    #[test]
    fn record_max_keeps_high_water_mark() {
        let m = Metrics::new();
        m.record_max("depth_peak", 3);
        m.record_max("depth_peak", 7);
        m.record_max("depth_peak", 5);
        assert_eq!(m.snapshot().counters["depth_peak"], 7);
    }

    #[test]
    fn histogram_stats() {
        let mut h = Histogram::default();
        for us in [100u64, 200, 400, 800, 1600] {
            h.observe_us(us);
        }
        assert_eq!(h.count, 5);
        assert_eq!(h.min_us, 100);
        assert_eq!(h.max_us, 1600);
        assert!((h.mean_ms() - 0.62).abs() < 1e-9);
        // p50 falls in the bucket containing 400 µs.
        let p50 = h.quantile_ms(0.5);
        assert!(p50 >= 0.4 && p50 <= 1.0, "p50={p50}");
    }

    #[test]
    fn histogram_edges() {
        let mut h = Histogram::default();
        h.observe_us(0); // clamps to bucket 0
        h.observe_us(u64::MAX / 2); // clamps to last bucket
        assert_eq!(h.count, 2);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[BUCKETS - 1], 1);
    }

    #[test]
    fn timers_via_registry() {
        let m = Metrics::new();
        m.observe("sort", Duration::from_millis(5));
        m.observe("sort", Duration::from_millis(10));
        m.observe_ms("sort", 20.0);
        let s = m.snapshot();
        assert_eq!(s.timers["sort"].count, 3);
        assert!(s.summary().contains("sort"));
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.incr("x", 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().counters["x"], 8000);
    }
}

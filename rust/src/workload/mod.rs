//! Input-distribution generators.
//!
//! The paper evaluates on uniformly distributed random keys — explicitly
//! noting this is the *best case* for the randomized competitor [9],
//! whose own evaluation sweeps six distributions to document its input-
//! dependent fluctuations (§1, §3, §5). To reproduce the robustness
//! claim (deterministic = flat across distributions, randomized =
//! fluctuating) we provide the distribution family of Leischner et al. /
//! Helman et al. plus degenerate patterns, all deterministically seeded.

use crate::util::Rng;
use crate::{Key, KeyData, KeyType, SortKey};

/// The input distributions of the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// i.i.d. uniform over the full u32 range — the paper's Figures 3–7
    /// workload and the randomized method's best case.
    Uniform,
    /// Gaussian (clamped to u32) — mild clustering.
    Gaussian,
    /// Zipf over 2^20 distinct values — heavy skew with duplicates.
    Zipf,
    /// Staggered: block-permuted ramps (the classic sample-sort stress
    /// pattern of Helman et al.).
    Staggered,
    /// Already sorted ascending.
    Sorted,
    /// Sorted with 1% random transpositions.
    NearlySorted,
    /// Reverse sorted.
    ReverseSorted,
    /// All keys equal — the degenerate duplicate case.
    AllEqual,
    /// Two interleaved values — maximal tie pressure on splitters.
    TwoValues,
    /// Uniform draws folded onto 4096 distinct values — heavy duplicate
    /// density with only the low 12 bits varying, the case where the
    /// planner's constant-digit elision beats the uniform plan.
    FewUnique,
    /// First half one constant from the middle of the domain, second
    /// half uniform — poisons equidistant splitter samples (half of
    /// them land on the constant), stressing deterministic bucketing.
    SplitterKiller,
    /// Eight concatenated internally-sorted blocks (a sawtooth /
    /// pipe-organ ramp): high local sortedness with block-boundary
    /// inversions, the nearly-sorted-but-not-sorted stress for the
    /// adaptive front-end's early-exit verification.
    NearlySortedBlocks,
}

impl Distribution {
    /// The six-distribution robustness suite (matching the spirit of
    /// [9]'s evaluation) in presentation order.
    pub const ROBUSTNESS_SUITE: [Distribution; 6] = [
        Distribution::Uniform,
        Distribution::Gaussian,
        Distribution::Zipf,
        Distribution::Staggered,
        Distribution::Sorted,
        Distribution::NearlySorted,
    ];

    /// Every distribution, including the degenerate extras.
    pub const ALL: [Distribution; 12] = [
        Distribution::Uniform,
        Distribution::Gaussian,
        Distribution::Zipf,
        Distribution::Staggered,
        Distribution::Sorted,
        Distribution::NearlySorted,
        Distribution::ReverseSorted,
        Distribution::AllEqual,
        Distribution::TwoValues,
        Distribution::FewUnique,
        Distribution::SplitterKiller,
        Distribution::NearlySortedBlocks,
    ];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Distribution> {
        match s.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "uniform" => Some(Distribution::Uniform),
            "gaussian" | "normal" => Some(Distribution::Gaussian),
            "zipf" => Some(Distribution::Zipf),
            "staggered" => Some(Distribution::Staggered),
            "sorted" => Some(Distribution::Sorted),
            "nearlysorted" | "almostsorted" => Some(Distribution::NearlySorted),
            "reverse" | "reversesorted" => Some(Distribution::ReverseSorted),
            "allequal" | "equal" | "constant" => Some(Distribution::AllEqual),
            "twovalues" | "binary" => Some(Distribution::TwoValues),
            "fewunique" | "lowcardinality" => Some(Distribution::FewUnique),
            "splitterkiller" | "halfconstant" => Some(Distribution::SplitterKiller),
            "nearlysortedblocks" | "sawtooth" | "pipeorgan" => {
                Some(Distribution::NearlySortedBlocks)
            }
            _ => None,
        }
    }

    /// Short stable identifier for CSV output.
    pub fn id(&self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Gaussian => "gaussian",
            Distribution::Zipf => "zipf",
            Distribution::Staggered => "staggered",
            Distribution::Sorted => "sorted",
            Distribution::NearlySorted => "nearly_sorted",
            Distribution::ReverseSorted => "reverse",
            Distribution::AllEqual => "all_equal",
            Distribution::TwoValues => "two_values",
            Distribution::FewUnique => "few_unique",
            Distribution::SplitterKiller => "splitter_killer",
            Distribution::NearlySortedBlocks => "nearly_sorted_blocks",
        }
    }

    /// Generate `n` classic `u32` keys with this distribution,
    /// deterministically from `seed` — byte-identical to the historical
    /// (pre-typed) generator: [`Distribution::generate_typed`] at
    /// `K = u32` reproduces its exact arithmetic and RNG draw sequence.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<Key> {
        self.generate_typed::<u32>(n, seed)
    }

    /// Generate `n` keys of any [`SortKey`] type.
    ///
    /// One definition covers every type by working in *bit space*: a
    /// draw is a position in the key's total order, mapped through
    /// [`SortKey::from_raw_bits`]. 4-byte keys consume one `next_u32`
    /// per draw (the historical stream), 8-byte keys one `next_u64`.
    /// Consequences worth knowing:
    /// * for `i32`/`i64`, Uniform covers the full signed range and
    ///   Gaussian centres at 0;
    /// * for `f32`, Uniform is uniform over the *total order* — it
    ///   contains negatives, infinities and NaNs, which is exactly the
    ///   robustness stress the suite wants; Zipf/TwoValues/AllEqual map
    ///   their small raw values to the bottom of the total order (the
    ///   negative-NaN region), keeping their duplicate structure while
    ///   doubling as a NaN-handling stress.
    pub fn generate_typed<K: SortKey>(&self, n: usize, seed: u64) -> Vec<K> {
        let mut rng = Rng::new(seed ^ 0xD15C0_u64.wrapping_mul(self.salt()));
        let wide = K::WIDTH_BYTES > 4;
        fn draw(rng: &mut Rng, wide: bool) -> u64 {
            if wide {
                rng.next_u64()
            } else {
                rng.next_u32() as u64
            }
        }
        let domain_max: u64 = if wide { u64::MAX } else { u32::MAX as u64 };
        match self {
            Distribution::Uniform => (0..n)
                .map(|_| K::from_raw_bits(draw(&mut rng, wide)))
                .collect(),
            Distribution::Gaussian => {
                let mean = domain_max as f64 / 2.0;
                let sigma = domain_max as f64 / 8.0;
                (0..n)
                    .map(|_| {
                        let x = (mean + sigma * rng.next_gaussian())
                            .clamp(0.0, domain_max as f64 - 1.0);
                        // The f64 clamp is exact at 32-bit width (the
                        // historical arithmetic) but at 64-bit width
                        // `domain_max - 1.0` rounds to 2^64, so cap in
                        // integer space too: the generator never emits
                        // the domain maximum (the PAD sentinel).
                        K::from_raw_bits((x as u64).min(domain_max - 1))
                    })
                    .collect()
            }
            Distribution::Zipf => (0..n)
                .map(|_| K::from_raw_bits(rng.next_zipf(1u64 << 20)))
                .collect(),
            Distribution::Staggered => {
                // Helman-style staggered: split into 2^b blocks; block i
                // contributes the ramp starting at a bit-reversed offset,
                // defeating naive regular samples of unsorted data.
                let blocks = 64usize;
                let block_len = n.div_ceil(blocks);
                let mut out = Vec::with_capacity(n);
                for b in 0..blocks {
                    let rev = (b as u32).reverse_bits() >> (32 - 6);
                    let base = (rev as u128 * domain_max as u128 / blocks as u128) as u64;
                    for i in 0..block_len {
                        if out.len() == n {
                            break;
                        }
                        let off = ((i as u32).wrapping_mul(2654435761) % 65536) as u64;
                        // from_raw_bits truncates to the key width, so
                        // the add wraps exactly like the historical u32
                        // arithmetic.
                        out.push(K::from_raw_bits(base.wrapping_add(off)));
                    }
                }
                out
            }
            Distribution::Sorted => {
                let mut v: Vec<K> = (0..n)
                    .map(|_| K::from_raw_bits(draw(&mut rng, wide)))
                    .collect();
                v.sort_unstable_by(K::key_cmp);
                v
            }
            Distribution::NearlySorted => {
                let mut v: Vec<K> = (0..n)
                    .map(|_| K::from_raw_bits(draw(&mut rng, wide)))
                    .collect();
                v.sort_unstable_by(K::key_cmp);
                let swaps = n / 100;
                for _ in 0..swaps {
                    let i = rng.gen_range(n);
                    let j = rng.gen_range(n);
                    v.swap(i, j);
                }
                v
            }
            Distribution::ReverseSorted => {
                let mut v: Vec<K> = (0..n)
                    .map(|_| K::from_raw_bits(draw(&mut rng, wide)))
                    .collect();
                v.sort_unstable_by(K::key_cmp);
                v.reverse();
                v
            }
            Distribution::AllEqual => vec![K::from_raw_bits(0xCAFE_F00D); n],
            Distribution::TwoValues => (0..n)
                .map(|i| K::from_raw_bits(if i % 2 == 0 { 10 } else { 20 }))
                .collect(),
            Distribution::FewUnique => (0..n)
                .map(|_| K::from_raw_bits(draw(&mut rng, wide) % 4096))
                .collect(),
            Distribution::SplitterKiller => {
                let pivot = domain_max / 2;
                (0..n)
                    .map(|i| {
                        if i < n / 2 {
                            // Constant half first: every equidistant
                            // sample over the prefix hits the pivot.
                            K::from_raw_bits(pivot)
                        } else {
                            K::from_raw_bits(draw(&mut rng, wide))
                        }
                    })
                    .collect()
            }
            Distribution::NearlySortedBlocks => {
                let blocks = 8usize;
                let mut v: Vec<K> = (0..n)
                    .map(|_| K::from_raw_bits(draw(&mut rng, wide)))
                    .collect();
                let block_len = n.div_ceil(blocks).max(1);
                for chunk in v.chunks_mut(block_len) {
                    chunk.sort_unstable_by(K::key_cmp);
                }
                v
            }
        }
    }

    /// Generate `n` keys of the runtime-selected `key_type` as a
    /// request-ready [`KeyData`] (the CLI/service entry to
    /// [`Distribution::generate_typed`]).
    pub fn generate_data(&self, key_type: KeyType, n: usize, seed: u64) -> KeyData {
        match key_type {
            KeyType::U32 => KeyData::U32(self.generate_typed(n, seed)),
            KeyType::U64 => KeyData::U64(self.generate_typed(n, seed)),
            KeyType::I32 => KeyData::I32(self.generate_typed(n, seed)),
            KeyType::I64 => KeyData::I64(self.generate_typed(n, seed)),
            KeyType::F32 => KeyData::F32(self.generate_typed(n, seed)),
        }
    }

    fn salt(&self) -> u64 {
        match self {
            Distribution::Uniform => 1,
            Distribution::Gaussian => 2,
            Distribution::Zipf => 3,
            Distribution::Staggered => 4,
            Distribution::Sorted => 5,
            Distribution::NearlySorted => 6,
            Distribution::ReverseSorted => 7,
            Distribution::AllEqual => 8,
            Distribution::TwoValues => 9,
            Distribution::FewUnique => 10,
            Distribution::SplitterKiller => 11,
            Distribution::NearlySortedBlocks => 12,
        }
    }
}

impl std::fmt::Display for Distribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        for d in Distribution::ALL {
            let a = d.generate(1000, 7);
            let b = d.generate(1000, 7);
            assert_eq!(a, b, "{d}");
            assert_eq!(a.len(), 1000);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = Distribution::Uniform.generate(1000, 1);
        let b = Distribution::Uniform.generate(1000, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn sorted_is_sorted() {
        assert!(crate::is_sorted(&Distribution::Sorted.generate(5000, 3)));
        let rev = Distribution::ReverseSorted.generate(5000, 3);
        assert!(rev.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn nearly_sorted_is_mostly_sorted() {
        let v = Distribution::NearlySorted.generate(10_000, 3);
        let inversions = v.windows(2).filter(|w| w[0] > w[1]).count();
        assert!(inversions > 0, "should not be fully sorted");
        assert!(inversions < 500, "should be mostly sorted, got {inversions}");
    }

    #[test]
    fn zipf_is_skewed() {
        let v = Distribution::Zipf.generate(100_000, 3);
        let ones = v.iter().filter(|&&x| x == 1).count();
        // Zipf s=1 over 2^20 values: value 1 has probability ~1/H ≈ 7%.
        assert!(ones > 2_000, "zipf head too light: {ones}");
    }

    #[test]
    fn gaussian_is_centered() {
        let v = Distribution::Gaussian.generate(100_000, 3);
        let mid = u32::MAX / 2;
        let within = v
            .iter()
            .filter(|&&x| x > mid / 2 && x < mid + mid / 2)
            .count();
        assert!(within > 90_000, "gaussian not clustered: {within}");
    }

    #[test]
    fn two_values_and_equal() {
        let v = Distribution::TwoValues.generate(100, 0);
        assert!(v.iter().all(|&x| x == 10 || x == 20));
        let e = Distribution::AllEqual.generate(100, 0);
        assert!(e.iter().all(|&x| x == e[0]));
    }

    #[test]
    fn staggered_covers_range() {
        let v = Distribution::Staggered.generate(64 * 100, 0);
        let lo = v.iter().filter(|&&x| x < u32::MAX / 4).count();
        let hi = v.iter().filter(|&&x| x > 3 * (u32::MAX / 4)).count();
        assert!(lo > 0 && hi > 0, "staggered should span the range");
    }

    #[test]
    fn few_unique_has_low_cardinality() {
        let v = Distribution::FewUnique.generate(100_000, 3);
        assert!(v.iter().all(|&x| x < 4096));
        let mut distinct = v.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() > 1000, "too few values: {}", distinct.len());
        assert!(distinct.len() <= 4096);
    }

    #[test]
    fn splitter_killer_is_half_constant() {
        let n = 10_000;
        let v = Distribution::SplitterKiller.generate(n, 3);
        let pivot = (u32::MAX as u64 / 2) as u32;
        assert!(v[..n / 2].iter().all(|&x| x == pivot));
        // The uniform half is genuinely varied.
        let mut tail = v[n / 2..].to_vec();
        tail.sort_unstable();
        tail.dedup();
        assert!(tail.len() > n / 4, "uniform half degenerate: {}", tail.len());
    }

    #[test]
    fn nearly_sorted_blocks_is_blockwise_sorted() {
        let n = 10_000;
        let v = Distribution::NearlySortedBlocks.generate(n, 3);
        let block_len = n.div_ceil(8);
        for chunk in v.chunks(block_len) {
            assert!(crate::is_sorted(chunk));
        }
        // The whole array is (almost surely) not sorted — the blocks
        // overlap in value range.
        assert!(!crate::is_sorted(&v));
    }

    #[test]
    fn parse_roundtrip() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::parse(d.id()), Some(d), "{d}");
        }
        assert_eq!(Distribution::parse("bogus"), None);
    }

    #[test]
    fn typed_generation_is_deterministic_for_every_key_type() {
        for d in Distribution::ALL {
            for kt in KeyType::ALL {
                let a = d.generate_data(kt, 500, 7);
                let b = d.generate_data(kt, 500, 7);
                assert_eq!(a.key_type(), kt);
                assert_eq!(a.len(), 500);
                // f32 streams can contain NaN (NaN != NaN), so compare
                // deterministically at the byte level.
                match (&a, &b) {
                    (KeyData::F32(x), KeyData::F32(y)) => {
                        let xb: Vec<u32> = x.iter().map(|v| f32::to_bits(*v)).collect();
                        let yb: Vec<u32> = y.iter().map(|v| f32::to_bits(*v)).collect();
                        assert_eq!(xb, yb, "{d} {kt}");
                    }
                    _ => assert_eq!(a, b, "{d} {kt}"),
                }
            }
        }
    }

    #[test]
    fn typed_generation_covers_each_domain() {
        // u64 uniform actually uses the 64-bit domain.
        let v: Vec<u64> = Distribution::Uniform.generate_typed(1000, 3);
        assert!(v.iter().any(|&x| x > u32::MAX as u64));
        // i32 uniform covers both signs; gaussian centres near zero.
        let v: Vec<i32> = Distribution::Uniform.generate_typed(1000, 3);
        assert!(v.iter().any(|&x| x < 0) && v.iter().any(|&x| x > 0));
        let g: Vec<i64> = Distribution::Gaussian.generate_typed(10_000, 3);
        let near_zero = g
            .iter()
            .filter(|&&x| x.unsigned_abs() < u64::MAX / 2)
            .count();
        assert!(near_zero > 9_000, "i64 gaussian not centred: {near_zero}");
        // f32 uniform (total-order domain) exercises the NaN stress.
        let f: Vec<f32> = Distribution::Uniform.generate_typed(100_000, 3);
        assert!(f.iter().any(|x| x.is_nan()), "no NaNs in the f32 stress");
        assert!(f.iter().any(|x| *x < 0.0) && f.iter().any(|x| *x > 0.0));
        // Sorted is sorted under the total order for every type.
        let s: Vec<f32> = Distribution::Sorted.generate_typed(5000, 3);
        assert!(crate::is_sorted(&s));
        let s: Vec<i64> = Distribution::Sorted.generate_typed(5000, 3);
        assert!(crate::is_sorted(&s));
        // Duplicate structure survives the typed mapping.
        let t: Vec<u64> = Distribution::TwoValues.generate_typed(100, 0);
        assert!(t.iter().all(|&x| x == 10 || x == 20));
    }
}

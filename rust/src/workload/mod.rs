//! Input-distribution generators.
//!
//! The paper evaluates on uniformly distributed random keys — explicitly
//! noting this is the *best case* for the randomized competitor [9],
//! whose own evaluation sweeps six distributions to document its input-
//! dependent fluctuations (§1, §3, §5). To reproduce the robustness
//! claim (deterministic = flat across distributions, randomized =
//! fluctuating) we provide the distribution family of Leischner et al. /
//! Helman et al. plus degenerate patterns, all deterministically seeded.

use crate::util::Rng;
use crate::Key;

/// The input distributions of the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// i.i.d. uniform over the full u32 range — the paper's Figures 3–7
    /// workload and the randomized method's best case.
    Uniform,
    /// Gaussian (clamped to u32) — mild clustering.
    Gaussian,
    /// Zipf over 2^20 distinct values — heavy skew with duplicates.
    Zipf,
    /// Staggered: block-permuted ramps (the classic sample-sort stress
    /// pattern of Helman et al.).
    Staggered,
    /// Already sorted ascending.
    Sorted,
    /// Sorted with 1% random transpositions.
    NearlySorted,
    /// Reverse sorted.
    ReverseSorted,
    /// All keys equal — the degenerate duplicate case.
    AllEqual,
    /// Two interleaved values — maximal tie pressure on splitters.
    TwoValues,
}

impl Distribution {
    /// The six-distribution robustness suite (matching the spirit of
    /// [9]'s evaluation) in presentation order.
    pub const ROBUSTNESS_SUITE: [Distribution; 6] = [
        Distribution::Uniform,
        Distribution::Gaussian,
        Distribution::Zipf,
        Distribution::Staggered,
        Distribution::Sorted,
        Distribution::NearlySorted,
    ];

    /// Every distribution, including the degenerate extras.
    pub const ALL: [Distribution; 9] = [
        Distribution::Uniform,
        Distribution::Gaussian,
        Distribution::Zipf,
        Distribution::Staggered,
        Distribution::Sorted,
        Distribution::NearlySorted,
        Distribution::ReverseSorted,
        Distribution::AllEqual,
        Distribution::TwoValues,
    ];

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Distribution> {
        match s.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "uniform" => Some(Distribution::Uniform),
            "gaussian" | "normal" => Some(Distribution::Gaussian),
            "zipf" => Some(Distribution::Zipf),
            "staggered" => Some(Distribution::Staggered),
            "sorted" => Some(Distribution::Sorted),
            "nearlysorted" | "almostsorted" => Some(Distribution::NearlySorted),
            "reverse" | "reversesorted" => Some(Distribution::ReverseSorted),
            "allequal" | "equal" | "constant" => Some(Distribution::AllEqual),
            "twovalues" | "binary" => Some(Distribution::TwoValues),
            _ => None,
        }
    }

    /// Short stable identifier for CSV output.
    pub fn id(&self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Gaussian => "gaussian",
            Distribution::Zipf => "zipf",
            Distribution::Staggered => "staggered",
            Distribution::Sorted => "sorted",
            Distribution::NearlySorted => "nearly_sorted",
            Distribution::ReverseSorted => "reverse",
            Distribution::AllEqual => "all_equal",
            Distribution::TwoValues => "two_values",
        }
    }

    /// Generate `n` keys with this distribution, deterministically from
    /// `seed`.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<Key> {
        let mut rng = Rng::new(seed ^ 0xD15C0_u64.wrapping_mul(self.salt()));
        match self {
            Distribution::Uniform => (0..n).map(|_| rng.next_u32()).collect(),
            Distribution::Gaussian => {
                let mean = u32::MAX as f64 / 2.0;
                let sigma = u32::MAX as f64 / 8.0;
                (0..n)
                    .map(|_| {
                        (mean + sigma * rng.next_gaussian()).clamp(0.0, u32::MAX as f64 - 1.0)
                            as u32
                    })
                    .collect()
            }
            Distribution::Zipf => (0..n).map(|_| rng.next_zipf(1u64 << 20) as u32).collect(),
            Distribution::Staggered => {
                // Helman-style staggered: split into 2^b blocks; block i
                // contributes the ramp starting at a bit-reversed offset,
                // defeating naive regular samples of unsorted data.
                let blocks = 64usize;
                let block_len = n.div_ceil(blocks);
                let mut out = Vec::with_capacity(n);
                for b in 0..blocks {
                    let rev = (b as u32).reverse_bits() >> (32 - 6);
                    let base = (rev as u64 * (u32::MAX as u64) / blocks as u64) as u32;
                    for i in 0..block_len {
                        if out.len() == n {
                            break;
                        }
                        out.push(base.wrapping_add((i as u32).wrapping_mul(2654435761) % 65536));
                    }
                }
                out
            }
            Distribution::Sorted => {
                let mut v: Vec<Key> = (0..n).map(|_| rng.next_u32()).collect();
                v.sort_unstable();
                v
            }
            Distribution::NearlySorted => {
                let mut v: Vec<Key> = (0..n).map(|_| rng.next_u32()).collect();
                v.sort_unstable();
                let swaps = n / 100;
                for _ in 0..swaps {
                    let i = rng.gen_range(n);
                    let j = rng.gen_range(n);
                    v.swap(i, j);
                }
                v
            }
            Distribution::ReverseSorted => {
                let mut v: Vec<Key> = (0..n).map(|_| rng.next_u32()).collect();
                v.sort_unstable();
                v.reverse();
                v
            }
            Distribution::AllEqual => vec![0xCAFE_F00D; n],
            Distribution::TwoValues => (0..n).map(|i| if i % 2 == 0 { 10 } else { 20 }).collect(),
        }
    }

    fn salt(&self) -> u64 {
        match self {
            Distribution::Uniform => 1,
            Distribution::Gaussian => 2,
            Distribution::Zipf => 3,
            Distribution::Staggered => 4,
            Distribution::Sorted => 5,
            Distribution::NearlySorted => 6,
            Distribution::ReverseSorted => 7,
            Distribution::AllEqual => 8,
            Distribution::TwoValues => 9,
        }
    }
}

impl std::fmt::Display for Distribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        for d in Distribution::ALL {
            let a = d.generate(1000, 7);
            let b = d.generate(1000, 7);
            assert_eq!(a, b, "{d}");
            assert_eq!(a.len(), 1000);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = Distribution::Uniform.generate(1000, 1);
        let b = Distribution::Uniform.generate(1000, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn sorted_is_sorted() {
        assert!(crate::is_sorted(&Distribution::Sorted.generate(5000, 3)));
        let rev = Distribution::ReverseSorted.generate(5000, 3);
        assert!(rev.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn nearly_sorted_is_mostly_sorted() {
        let v = Distribution::NearlySorted.generate(10_000, 3);
        let inversions = v.windows(2).filter(|w| w[0] > w[1]).count();
        assert!(inversions > 0, "should not be fully sorted");
        assert!(inversions < 500, "should be mostly sorted, got {inversions}");
    }

    #[test]
    fn zipf_is_skewed() {
        let v = Distribution::Zipf.generate(100_000, 3);
        let ones = v.iter().filter(|&&x| x == 1).count();
        // Zipf s=1 over 2^20 values: value 1 has probability ~1/H ≈ 7%.
        assert!(ones > 2_000, "zipf head too light: {ones}");
    }

    #[test]
    fn gaussian_is_centered() {
        let v = Distribution::Gaussian.generate(100_000, 3);
        let mid = u32::MAX / 2;
        let within = v
            .iter()
            .filter(|&&x| x > mid / 2 && x < mid + mid / 2)
            .count();
        assert!(within > 90_000, "gaussian not clustered: {within}");
    }

    #[test]
    fn two_values_and_equal() {
        let v = Distribution::TwoValues.generate(100, 0);
        assert!(v.iter().all(|&x| x == 10 || x == 20));
        let e = Distribution::AllEqual.generate(100, 0);
        assert!(e.iter().all(|&x| x == e[0]));
    }

    #[test]
    fn staggered_covers_range() {
        let v = Distribution::Staggered.generate(64 * 100, 0);
        let lo = v.iter().filter(|&&x| x < u32::MAX / 4).count();
        let hi = v.iter().filter(|&&x| x > 3 * (u32::MAX / 4)).count();
        assert!(lo > 0 && hi > 0, "staggered should span the range");
    }

    #[test]
    fn parse_roundtrip() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::parse(d.id()), Some(d), "{d}");
        }
        assert_eq!(Distribution::parse("bogus"), None);
    }
}

//! The execution planner: pass schedules for the wide-digit LSD
//! kernel.
//!
//! The paper's bound is a *fixed number of regular passes* over the
//! data; the planner makes the executed host path honour that shape as
//! tightly as the key width allows. Given the element's bit width, the
//! run length and a cheap **digit-occupancy sketch** of the data, it
//! emits a [`SortPlan`]: the list of LSD counting passes the kernel
//! actually executes.
//!
//! Three mechanisms shrink the pass count below the byte-wise kernel's
//! `WIDTH_BYTES` passes:
//!
//! * **Wide digits** — `digit_bits` (default [`DEFAULT_DIGIT_BITS`] =
//!   11) bits per pass instead of 8: ⌈32/11⌉ = 3 passes for `u32`
//!   instead of 4, ⌈64/11⌉ = 6 for `u64` instead of 8. 2^11 = 2048
//!   counting bins still fit comfortably in an L1/shared-memory-sized
//!   table — the same tradeoff Satish et al.'s GPU radix [14] makes
//!   with its multi-bit digits.
//! * **Constant-digit skipping** — a digit position whose bits are
//!   identical across the whole input contributes nothing to the order;
//!   its pass is elided. This generalizes the byte-wise kernel's
//!   constant-*byte* skip to arbitrary digit boundaries. Skips are
//!   decided from an exact bit-occupancy mask (`OR` and `AND` of every
//!   element's bits): a bit varies iff `OR ^ AND` has it set.
//! * **Sampled sketch first** — a small equidistant sample is scanned
//!   before the full input. Two sampled elements differing inside a
//!   digit *prove* the digit varies, so when the sketch already proves
//!   every digit varies (the common case for uniform-ish data) the full
//!   occupancy scan is skipped entirely and planning costs O(sample).
//!   Only low-entropy inputs pay the one confirming read pass — and
//!   they earn it back multiple times in skipped passes.
//!
//! [`execute`] runs a plan by **ping-ponging** between the input and
//! one arena scratch buffer: each pass scatters `src → dst` and the
//! roles swap, with a single final copy-back only when the executed
//! pass count is odd. A prebuilt first-pass histogram (from the fused
//! Step-8 relocation scatter, see
//! [`crate::algos::relocation::relocate_with_prep`]) lets the first
//! pass skip its counting traversal.
//!
//! The plan affects wall time only, never bytes: every pass is a stable
//! scatter over the ordered bit pattern, so any schedule produces the
//! unique sorted sequence — property-tested against the comparison
//! order in `rust/tests/prop_kernels.rs`. The traffic ledger never sees
//! the planner (it keeps recording the paper's analytic figures).

use crate::SortKey;

/// Default digit width in bits (2^11 = 2048 counting bins; 3 passes
/// over `u32`).
pub const DEFAULT_DIGIT_BITS: u32 = 11;

/// Narrowest supported digit.
pub const MIN_DIGIT_BITS: u32 = 1;

/// Widest supported digit (65 536 bins — beyond this the counting
/// table stops fitting in cache and wider stops paying).
pub const MAX_DIGIT_BITS: u32 = 16;

/// Elements sampled by the occupancy sketch.
const SKETCH_SAMPLES: usize = 128;

/// Below this length the sketch scans exactly instead of sampling: a
/// tiny input can't amortize a wrong hint, and at these sizes the
/// sample grid covers most of the data anyway, so the exact scan costs
/// nearly the same and can never produce a bogus "everything constant"
/// reading.
const SKETCH_EXACT_MAX: usize = 256;

/// Widest element the occupancy mask covers ([`crate::Record`] over
/// `Segmented<u64>` is 16 bytes).
const MAX_WIDTH_BYTES: usize = 16;

/// Validate a digit width from config/CLI.
pub fn validate_digit_bits(bits: u32) -> crate::error::Result<()> {
    if !(MIN_DIGIT_BITS..=MAX_DIGIT_BITS).contains(&bits) {
        return Err(crate::Error::InvalidParams(format!(
            "digit_bits must be in {MIN_DIGIT_BITS}..={MAX_DIGIT_BITS}, got {bits}"
        )));
    }
    Ok(())
}

/// Per-bit occupancy of a key set: which bit positions actually vary.
///
/// `or[i]` and `and[i]` accumulate byte `i` of every element's ordered
/// bit pattern; bit `b` of byte `i` is **constant** across the set iff
/// the two agree there. Accumulated over a sample, a differing bit is a
/// *proof* of variation (two witnesses exist) while an agreeing bit is
/// merely unproven.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Occupancy {
    or: [u8; MAX_WIDTH_BYTES],
    and: [u8; MAX_WIDTH_BYTES],
}

impl Occupancy {
    fn empty() -> Occupancy {
        Occupancy {
            or: [0; MAX_WIDTH_BYTES],
            and: [0xFF; MAX_WIDTH_BYTES],
        }
    }

    /// Exact occupancy: one read pass over the whole input.
    pub fn scan<K: SortKey>(data: &[K]) -> Occupancy {
        debug_assert!(K::WIDTH_BYTES <= MAX_WIDTH_BYTES);
        let mut occ = Occupancy::empty();
        for x in data {
            occ.accumulate(*x);
        }
        occ
    }

    /// Sampled occupancy: up to [`SKETCH_SAMPLES`] equidistant
    /// elements. O(1) in the input size. Inputs of [`SKETCH_EXACT_MAX`]
    /// elements or fewer take the exact [`Occupancy::scan`] instead —
    /// sampling a tiny run saves nothing and risks a misleadingly
    /// constant-looking hint.
    pub fn sketch<K: SortKey>(data: &[K]) -> Occupancy {
        if data.len() <= SKETCH_EXACT_MAX {
            return Occupancy::scan(data);
        }
        let mut occ = Occupancy::empty();
        let stride = (data.len() / SKETCH_SAMPLES).max(1);
        for x in data.iter().step_by(stride) {
            occ.accumulate(*x);
        }
        occ
    }

    #[inline]
    fn accumulate<K: SortKey>(&mut self, x: K) {
        for i in 0..K::WIDTH_BYTES {
            let b = x.radix_byte(i);
            self.or[i] |= b;
            self.and[i] &= b;
        }
    }

    /// True when some bit in `[bit_offset, bit_offset + bits)` differs
    /// across the accumulated elements.
    pub fn varies(&self, bit_offset: u32, bits: u32) -> bool {
        (bit_offset..bit_offset + bits).any(|b| {
            let (byte, bit) = (b as usize / 8, b % 8);
            byte < MAX_WIDTH_BYTES && (self.or[byte] ^ self.and[byte]) >> bit & 1 == 1
        })
    }

    /// Bit positions within the first `width_bytes` bytes proven to
    /// differ across the accumulated elements — the adaptive front-end's
    /// bit-occupancy summary.
    pub fn varying_bits(&self, width_bytes: usize) -> u32 {
        (0..width_bytes.min(MAX_WIDTH_BYTES))
            .map(|i| (self.or[i] ^ self.and[i]).count_ones())
            .sum()
    }
}

/// One executed LSD pass: the digit at `bit_offset`, `bits` wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DigitPass {
    /// Least-significant bit of the digit within the ordered pattern.
    pub bit_offset: u32,
    /// Digit width (≤ `digit_bits`; the top pass may be narrower).
    pub bits: u32,
}

/// A pass schedule for one run: the executed passes in LSD order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SortPlan {
    /// Digit width the schedule was planned at.
    pub digit_bits: u32,
    /// Executed passes (constant digits already elided).
    pub passes: Vec<DigitPass>,
    /// Digit positions the element width implies before skipping.
    pub nominal_passes: usize,
}

impl SortPlan {
    /// Passes elided by the occupancy analysis.
    pub fn skipped(&self) -> usize {
        self.nominal_passes - self.passes.len()
    }
}

/// Build the schedule for width-`K` elements from an **exact**
/// occupancy: one pass per `digit_bits`-wide digit, constant digits
/// elided.
pub fn plan_from_occupancy<K: SortKey>(occ: &Occupancy, digit_bits: u32) -> SortPlan {
    let digit_bits = digit_bits.clamp(MIN_DIGIT_BITS, MAX_DIGIT_BITS);
    let width_bits = 8 * K::WIDTH_BYTES as u32;
    let nominal = width_bits.div_ceil(digit_bits) as usize;
    let passes = (0..nominal as u32)
        .map(|p| {
            let bit_offset = p * digit_bits;
            DigitPass {
                bit_offset,
                bits: digit_bits.min(width_bits - bit_offset),
            }
        })
        .filter(|pass| occ.varies(pass.bit_offset, pass.bits))
        .collect();
    SortPlan {
        digit_bits,
        passes,
        nominal_passes: nominal,
    }
}

/// Plan a run: sketch first, full scan only when the sketch leaves some
/// digit unproven. Either way the resulting plan is exact — a pass is
/// elided only when its digit is constant across the *whole* input.
pub fn plan_for<K: SortKey>(data: &[K], digit_bits: u32) -> SortPlan {
    let digit_bits = digit_bits.clamp(MIN_DIGIT_BITS, MAX_DIGIT_BITS);
    let sketch = Occupancy::sketch(data);
    let sketch_plan = plan_from_occupancy::<K>(&sketch, digit_bits);
    if sketch_plan.skipped() == 0 {
        // The sample already proved every digit varies — the full scan
        // could not add a skip.
        return sketch_plan;
    }
    plan_from_occupancy::<K>(&Occupancy::scan(data), digit_bits)
}

/// Execute a plan over `data`, ping-ponging with `scratch` (resized to
/// `data.len()`). `counts` is the recycled histogram table
/// (`2^digit_bits` bins). `prebuilt` optionally carries the first
/// pass's already-accumulated histogram — it is consumed only when the
/// plan's first pass is the bit-0 digit of matching width (a fused
/// producer cannot know in advance whether that digit survives
/// planning).
pub fn execute<K: SortKey>(
    data: &mut [K],
    scratch: &mut Vec<K>,
    counts: &mut Vec<usize>,
    plan: &SortPlan,
    prebuilt: Option<&[usize]>,
) {
    let n = data.len();
    if n <= 1 || plan.passes.is_empty() {
        return;
    }
    scratch.clear();
    scratch.resize(n, data[0]);
    let mut flipped = false;
    for (i, pass) in plan.passes.iter().enumerate() {
        let radix = 1usize << pass.bits;
        counts.clear();
        counts.resize(radix, 0);
        let prebuilt_ok = i == 0
            && pass.bit_offset == 0
            && matches!(prebuilt, Some(p) if p.len() == radix);
        if prebuilt_ok {
            counts.copy_from_slice(prebuilt.expect("checked above"));
        } else {
            let src: &[K] = if flipped { scratch } else { data };
            for x in src {
                counts[x.radix_digit(pass.bit_offset, pass.bits)] += 1;
            }
        }
        // Exclusive prefix sum → per-digit cursors.
        let mut acc = 0usize;
        for c in counts.iter_mut() {
            let t = *c;
            *c = acc;
            acc += t;
        }
        // Stable scatter src → dst.
        if flipped {
            scatter(scratch, data, pass, counts);
        } else {
            scatter(data, scratch, pass, counts);
        }
        flipped = !flipped;
    }
    if flipped {
        data.copy_from_slice(scratch);
    }
}

#[inline]
fn scatter<K: SortKey>(src: &[K], dst: &mut [K], pass: &DigitPass, starts: &mut [usize]) {
    for &x in src {
        let d = x.radix_digit(pass.bit_offset, pass.bits);
        dst[starts[d]] = x;
        starts[d] += 1;
    }
}

/// The planned wide-digit sort — the [`crate::KernelKind::Radix`]
/// kernel behind every executed tile, bucket and chunk sort. `scratch`
/// and `counts` are recycled buffers (arena checkouts on the hot path);
/// `prebuilt` is the optional fused first-pass histogram.
///
/// Runs below [`crate::algos::radix::RADIX_MIN_N`] take the comparison
/// path — identical output, and the per-pass fixed costs (bin clear +
/// prefix) would dominate there.
pub fn planned_sort<K: SortKey>(
    data: &mut [K],
    scratch: &mut Vec<K>,
    counts: &mut Vec<usize>,
    digit_bits: u32,
    prebuilt: Option<&[usize]>,
) {
    if data.len() <= 1 {
        return;
    }
    if data.len() < super::radix::RADIX_MIN_N {
        data.sort_unstable_by(K::key_cmp);
        return;
    }
    let plan = plan_for(data, digit_bits);
    execute(data, scratch, counts, &plan, prebuilt);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Record;

    fn scrambled(n: usize) -> Vec<u32> {
        (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect()
    }

    #[test]
    fn u32_default_plan_is_three_passes() {
        let keys = scrambled(10_000);
        let plan = plan_for(&keys, DEFAULT_DIGIT_BITS);
        assert_eq!(plan.nominal_passes, 3);
        assert_eq!(plan.passes.len(), 3);
        assert_eq!(plan.skipped(), 0);
        // Digit boundaries tile the 32 bits: 11 + 11 + 10.
        assert_eq!(
            plan.passes,
            vec![
                DigitPass { bit_offset: 0, bits: 11 },
                DigitPass { bit_offset: 11, bits: 11 },
                DigitPass { bit_offset: 22, bits: 10 },
            ]
        );
    }

    #[test]
    fn constant_digits_are_skipped_exactly() {
        // Keys in [0, 128): everything above bit 7 is constant.
        let keys: Vec<u32> = (0..5000u32).map(|x| x % 128).collect();
        let plan = plan_for(&keys, 8);
        assert_eq!(plan.nominal_passes, 4);
        assert_eq!(plan.passes.len(), 1);
        assert_eq!(plan.passes[0], DigitPass { bit_offset: 0, bits: 8 });

        // A single constant key needs no pass at all.
        let plan = plan_for(&vec![42u32; 1000], 11);
        assert!(plan.passes.is_empty());
        assert_eq!(plan.skipped(), 3);
    }

    #[test]
    fn sketch_proof_skips_the_full_scan_safely() {
        // A value varying only outside the sketch's sample positions
        // must still be caught: the plan is exact, not probabilistic.
        let mut keys = vec![7u32; 100_000];
        keys[1] = 0xFFFF_FFFF; // off the equidistant sample grid
        let plan = plan_for(&keys, 11);
        assert_eq!(plan.passes.len(), 3, "high bits vary in one element");
        let mut sorted = keys.clone();
        let (mut scratch, mut counts) = (Vec::new(), Vec::new());
        planned_sort(&mut sorted, &mut scratch, &mut counts, 11, None);
        let mut expect = keys;
        expect.sort_unstable();
        assert_eq!(sorted, expect);
    }

    #[test]
    fn planned_sort_matches_comparison_across_digit_widths() {
        let input = scrambled(20_000);
        let mut expect = input.clone();
        expect.sort_unstable();
        for bits in [1u32, 4, 8, 11, 13, 16] {
            let mut keys = input.clone();
            let (mut scratch, mut counts) = (Vec::new(), Vec::new());
            planned_sort(&mut keys, &mut scratch, &mut counts, bits, None);
            assert_eq!(keys, expect, "digit_bits={bits}");
        }
    }

    #[test]
    fn records_sort_by_key_then_index_under_any_digit_width() {
        let recs: Vec<Record<u32>> = (0..4000u32)
            .map(|i| Record {
                key: i.wrapping_mul(2654435761) % 16,
                idx: i,
            })
            .collect();
        let mut expect = recs.clone();
        expect.sort_unstable_by(<Record<u32>>::key_cmp);
        for bits in [8u32, 11] {
            let mut a = recs.clone();
            let (mut scratch, mut counts) = (Vec::new(), Vec::new());
            planned_sort(&mut a, &mut scratch, &mut counts, bits, None);
            assert_eq!(a, expect, "digit_bits={bits}");
        }
    }

    #[test]
    fn prebuilt_first_pass_histogram_is_honoured() {
        let keys = scrambled(8192);
        let plan = plan_for(&keys, DEFAULT_DIGIT_BITS);
        // Accumulate the digit-0 histogram the way the fused relocation
        // scatter does.
        let mut hist = vec![0usize; 1 << DEFAULT_DIGIT_BITS];
        for &x in &keys {
            hist[SortKey::radix_digit(x, 0, DEFAULT_DIGIT_BITS)] += 1;
        }
        let mut with = keys.clone();
        let (mut s1, mut c1) = (Vec::new(), Vec::new());
        execute(&mut with, &mut s1, &mut c1, &plan, Some(&hist));
        let mut without = keys.clone();
        let (mut s2, mut c2) = (Vec::new(), Vec::new());
        execute(&mut without, &mut s2, &mut c2, &plan, None);
        assert_eq!(with, without);
        let mut expect = keys;
        expect.sort_unstable();
        assert_eq!(with, expect);
    }

    #[test]
    fn mismatched_prebuilt_is_ignored_not_trusted() {
        // A histogram of the wrong arity (planned at different digit
        // bits) must be rejected by the length check.
        let keys = scrambled(4096);
        let plan = plan_for(&keys, 11);
        let bogus = vec![1usize; 256]; // 8-bit arity
        let mut sorted = keys.clone();
        let (mut scratch, mut counts) = (Vec::new(), Vec::new());
        execute(&mut sorted, &mut scratch, &mut counts, &plan, Some(&bogus));
        let mut expect = keys;
        expect.sort_unstable();
        assert_eq!(sorted, expect);
    }

    #[test]
    fn tiny_sketch_is_exact() {
        // Below SKETCH_EXACT_MAX the sketch must equal the full scan:
        // a sampled sketch of a tiny input could otherwise report a
        // bogus "everything constant" hint.
        for n in [0usize, 1, 2, 100, 255, 256] {
            let data: Vec<u32> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();
            assert_eq!(Occupancy::sketch(&data), Occupancy::scan(&data), "n={n}");
        }
        // Just above the threshold the sampled path resumes (and stays
        // a sound over-approximation of constancy: proven-varying bits
        // are a subset of the scan's).
        let data: Vec<u32> = (0..1000u32).map(|x| x.wrapping_mul(2654435761)).collect();
        let (sk, sc) = (Occupancy::sketch(&data), Occupancy::scan(&data));
        assert!(sk.varying_bits(4) <= sc.varying_bits(4));
    }

    #[test]
    fn varying_bits_counts_proven_positions() {
        let occ = Occupancy::scan(&[0u32, 0b1011]);
        assert_eq!(occ.varying_bits(4), 3);
        assert_eq!(Occupancy::scan(&[7u32; 50]).varying_bits(4), 0);
        assert_eq!(Occupancy::scan(&[0u32, u32::MAX]).varying_bits(4), 32);
    }

    #[test]
    fn digit_bits_validation() {
        assert!(validate_digit_bits(0).is_err());
        assert!(validate_digit_bits(1).is_ok());
        assert!(validate_digit_bits(11).is_ok());
        assert!(validate_digit_bits(16).is_ok());
        assert!(validate_digit_bits(17).is_err());
    }
}

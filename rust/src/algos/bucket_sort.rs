//! GPU BUCKET SORT — Algorithm 1 of the paper, end to end.
//!
//! The deterministic sample sort: local bitonic sort of shared-memory
//! tiles (Step 2), regular sampling (Steps 3–5), deterministic bucket
//! formation with guaranteed sizes (Steps 6–7), coalesced relocation
//! (Step 8), and per-bucket bitonic sort (Step 9). Determinism is the
//! headline property: bucket sizes are *guaranteed* (|B_j| ≤ 2n/s, Shi &
//! Schaeffer [15]), so the running time does not fluctuate with the
//! input distribution — unlike the randomized sample sort of Leischner
//! et al. [9].
//!
//! Two entry points:
//! * [`BucketSort::sort`] — executes the algorithm for real on host
//!   memory while recording the exact GPU traffic ledger;
//! * [`BucketSort::sort_analytic`] — produces the identical ledger from
//!   closed forms without touching data, enabling the paper-scale
//!   (up to 512M keys) configurations of Figures 3–7.
//!
//! Buckets are sorted at their *guaranteed capacity* (next power of two
//! of 2n/s, padded with the key type's [`crate::SortKey::PAD`]
//! sentinel) rather than their data-dependent actual size — this is
//! precisely what makes the deterministic variant's runtime
//! input-independent (§5: "<1 ms observed variance"), and is also the
//! shape the fixed-shape XLA/PJRT pipeline compiles AOT.
//!
//! Both entry points are generic over [`crate::SortKey`] (`u32`, `u64`,
//! `i32`, `i64`, `f32` under IEEE-754 total order), and
//! [`BucketSort::sort_pairs`] runs the same pipeline over
//! [`crate::Record`]s for key–value jobs. The `u32` path is
//! byte-identical to the historical `Key = u32` implementation.

use super::{bitonic, indexing, local_sort, plan, prefix, relocation, sampling};
use super::{ExecContext, KernelKind};
use crate::error::Result;
use crate::key::Record;
use crate::sim::ledger::Ledger;
use crate::sim::spec::GpuSpec;
use crate::sim::{CostModel, GpuSim};
use crate::util::pool;
use crate::{SortKey, KEY_BYTES};
use std::collections::BTreeMap;

/// Tunable parameters of Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketSortParams {
    /// Sublist (tile) size n/m in keys — the shared-memory capacity of
    /// one SM (2K items for the 16 KB of Table 1 hardware). Power of
    /// two.
    pub tile: usize,
    /// Sample count s — the free parameter studied in Figure 3; the
    /// paper's production choice is s = 64. Must divide `tile`.
    pub s: usize,
}

impl Default for BucketSortParams {
    fn default() -> Self {
        BucketSortParams { tile: 2048, s: 64 }
    }
}

impl BucketSortParams {
    /// Validate the parameter combination.
    pub fn validate(&self) -> Result<()> {
        if !self.tile.is_power_of_two() {
            return Err(crate::Error::InvalidParams(format!(
                "tile must be a power of two, got {}",
                self.tile
            )));
        }
        if self.s == 0 || self.s > self.tile || self.tile % self.s != 0 {
            return Err(crate::Error::InvalidParams(format!(
                "s must satisfy 1 <= s <= tile and s | tile, got s={} tile={}",
                self.s, self.tile
            )));
        }
        Ok(())
    }

    /// Guaranteed per-bucket capacity for an (already tile-aligned)
    /// input of `padded_n` keys: next power of two of 2n/s.
    pub fn bucket_capacity(&self, padded_n: usize) -> usize {
        if padded_n == 0 || self.s == 0 {
            return 0;
        }
        bitonic::next_pow2((2 * padded_n).div_ceil(self.s))
    }
}

/// Everything recorded about one run of Algorithm 1.
#[derive(Debug, Clone)]
pub struct BucketSortReport {
    /// Requested key count.
    pub n: usize,
    /// Tile-aligned key count actually processed (MAX-padded).
    pub padded_n: usize,
    /// Number of sublists m.
    pub m: usize,
    /// Sample count s.
    pub s: usize,
    /// Per-launch traffic, tagged with Algorithm-1 step numbers.
    pub ledger: Ledger,
    /// Peak simulated device memory during the run.
    pub peak_device_bytes: usize,
    /// Largest actual bucket observed (`0` for analytic runs) — the
    /// deterministic guarantee is ≤ 2·padded_n/s.
    pub max_bucket: u64,
}

impl BucketSortReport {
    /// Estimated total milliseconds on `spec` with the calibrated cost
    /// model.
    pub fn total_estimated_ms(&self, spec: &GpuSpec) -> f64 {
        CostModel::default_params(spec).ledger_ms(&self.ledger)
    }

    /// Estimated per-step milliseconds (the Figure 5 series).
    pub fn step_ms(&self, spec: &GpuSpec) -> BTreeMap<u8, f64> {
        CostModel::default_params(spec).step_ms(&self.ledger)
    }

    /// Sorting rate in Mkeys/s on `spec` (§5's flat-rate metric).
    pub fn sort_rate_mkeys_s(&self, spec: &GpuSpec) -> f64 {
        CostModel::sort_rate_mkeys_s(self.n, self.total_estimated_ms(spec))
    }
}

/// The deterministic sample sorter.
#[derive(Debug, Clone)]
pub struct BucketSort {
    params: BucketSortParams,
}

impl BucketSort {
    /// Construct with the given parameters (panics on invalid ones; use
    /// [`BucketSort::try_new`] for fallible construction).
    pub fn new(params: BucketSortParams) -> Self {
        params.validate().expect("invalid BucketSortParams");
        BucketSort { params }
    }

    /// Fallible constructor.
    pub fn try_new(params: BucketSortParams) -> Result<Self> {
        params.validate()?;
        Ok(BucketSort { params })
    }

    /// The parameters in use.
    pub fn params(&self) -> &BucketSortParams {
        &self.params
    }

    /// Sort `keys` in place on the simulated device, recording traffic
    /// and enforcing the device's memory capacity. Generic over
    /// [`SortKey`]: ordering is by key bits, padding uses the type's own
    /// sentinel, and the ledger's traffic/memory accounting scales with
    /// [`SortKey::WIDTH_BYTES`]. Uses a transient default
    /// [`ExecContext`]; the service engines pass a persistent one
    /// through [`BucketSort::sort_in`] so their steady state allocates
    /// nothing.
    pub fn sort<K: SortKey>(&self, keys: &mut [K], sim: &mut GpuSim) -> Result<BucketSortReport> {
        self.sort_in(keys, sim, &ExecContext::default())
    }

    /// [`BucketSort::sort`] with explicit execution resources: every
    /// working buffer (tile-aligned work array, sample array, boundary
    /// and count matrices, relocation target, Step-9 scratch) is checked
    /// out of `ctx.arena`, Steps 2 and 9 run on the resident worker pool
    /// over disjoint regions (byte-identical output at any worker
    /// count), and `ctx.kernel` selects the executed tile/bucket kernel.
    /// The recorded ledger is independent of both the kernel and the
    /// worker count — it stays the paper's bitonic analytics.
    pub fn sort_in<K: SortKey>(
        &self,
        keys: &mut [K],
        sim: &mut GpuSim,
        ctx: &ExecContext,
    ) -> Result<BucketSortReport> {
        let n = keys.len();
        let (tile, s) = (self.params.tile, self.params.s);
        if n == 0 {
            return Ok(self.empty_report());
        }
        let elem_bytes = K::WIDTH_BYTES;

        // Step 1: split into m tile-sized sublists (pad with PAD).
        //
        // Device memory: exactly two n-key buffers (input + relocation
        // target), allocated up front. The paper's ceilings (256M keys
        // in 2 GiB, 512M in 4 GiB = exactly 2·n·4 B) prove the original
        // implementation holds nothing else at peak — every auxiliary
        // array (samples, boundary/location matrices, Step-9 scratch)
        // lives inside whichever big buffer is dead in that phase; the
        // assertion below checks that overlay always fits.
        let padded_n = n.div_ceil(tile) * tile;
        let m = padded_n / tile;
        let input_alloc = sim.alloc(padded_n * elem_bytes)?;
        let out_alloc = sim.alloc(padded_n * elem_bytes)?;
        let cap = self.params.bucket_capacity(padded_n);
        // At paper scale the aux overlay vanishes inside a dead buffer;
        // for toy inputs (n within a few tiles) it can exceed one, and
        // the excess is charged as a real allocation.
        let aux_alloc = sim.alloc(
            aux_overlay_bytes(m, s, cap, elem_bytes).saturating_sub(padded_n * elem_bytes),
        )?;
        let mut work = ctx.arena.take_from(keys);
        work.resize(padded_n, K::PAD);

        let mut ledger = Ledger::default();

        // Steps 2+3, fused: each worker sorts a sublist on one SM and
        // extracts its s equidistant samples while the tile is still
        // hot — the separate sampling traversal of the unfused path
        // disappears. The ledger still records the paper's two launches
        // (Step 2 local sort, Step 3 sampling), byte-identical to the
        // analytic twin. (Samples overlay the not-yet-used relocation
        // buffer in the device model.)
        let mut samples = ctx.arena.take_empty::<K>();
        local_sort::run_sampled(work.as_mut_slice(), tile, s, ctx, &mut samples, &mut ledger);

        // Step 4: sort all s·m samples globally (bitonic, padded to a
        // power of two).
        let padded_samples = bitonic::next_pow2(samples.len());
        samples.resize(padded_samples, K::PAD);
        bitonic::global_sort(samples.as_mut_slice(), tile, &mut ledger, 4);

        // Step 5: s equidistant global samples → s−1 splitters.
        let splitters = sampling::select_splitters(samples.as_slice(), s, &mut ledger);

        // Step 6: locate every splitter in every sublist.
        let mut bounds = ctx.arena.take_empty::<u32>();
        indexing::boundaries_into(work.as_slice(), tile, &splitters, &mut bounds, &mut ledger);
        drop(samples); // dead after Step 6 (returns to the arena)

        // Step 7: column-major prefix sum → bucket locations.
        let mut counts = ctx.arena.take_empty::<u32>();
        counts.reserve(m * s);
        for row in bounds.chunks_exact(s) {
            let mut prev = 0u32;
            for &b in row {
                counts.push(b - prev);
                prev = b;
            }
        }
        let layout = prefix::column_prefix(counts.as_slice(), m, s, &mut ledger);

        // Step 8: relocate all buckets (coalesced read + write). On the
        // radix path the scatter simultaneously accumulates each
        // bucket's first-pass digit histogram, so the Step-9 sorts
        // start with pass 1 prebuilt (one fewer traversal per bucket).
        let mut relocated = ctx.arena.take(padded_n, K::PAD);
        let digit_bits = ctx.digit_bits.clamp(plan::MIN_DIGIT_BITS, plan::MAX_DIGIT_BITS);
        let prep_radix = 1usize << digit_bits;
        let mut prep_counts = match ctx.kernel {
            KernelKind::Radix | KernelKind::Adaptive => Some(ctx.arena.take_empty::<usize>()),
            KernelKind::Bitonic => None,
        };
        match prep_counts.as_mut() {
            Some(counts) => relocation::relocate_with_prep(
                work.as_slice(),
                tile,
                bounds.as_slice(),
                &layout,
                relocated.as_mut_slice(),
                &mut ledger,
                digit_bits,
                counts,
            ),
            None => relocation::relocate(
                work.as_slice(),
                tile,
                bounds.as_slice(),
                &layout,
                relocated.as_mut_slice(),
                &mut ledger,
            ),
        }

        // Step 9: sort every sublist B_j (buckets in parallel over
        // disjoint regions of the relocated array, scratch per worker
        // from the arena — overlaid on the now-dead input buffer in the
        // device model).
        //
        // Cost model: each sort is priced at the *balanced* sublist
        // size padded_n/s under virtual padding (predicated
        // compare-exchanges against virtual PAD keys touch no memory) —
        // the uniform-data cost, which the deterministic bound keeps
        // within 2× for any input. This keeps the ledger
        // input-independent, the paper's determinism claim. Physically
        // the bitonic kernel sorts the full capacity so any actual size
        // ≤ cap (or beyond, for tie-degenerate inputs) stays correct;
        // the radix kernel sorts each bucket's actual length, which
        // yields the same (unique) sorted output.
        let max_bucket = layout.max_bucket();
        let balanced = padded_n / s;
        {
            let prep = prep_counts.as_deref();
            let mut slices: Vec<&mut [K]> = Vec::with_capacity(s);
            let mut rest: &mut [K] = relocated.as_mut_slice();
            for j in 0..s {
                let len = layout.bucket_size[j] as usize;
                debug_assert_eq!(layout.bucket_start[j] as usize, padded_n - rest.len());
                let (head, tail) = rest.split_at_mut(len);
                slices.push(head);
                rest = tail;
            }
            debug_assert!(rest.is_empty(), "buckets must tile the padded array");
            pool::parallel_slices_mut(slices, ctx.effective_workers(), |j, b| {
                let prebuilt = prep.map(|c| &c[j * prep_radix..(j + 1) * prep_radix]);
                sort_bucket(b, cap, ctx, prebuilt);
            });
        }
        for _ in 0..s {
            bitonic::global_sort_virtual_bytes(balanced, tile, elem_bytes, &mut ledger, 9);
        }

        keys.copy_from_slice(&relocated[..n]);

        let peak = sim.peak_bytes();
        sim.free(aux_alloc);
        sim.free(out_alloc);
        sim.free(input_alloc);
        sim.ledger_mut().extend_from(&ledger);

        Ok(BucketSortReport {
            n,
            padded_n,
            m,
            s,
            ledger,
            peak_device_bytes: peak,
            max_bucket,
        })
    }

    /// Sort a key–value job: `keys` in place, `payload` permuted so
    /// `payload[i]` still belongs to `keys[i]` afterwards. Runs the
    /// full Algorithm 1 over [`Record`]s — Steps 6–8 carry the payload
    /// index alongside the key, ties break by original position (so the
    /// result is stable and byte-deterministic), and the ledger prices
    /// the widened `key + 4 B` elements.
    pub fn sort_pairs<K: SortKey>(
        &self,
        keys: &mut [K],
        payload: &mut Vec<u64>,
        sim: &mut GpuSim,
    ) -> Result<BucketSortReport> {
        self.sort_pairs_in(keys, payload, sim, &ExecContext::default())
    }

    /// [`BucketSort::sort_pairs`] with explicit execution resources:
    /// the record vector and the payload permutation staging both come
    /// from the context's arena.
    pub fn sort_pairs_in<K: SortKey>(
        &self,
        keys: &mut [K],
        payload: &mut Vec<u64>,
        sim: &mut GpuSim,
        ctx: &ExecContext,
    ) -> Result<BucketSortReport> {
        crate::key::validate_key_value(keys.len(), payload.len())?;
        let mut recs = ctx.arena.take_empty::<Record<K>>();
        crate::key::tag_records_into(keys, &mut recs)?;
        let report = self.sort_in(recs.as_mut_slice(), sim, ctx)?;
        crate::key::untag_records_in(recs.as_slice(), keys, payload, &ctx.arena);
        Ok(report)
    }

    /// Produce the ledger and memory profile of sorting `n` keys without
    /// touching data — identical launches to [`BucketSort::sort`] at the
    /// classic `u32` width.
    pub fn sort_analytic(&self, n: usize, sim: &mut GpuSim) -> Result<BucketSortReport> {
        self.sort_analytic_bytes(n, KEY_BYTES, sim)
    }

    /// Ledger-only twin of [`BucketSort::sort`] at an explicit
    /// per-element width (`<K as SortKey>::WIDTH_BYTES`, plus 4 for the
    /// payload index of a key–value job) — identical launches to the
    /// executing path under the balanced-bucket assumption (every B_j
    /// at its guaranteed capacity, which is exactly how the executing
    /// path sorts them).
    pub fn sort_analytic_bytes(
        &self,
        n: usize,
        elem_bytes: usize,
        sim: &mut GpuSim,
    ) -> Result<BucketSortReport> {
        let (tile, s) = (self.params.tile, self.params.s);
        if n == 0 {
            return Ok(self.empty_report());
        }
        let padded_n = n.div_ceil(tile) * tile;
        let m = padded_n / tile;
        let mut ledger = Ledger::default();

        // Same two-buffer memory model as `sort` (aux overlaid).
        let input_alloc = sim.alloc(padded_n * elem_bytes)?;
        let out_alloc = sim.alloc(padded_n * elem_bytes)?;
        let cap = self.params.bucket_capacity(padded_n);
        let aux_alloc = sim.alloc(
            aux_overlay_bytes(m, s, cap, elem_bytes).saturating_sub(padded_n * elem_bytes),
        )?;

        local_sort::analytic_bytes(padded_n, tile, elem_bytes, &mut ledger);

        let padded_samples = bitonic::next_pow2(m * s);
        sampling::analytic_local_bytes(padded_n, tile, s, elem_bytes, &mut ledger);
        bitonic::global_sort_analytic_bytes(padded_samples, tile, elem_bytes, &mut ledger, 4);
        sampling::analytic_splitters_bytes(padded_samples, s, elem_bytes, &mut ledger);

        indexing::analytic_bytes(padded_n, tile, s, elem_bytes, &mut ledger);
        prefix::analytic(m, s, &mut ledger);
        relocation::analytic_bytes(padded_n, tile, s, elem_bytes, &mut ledger);

        let balanced = padded_n / s;
        for _ in 0..s {
            bitonic::global_sort_virtual_bytes(balanced, tile, elem_bytes, &mut ledger, 9);
        }

        let peak = sim.peak_bytes();
        sim.free(aux_alloc);
        sim.free(out_alloc);
        sim.free(input_alloc);
        sim.ledger_mut().extend_from(&ledger);

        Ok(BucketSortReport {
            n,
            padded_n,
            m,
            s,
            ledger,
            peak_device_bytes: peak,
            max_bucket: 0,
        })
    }

    fn empty_report(&self) -> BucketSortReport {
        BucketSortReport {
            n: 0,
            padded_n: 0,
            m: 0,
            s: self.params.s,
            ledger: Ledger::default(),
            peak_device_bytes: 0,
            max_bucket: 0,
        }
    }
}

/// Step-9 sort of one relocated bucket with the selected kernel.
///
/// The bitonic path reproduces the paper's fixed shape: sort at the
/// guaranteed capacity (`cap`, grown to the next power of two for
/// tie-degenerate over-full buckets), PAD-padded, through arena
/// scratch. The planned radix path sorts the bucket's actual length
/// directly — no padding needed — starting from the `prebuilt`
/// first-pass histogram the fused Step-8 scatter accumulated; both
/// produce the identical (unique) sorted output.
fn sort_bucket<K: SortKey>(b: &mut [K], cap: usize, ctx: &ExecContext, prebuilt: Option<&[usize]>) {
    let len = b.len();
    if len <= 1 {
        return;
    }
    match ctx.kernel {
        // Adaptive selection happens per request, not per bucket — the
        // executed bucket kernel is the planned radix path.
        KernelKind::Radix | KernelKind::Adaptive => {
            let mut scratch = ctx.arena.take_empty::<K>();
            let mut counts = ctx.arena.take_empty::<usize>();
            plan::planned_sort(b, &mut scratch, &mut counts, ctx.digit_bits, prebuilt);
        }
        KernelKind::Bitonic => {
            let bcap = cap.max(bitonic::next_pow2(len));
            let mut scratch = ctx.arena.take(bcap, K::PAD);
            scratch[..len].copy_from_slice(b);
            let ces = bitonic::sort_slice(&mut scratch[..bcap]);
            debug_assert_eq!(ces, bitonic::ce_count(bcap));
            b.copy_from_slice(&scratch[..len]);
        }
    }
}

/// Bytes of auxiliary state that must fit inside a dead n-key buffer:
/// the padded sample array and Step-9 scratch bucket (key-width
/// elements) plus the boundary and location matrices (u32 counts
/// regardless of key type).
fn aux_overlay_bytes(m: usize, s: usize, cap: usize, elem_bytes: usize) -> usize {
    (bitonic::next_pow2(m * s) + cap) * elem_bytes + 2 * m * s * KEY_BYTES
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::{is_sorted_permutation, Key};

    fn scrambled(n: usize) -> Vec<Key> {
        (0..n as u32).map(|x| x.wrapping_mul(2654435761) ^ 0x9E37) .collect()
    }

    fn small_params() -> BucketSortParams {
        BucketSortParams { tile: 256, s: 16 }
    }

    #[test]
    fn sorts_various_sizes() {
        let sorter = BucketSort::new(small_params());
        for n in [0usize, 1, 2, 255, 256, 257, 1000, 4096, 10_000] {
            let mut keys = scrambled(n);
            let orig = keys.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let report = sorter.sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&orig, &keys), "n={n}");
            assert_eq!(report.n, n);
            assert_eq!(sim.allocated_bytes(), 0, "all allocations freed");
        }
    }

    #[test]
    fn sorts_adversarial_patterns() {
        let sorter = BucketSort::new(small_params());
        let patterns: Vec<Vec<Key>> = vec![
            vec![5; 3000],                                  // all equal
            (0..3000u32).collect(),                         // pre-sorted
            (0..3000u32).rev().collect(),                   // reverse
            (0..3000u32).map(|x| x % 2).collect(),          // two values
            (0..3000u32).map(|x| x / 100).collect(),        // long runs
        ];
        for (i, p) in patterns.into_iter().enumerate() {
            let mut keys = p.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            sorter.sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&p, &keys), "pattern {i}");
        }
    }

    #[test]
    fn deterministic_ledger_across_distributions() {
        // The paper's headline: runtime (here: the launch/traffic ledger)
        // is identical for any input of the same size — Steps 1–8 are
        // fully oblivious and Step 9 sorts guaranteed capacities.
        let sorter = BucketSort::new(small_params());
        // Tie-free inputs: with unbounded duplicates the bucket-size
        // guarantee needs key tie-breaking the paper does not specify,
        // and an over-full bucket legitimately costs extra (see
        // DESIGN.md §Limitations and the robustness experiment).
        let n = 8192;
        let inputs: Vec<Vec<Key>> = vec![
            scrambled(n),
            (0..n as u32).collect(),
            (0..n as u32).map(|x| x.wrapping_mul(2246822519)).collect(),
            (0..n as u32).rev().collect(),
        ];
        let mut ledgers = Vec::new();
        for mut keys in inputs {
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let r = sorter.sort(&mut keys, &mut sim).unwrap();
            ledgers.push(r.ledger);
        }
        for l in &ledgers[1..] {
            assert_eq!(l, &ledgers[0], "ledger must be input-independent");
        }
    }

    #[test]
    fn kernel_and_worker_count_never_change_the_bytes() {
        // The tentpole invariant: outputs and ledgers are identical for
        // either executed kernel at any worker count, and a reused
        // arena recycles rather than reallocates.
        let sorter = BucketSort::new(small_params());
        let input = scrambled(10_000);
        let mut reference = input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let ref_report = sorter.sort(&mut reference, &mut sim).unwrap();
        for kernel in [crate::KernelKind::Bitonic, crate::KernelKind::Radix] {
            for workers in [1usize, 2, 4] {
                let ctx = crate::ExecContext::new(kernel, workers);
                for round in 0..2 {
                    let mut keys = input.clone();
                    let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
                    let r = sorter.sort_in(&mut keys, &mut sim, &ctx).unwrap();
                    assert_eq!(keys, reference, "{kernel} × {workers} workers");
                    assert_eq!(r.ledger, ref_report.ledger);
                    if round == 1 {
                        let stats = ctx.arena.stats();
                        assert!(stats.hits > 0, "second round must reuse buffers: {stats:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn analytic_matches_executed() {
        let sorter = BucketSort::new(small_params());
        for n in [256usize, 4096, 8192, 100 * 256] {
            let mut keys = scrambled(n);
            let mut sim_e = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let exec = sorter.sort(&mut keys, &mut sim_e).unwrap();
            let mut sim_a = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let ana = sorter.sort_analytic(n, &mut sim_a).unwrap();
            assert_eq!(exec.ledger, ana.ledger, "n={n}");
            assert_eq!(exec.peak_device_bytes, ana.peak_device_bytes);
        }
    }

    #[test]
    fn bucket_guarantee_holds() {
        let sorter = BucketSort::new(small_params());
        let n = 64 * 256;
        let mut keys = scrambled(n);
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let r = sorter.sort(&mut keys, &mut sim).unwrap();
        assert!(
            r.max_bucket <= (2 * r.padded_n / r.s) as u64,
            "deterministic bound violated: {} > {}",
            r.max_bucket,
            2 * r.padded_n / r.s
        );
    }

    #[test]
    fn oom_reproduces_memory_ceilings() {
        // Figure 4/6/7 ceilings via the analytic path: 64M fits the
        // GTX 260, 128M does not; 256M fits the GTX 285 2GB, 512M does
        // not; 512M fits the Tesla C1060.
        let sorter = BucketSort::new(BucketSortParams::default());
        let cases = [
            (GpuModel::Gtx260, 64 << 20, true),
            (GpuModel::Gtx260, 128 << 20, false),
            (GpuModel::Gtx285_2G, 256 << 20, true),
            (GpuModel::Gtx285_2G, 512 << 20, false),
            (GpuModel::TeslaC1060, 512 << 20, true),
            (GpuModel::TeslaC1060, 1024 << 20, false),
        ];
        for (gpu, n, fits) in cases {
            let mut sim = GpuSim::new(gpu.spec());
            let r = sorter.sort_analytic(n, &mut sim);
            assert_eq!(r.is_ok(), fits, "{gpu} n={}M", n >> 20);
            if !fits {
                assert!(r.unwrap_err().is_oom());
            }
        }
    }

    #[test]
    fn estimated_time_scales_linearly() {
        // Figure 4: near-linear growth. Doubling n should scale time by
        // ~2 (within [1.8, 2.6] — the log² factor adds a mild slope).
        let sorter = BucketSort::new(BucketSortParams::default());
        let spec = GpuModel::Gtx285_2G.spec();
        let t = |n: usize| {
            let mut sim = GpuSim::new(GpuModel::TeslaC1060.spec());
            sorter
                .sort_analytic(n, &mut sim)
                .unwrap()
                .total_estimated_ms(&spec)
        };
        let t32 = t(32 << 20);
        let t64 = t(64 << 20);
        let t128 = t(128 << 20);
        assert!(t64 / t32 > 1.8 && t64 / t32 < 2.6, "ratio={}", t64 / t32);
        assert!(t128 / t64 > 1.8 && t128 / t64 < 2.6, "ratio={}", t128 / t64);
    }

    #[test]
    fn steps_2_and_9_dominate() {
        // Figure 5: local sort + sublist sort are the bulk; the sampling
        // machinery (Steps 3–7) is small.
        let sorter = BucketSort::new(BucketSortParams::default());
        let spec = GpuModel::Gtx285_2G.spec();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let r = sorter.sort_analytic(32 << 20, &mut sim).unwrap();
        let steps = r.step_ms(&spec);
        let total: f64 = steps.values().sum();
        let heavy = steps[&2] + steps[&9];
        let overhead: f64 = [3u8, 4, 5, 6, 7].iter().map(|s| steps.get(s).copied().unwrap_or(0.0)).sum();
        assert!(heavy / total > 0.6, "Steps 2+9 = {:.1}%", 100.0 * heavy / total);
        assert!(overhead / total < 0.25, "Steps 3–7 = {:.1}%", 100.0 * overhead / total);
        assert!(steps[&8] / total < 0.1, "Step 8 = {:.1}%", 100.0 * steps[&8] / total);
    }

    #[test]
    fn sorts_typed_keys() {
        let sorter = BucketSort::new(small_params());
        // u64 beyond the 32-bit range.
        let input: Vec<u64> = (0..5000u64)
            .map(|x| x.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let mut keys = input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort(&mut keys, &mut sim).unwrap();
        assert!(is_sorted_permutation(&input, &keys));

        // i64 with negatives.
        let input: Vec<i64> = (0..5000i64).map(|x| (x * 2654435761) * if x % 2 == 0 { -1 } else { 1 }).collect();
        let mut keys = input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort(&mut keys, &mut sim).unwrap();
        assert!(is_sorted_permutation(&input, &keys));

        // f32 with NaNs, infinities and signed zeros: total order.
        let mut input: Vec<f32> = (0..5000u32)
            .map(|x| (x.wrapping_mul(2654435761) as f32) - (u32::MAX / 2) as f32)
            .collect();
        input[7] = f32::NAN;
        input[19] = f32::NEG_INFINITY;
        input[23] = -0.0;
        input[29] = 0.0;
        let mut keys = input.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        sorter.sort(&mut keys, &mut sim).unwrap();
        assert!(is_sorted_permutation(&input, &keys));
    }

    #[test]
    fn wider_keys_widen_the_ledger_and_memory() {
        // The accounting flows from SortKey::WIDTH_BYTES: a u64 sort of
        // the same n moves twice the coalesced bytes and peaks at twice
        // the device memory of the u32 sort.
        let sorter = BucketSort::new(small_params());
        let n = 4096;
        let mut sim32 = GpuSim::new(GpuModel::TeslaC1060.spec());
        let mut k32: Vec<u32> = (0..n as u32).rev().collect();
        let r32 = sorter.sort(&mut k32, &mut sim32).unwrap();
        let mut sim64 = GpuSim::new(GpuModel::TeslaC1060.spec());
        let mut k64: Vec<u64> = (0..n as u64).rev().collect();
        let r64 = sorter.sort(&mut k64, &mut sim64).unwrap();
        // Key traffic doubles; Step 7's count-matrix passes are
        // width-independent, so the total ratio sits just under 2.
        let ratio = r64.ledger.total().coalesced_bytes as f64
            / r32.ledger.total().coalesced_bytes as f64;
        assert!((1.8..=2.0).contains(&ratio), "ratio {ratio}");
        assert_eq!(r64.peak_device_bytes, 2 * r32.peak_device_bytes);
        // And the analytic twin agrees at the widened width.
        let mut sim_a = GpuSim::new(GpuModel::TeslaC1060.spec());
        let ana = sorter.sort_analytic_bytes(n, 8, &mut sim_a).unwrap();
        assert_eq!(ana.ledger, r64.ledger);
        assert_eq!(ana.peak_device_bytes, r64.peak_device_bytes);
    }

    #[test]
    fn sort_pairs_keeps_payloads_with_keys() {
        let sorter = BucketSort::new(small_params());
        let keys_in: Vec<u32> = (0..4000u32).map(|x| x.wrapping_mul(2654435761) % 512).collect();
        // Payload encodes (original position, key) so both pairing and
        // stability are checkable after the sort.
        let payload_in: Vec<u64> = keys_in
            .iter()
            .enumerate()
            .map(|(i, &k)| ((i as u64) << 32) | k as u64)
            .collect();
        let mut keys = keys_in.clone();
        let mut payload = payload_in.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let report = sorter.sort_pairs(&mut keys, &mut payload, &mut sim).unwrap();
        assert!(is_sorted_permutation(&keys_in, &keys));
        for (k, p) in keys.iter().zip(&payload) {
            assert_eq!(*p & 0xFFFF_FFFF, *k as u64, "payload divorced from key");
        }
        // Stability: equal keys keep their original (position) order.
        for (w, pw) in keys.windows(2).zip(payload.windows(2)) {
            if w[0] == w[1] {
                assert!(pw[0] >> 32 < pw[1] >> 32, "unstable at key {}", w[0]);
            }
        }
        // Records are key+index wide on the device.
        let mut sim_a = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let ana = sorter.sort_analytic_bytes(keys.len(), 8, &mut sim_a).unwrap();
        assert_eq!(ana.ledger, report.ledger);
        // Length mismatch is rejected.
        let mut short = vec![1u64];
        let mut sim_b = GpuSim::new(GpuModel::Gtx285_2G.spec());
        assert!(sorter.sort_pairs(&mut keys, &mut short, &mut sim_b).is_err());
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(BucketSort::try_new(BucketSortParams { tile: 100, s: 10 }).is_err());
        assert!(BucketSort::try_new(BucketSortParams { tile: 256, s: 0 }).is_err());
        assert!(BucketSort::try_new(BucketSortParams { tile: 256, s: 257 }).is_err());
        assert!(BucketSort::try_new(BucketSortParams { tile: 256, s: 96 }).is_err());
        assert!(BucketSort::try_new(BucketSortParams { tile: 256, s: 64 }).is_ok());
    }
}

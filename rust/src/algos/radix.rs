//! Radix sorting, in two roles:
//!
//! 1. **Analytic baseline** ([`RadixSort`]) — Satish, Harris &
//!    Garland's integer-specialized GPU method [14], which the paper
//!    acknowledges as faster than any comparison sort "for the special
//!    case of integer sorting" (§3). LSD radix over 32-bit keys with
//!    `DIGIT_BITS`-bit digits: each pass (1) builds per-block digit
//!    histograms (coalesced read), (2) scans them, and (3) scatters keys
//!    to their digit's partition — the scatter is staged through shared
//!    memory so writes leave each block in digit-contiguous chunks.
//!    Included because a credible reproduction of the paper's evaluation
//!    context needs the integer-sort reference point.
//!
//! 2. **Byte-wise tile kernel** ([`radix_tile_sort`]) — the original
//!    (PR 4) host kernel: an 8-bit-digit LSD counting sort over
//!    [`crate::SortKey::radix_byte`] digits. It does O(n·WIDTH_BYTES)
//!    work where the bitonic network does O(n log² n) — while producing
//!    bit-identical output (stable LSD over the ordered bit pattern
//!    *is* the [`crate::SortKey::to_bits`] total order, with the record
//!    path's tie-breaking index in the low digits). Since PR 5 the
//!    executed [`crate::KernelKind::Radix`] hot path runs the
//!    **planner-scheduled wide-digit kernel**
//!    ([`crate::algos::plan::planned_sort`]) instead — fewer, wider
//!    passes with constant digits elided; this byte-wise kernel remains
//!    as its fixed-schedule special case and the benchmarked baseline
//!    (`benches/planner.rs` gates the planner against it). The traffic
//!    **ledger is unaffected by kernel choice**: it keeps recording the
//!    paper's bitonic CE/traffic analytics, so Figures 3–7 and every
//!    analytic twin stay byte-identical.

use super::ExecContext;
use crate::error::Result;
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::sim::{CostModel, GpuSim};
use crate::{Key, SortKey, KEY_BYTES};

/// Bits per radix digit (4 → 16 counting bins, 8 passes over u32).
pub const DIGIT_BITS: u32 = 4;

/// Counting bins per pass.
pub const RADIX: usize = 1 << DIGIT_BITS;

/// Minimum run length for the executed counting kernels; runs below it
/// take the comparison path inside [`radix_tile_sort`] and
/// [`crate::algos::plan::planned_sort`].
pub(crate) const RADIX_MIN_N: usize = 64;

/// Parameters of the radix baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RadixParams {
    /// Keys per block for the histogram/scatter staging.
    pub tile: usize,
}

impl Default for RadixParams {
    fn default() -> Self {
        RadixParams { tile: 2048 }
    }
}

/// Report of one radix sort run.
#[derive(Debug, Clone)]
pub struct RadixReport {
    /// Input size.
    pub n: usize,
    /// Traffic ledger.
    pub ledger: Ledger,
    /// Digit passes executed (always 32 / DIGIT_BITS).
    pub passes: usize,
}

impl RadixReport {
    /// Estimated milliseconds on `spec`.
    pub fn total_estimated_ms(&self, spec: &crate::sim::GpuSpec) -> f64 {
        CostModel::default_params(spec).ledger_ms(&self.ledger)
    }
}

/// The radix sorter.
#[derive(Debug, Clone)]
pub struct RadixSort {
    params: RadixParams,
}

impl RadixSort {
    /// Peak device footprint per key: ping-pong buffers + histograms.
    pub const BYTES_PER_KEY: usize = 9;

    /// Construct with the given parameters.
    pub fn new(params: RadixParams) -> Self {
        assert!(params.tile.is_power_of_two());
        RadixSort { params }
    }

    /// Sort `keys` on the simulated device (transient default
    /// [`ExecContext`]; the harness passes a persistent one through
    /// [`RadixSort::sort_in`]).
    pub fn sort(&self, keys: &mut [Key], sim: &mut GpuSim) -> Result<RadixReport> {
        self.sort_in(keys, sim, &ExecContext::default())
    }

    /// [`RadixSort::sort`] with explicit execution resources: both
    /// ping-pong buffers are checked out of `ctx.arena` instead of
    /// being freshly allocated per run, so repeated baseline runs (the
    /// Figure 6/7 sweeps) allocate nothing after warm-up.
    pub fn sort_in(
        &self,
        keys: &mut [Key],
        sim: &mut GpuSim,
        ctx: &ExecContext,
    ) -> Result<RadixReport> {
        let n = keys.len();
        let alloc = sim.alloc(n * Self::BYTES_PER_KEY)?;
        let mut ledger = Ledger::default();
        let passes = (Key::BITS / DIGIT_BITS) as usize;

        let mut src = ctx.arena.take_from(keys);
        let mut dst = ctx.arena.take(n, 0 as Key);
        for p in 0..passes {
            let shift = p as u32 * DIGIT_BITS;
            // Counting pass.
            let mut counts = [0usize; RADIX];
            for &x in src.iter() {
                counts[((x >> shift) as usize) & (RADIX - 1)] += 1;
            }
            record_pass(n, self.params.tile, false, &mut ledger);
            // Exclusive scan.
            let mut starts = [0usize; RADIX];
            let mut acc = 0usize;
            for d in 0..RADIX {
                starts[d] = acc;
                acc += counts[d];
            }
            // Scatter pass (stable).
            for &x in src.iter() {
                let d = ((x >> shift) as usize) & (RADIX - 1);
                dst[starts[d]] = x;
                starts[d] += 1;
            }
            record_pass(n, self.params.tile, true, &mut ledger);
            std::mem::swap(&mut src, &mut dst);
        }
        keys.copy_from_slice(&src);

        sim.free(alloc);
        sim.ledger_mut().extend_from(&ledger);
        Ok(RadixReport { n, ledger, passes })
    }
}

/// Executed LSD counting-sort kernel over [`SortKey`] radix bytes — the
/// [`crate::KernelKind::Radix`] tile/bucket kernel.
///
/// Sorts `data` in place by [`SortKey::to_bits`] order using `scratch`
/// as the ping-pong buffer (resized to `data.len()`; checked out of a
/// [`crate::util::ScratchArena`] on the hot path so steady-state calls
/// allocate nothing). One counting + scatter pass per
/// [`SortKey::WIDTH_BYTES`] byte; a pass whose byte is constant across
/// the input (common in the high bytes of small-ranged keys) is skipped
/// — the skip changes wall time only, never the output.
pub fn radix_tile_sort<K: SortKey>(data: &mut [K], scratch: &mut Vec<K>) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    // Below this the fixed per-pass cost (256-bin clear + prefix, ×
    // WIDTH_BYTES passes) dominates: the comparison sort is cheaper and
    // produces the identical output (the sorted sequence of a bit
    // multiset is unique; records have no ties at all).
    if n < RADIX_MIN_N {
        data.sort_unstable_by(K::key_cmp);
        return;
    }
    scratch.clear();
    scratch.resize(n, data[0]);
    let mut counts = [0usize; 256];
    let mut flipped = false;
    for byte in 0..K::WIDTH_BYTES {
        let single_bin = if flipped {
            count_pass(scratch, byte, &mut counts)
        } else {
            count_pass(data, byte, &mut counts)
        };
        if single_bin {
            continue;
        }
        exclusive_prefix(&mut counts);
        if flipped {
            scatter_pass(scratch, data, byte, &mut counts);
        } else {
            scatter_pass(data, scratch, byte, &mut counts);
        }
        flipped = !flipped;
    }
    if flipped {
        data.copy_from_slice(scratch);
    }
}

/// Histogram one digit position; true when a single bin holds every
/// element (the pass would be an order-preserving no-op).
fn count_pass<K: SortKey>(src: &[K], byte: usize, counts: &mut [usize; 256]) -> bool {
    counts.fill(0);
    for x in src {
        counts[x.radix_byte(byte) as usize] += 1;
    }
    counts.iter().any(|&c| c == src.len())
}

/// In-place exclusive prefix sum over the 256 digit counts.
fn exclusive_prefix(counts: &mut [usize; 256]) {
    let mut acc = 0usize;
    for c in counts.iter_mut() {
        let t = *c;
        *c = acc;
        acc += t;
    }
}

/// Stable scatter of `src` into `dst` by the digit at `byte`, advancing
/// the per-digit cursors in `starts`.
fn scatter_pass<K: SortKey>(src: &[K], dst: &mut [K], byte: usize, starts: &mut [usize; 256]) {
    for &x in src {
        let d = x.radix_byte(byte) as usize;
        dst[starts[d]] = x;
        starts[d] += 1;
    }
}

fn record_pass(n: usize, tile: usize, scatter: bool, ledger: &mut Ledger) {
    let blocks = n.div_ceil(tile).max(1) as u64;
    ledger.begin_kernel(KernelClass::RadixPass, blocks, MAX_BLOCK_THREADS);
    ledger.add_coalesced((n * KEY_BYTES) as u64);
    // Digit extraction + histogram/offset update per key.
    ledger.add_compute(2 * n as u64);
    ledger.add_smem(2 * n as u64);
    if scatter {
        ledger.add_coalesced((n * KEY_BYTES) as u64);
        // One stream flush per block-digit.
        ledger.add_scattered(blocks * RADIX as u64);
    }
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::is_sorted_permutation;

    #[test]
    fn sorts_various_inputs() {
        let sorter = RadixSort::new(RadixParams { tile: 256 });
        for input in [
            (0..10_000u32).map(|x| x.wrapping_mul(2654435761)).collect::<Vec<_>>(),
            (0..10_000u32).rev().collect(),
            vec![42u32; 10_000],
            vec![u32::MAX, 0, u32::MAX, 1, 2],
        ] {
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let r = sorter.sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&input, &keys));
            assert_eq!(r.passes, 8);
        }
    }

    #[test]
    fn faster_than_comparison_sorts() {
        // §3: radix beats comparison sorts on integers.
        use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
        let spec = GpuModel::Gtx285_2G.spec();
        let n = 1 << 20;
        let keys: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();

        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let radix = RadixSort::new(RadixParams::default())
            .sort(&mut keys.clone(), &mut sim)
            .unwrap();
        let mut sim2 = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let bs = BucketSort::new(BucketSortParams::default())
            .sort(&mut keys.clone(), &mut sim2)
            .unwrap();
        assert!(radix.total_estimated_ms(&spec) < bs.total_estimated_ms(&spec));
    }

    #[test]
    fn tile_kernel_matches_comparison_sort() {
        let mut scratch = Vec::new();
        // u32 full range, reverse, constant, tiny range (skip-pass path).
        for input in [
            (0..5000u32).map(|x| x.wrapping_mul(2654435761)).collect::<Vec<_>>(),
            (0..5000u32).rev().collect(),
            vec![42u32; 5000],
            (0..5000u32).map(|x| x % 7).collect(),
            vec![],
            vec![3u32],
        ] {
            let mut a = input.clone();
            radix_tile_sort(&mut a, &mut scratch);
            let mut expect = input.clone();
            expect.sort_unstable();
            assert_eq!(a, expect);
        }
        // i64 negatives.
        let input: Vec<i64> = (0..3000i64).map(|x| (x - 1500) * 2654435761).collect();
        let mut a = input.clone();
        let mut scratch64 = Vec::new();
        radix_tile_sort(&mut a, &mut scratch64);
        let mut expect = input;
        expect.sort_unstable();
        assert_eq!(a, expect);
        // f32 under total order, NaN and signed zeros included.
        let mut input: Vec<f32> = (0..2000u32)
            .map(|x| x.wrapping_mul(2654435761) as f32 - 2e9)
            .collect();
        input[3] = f32::NAN;
        input[5] = -0.0;
        input[7] = 0.0;
        input[11] = f32::NEG_INFINITY;
        let mut a = input.clone();
        let mut fscratch = Vec::new();
        radix_tile_sort(&mut a, &mut fscratch);
        let mut expect = input;
        expect.sort_unstable_by(<f32 as SortKey>::key_cmp);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            expect.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn tile_kernel_is_stable_on_records() {
        use crate::Record;
        // Duplicate keys: the (key, idx) order is total, so the kernel
        // must keep equal keys in index order — the stability the
        // key–value path depends on.
        let recs: Vec<Record<u32>> = (0..4000u32)
            .map(|i| Record {
                key: i.wrapping_mul(2654435761) % 16,
                idx: i,
            })
            .collect();
        let mut a = recs.clone();
        let mut scratch = Vec::new();
        radix_tile_sort(&mut a, &mut scratch);
        let mut expect = recs;
        expect.sort_unstable_by(<Record<u32>>::key_cmp);
        assert_eq!(a, expect);
        for w in a.windows(2) {
            if w[0].key == w[1].key {
                assert!(w[0].idx < w[1].idx);
            }
        }
    }

    #[test]
    fn ledger_is_input_independent() {
        let sorter = RadixSort::new(RadixParams { tile: 256 });
        let mk = |keys: Vec<u32>| {
            let mut keys = keys;
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            sorter.sort(&mut keys, &mut sim).unwrap().ledger
        };
        let a = mk((0..5000u32).collect());
        let b = mk(vec![3u32; 5000]);
        assert_eq!(a, b);
    }
}

//! Baseline: **GPU radix sort** — Satish, Harris & Garland's integer-
//! specialized method [14], which the paper acknowledges as faster than
//! any comparison sort "for the special case of integer sorting" (§3).
//!
//! LSD radix over 32-bit keys with `DIGIT_BITS`-bit digits: each pass
//! (1) builds per-block digit histograms (coalesced read), (2) scans
//! them, and (3) scatters keys to their digit's partition — the scatter
//! is staged through shared memory so writes leave each block in digit-
//! contiguous chunks (mostly coalesced, with one transaction per
//! block-digit stream, like the sample-sort scatter).
//!
//! Included because a credible reproduction of the paper's evaluation
//! context needs the integer-sort reference point: it bounds from below
//! what any comparison-based method (including GPU BUCKET SORT) can
//! achieve on u32 keys.

use crate::error::Result;
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::sim::{CostModel, GpuSim};
use crate::{Key, KEY_BYTES};

/// Bits per radix digit (4 → 16 counting bins, 8 passes over u32).
pub const DIGIT_BITS: u32 = 4;

/// Counting bins per pass.
pub const RADIX: usize = 1 << DIGIT_BITS;

/// Parameters of the radix baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RadixParams {
    /// Keys per block for the histogram/scatter staging.
    pub tile: usize,
}

impl Default for RadixParams {
    fn default() -> Self {
        RadixParams { tile: 2048 }
    }
}

/// Report of one radix sort run.
#[derive(Debug, Clone)]
pub struct RadixReport {
    /// Input size.
    pub n: usize,
    /// Traffic ledger.
    pub ledger: Ledger,
    /// Digit passes executed (always 32 / DIGIT_BITS).
    pub passes: usize,
}

impl RadixReport {
    /// Estimated milliseconds on `spec`.
    pub fn total_estimated_ms(&self, spec: &crate::sim::GpuSpec) -> f64 {
        CostModel::default_params(spec).ledger_ms(&self.ledger)
    }
}

/// The radix sorter.
#[derive(Debug, Clone)]
pub struct RadixSort {
    params: RadixParams,
}

impl RadixSort {
    /// Peak device footprint per key: ping-pong buffers + histograms.
    pub const BYTES_PER_KEY: usize = 9;

    /// Construct with the given parameters.
    pub fn new(params: RadixParams) -> Self {
        assert!(params.tile.is_power_of_two());
        RadixSort { params }
    }

    /// Sort `keys` on the simulated device.
    pub fn sort(&self, keys: &mut [Key], sim: &mut GpuSim) -> Result<RadixReport> {
        let n = keys.len();
        let alloc = sim.alloc(n * Self::BYTES_PER_KEY)?;
        let mut ledger = Ledger::default();
        let passes = (Key::BITS / DIGIT_BITS) as usize;

        let mut src = keys.to_vec();
        let mut dst = vec![0 as Key; n];
        for p in 0..passes {
            let shift = p as u32 * DIGIT_BITS;
            // Counting pass.
            let mut counts = [0usize; RADIX];
            for &x in &src {
                counts[((x >> shift) as usize) & (RADIX - 1)] += 1;
            }
            record_pass(n, self.params.tile, false, &mut ledger);
            // Exclusive scan.
            let mut starts = [0usize; RADIX];
            let mut acc = 0usize;
            for d in 0..RADIX {
                starts[d] = acc;
                acc += counts[d];
            }
            // Scatter pass (stable).
            for &x in &src {
                let d = ((x >> shift) as usize) & (RADIX - 1);
                dst[starts[d]] = x;
                starts[d] += 1;
            }
            record_pass(n, self.params.tile, true, &mut ledger);
            std::mem::swap(&mut src, &mut dst);
        }
        keys.copy_from_slice(&src);

        sim.free(alloc);
        sim.ledger_mut().extend_from(&ledger);
        Ok(RadixReport { n, ledger, passes })
    }
}

fn record_pass(n: usize, tile: usize, scatter: bool, ledger: &mut Ledger) {
    let blocks = n.div_ceil(tile).max(1) as u64;
    ledger.begin_kernel(KernelClass::RadixPass, blocks, MAX_BLOCK_THREADS);
    ledger.add_coalesced((n * KEY_BYTES) as u64);
    // Digit extraction + histogram/offset update per key.
    ledger.add_compute(2 * n as u64);
    ledger.add_smem(2 * n as u64);
    if scatter {
        ledger.add_coalesced((n * KEY_BYTES) as u64);
        // One stream flush per block-digit.
        ledger.add_scattered(blocks * RADIX as u64);
    }
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::is_sorted_permutation;

    #[test]
    fn sorts_various_inputs() {
        let sorter = RadixSort::new(RadixParams { tile: 256 });
        for input in [
            (0..10_000u32).map(|x| x.wrapping_mul(2654435761)).collect::<Vec<_>>(),
            (0..10_000u32).rev().collect(),
            vec![42u32; 10_000],
            vec![u32::MAX, 0, u32::MAX, 1, 2],
        ] {
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let r = sorter.sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&input, &keys));
            assert_eq!(r.passes, 8);
        }
    }

    #[test]
    fn faster_than_comparison_sorts() {
        // §3: radix beats comparison sorts on integers.
        use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
        let spec = GpuModel::Gtx285_2G.spec();
        let n = 1 << 20;
        let keys: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();

        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let radix = RadixSort::new(RadixParams::default())
            .sort(&mut keys.clone(), &mut sim)
            .unwrap();
        let mut sim2 = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let bs = BucketSort::new(BucketSortParams::default())
            .sort(&mut keys.clone(), &mut sim2)
            .unwrap();
        assert!(radix.total_estimated_ms(&spec) < bs.total_estimated_ms(&spec));
    }

    #[test]
    fn ledger_is_input_independent() {
        let sorter = RadixSort::new(RadixParams { tile: 256 });
        let mk = |keys: Vec<u32>| {
            let mut keys = keys;
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            sorter.sort(&mut keys, &mut sim).unwrap().ledger
        };
        let a = mk((0..5000u32).collect());
        let b = mk(vec![3u32; 5000]);
        assert_eq!(a, b);
    }
}

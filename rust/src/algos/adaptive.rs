//! The adaptive front-end: let the data choose the algorithm.
//!
//! The paper's headline claim is that deterministic sample sort has no
//! input-dependent fluctuations — but a *service* can go further and
//! turn input shape into wins instead of merely tolerating it. Before
//! any kernel runs, this module builds an [`InputProfile`] from the
//! planner's equidistant occupancy sketch plus a ~128-point
//! run-detection probe, then consults a [`CostModel`] (per-kernel
//! coefficients, calibrated offline by `benches/adaptive.rs` and
//! loadable from versioned JSON) to pick the cheapest path:
//!
//! * **Early exit** — a profile that looks sorted (or reverse sorted)
//!   triggers an O(n) verify scan; on success the sort is a no-op (or a
//!   single in-place reversal). The verify aborts at the first
//!   violation, so unsorted inputs pay only the probe.
//! * **Comparison** — tiny or nearly-sorted runs where the planned
//!   radix kernel's per-pass fixed costs dominate.
//! * **Planned radix** — everything else: the wide-digit LSD schedule
//!   with constant digits elided ([`super::plan`]).
//!
//! Every decision is recorded as a [`PlanChoice`] (chosen path,
//! predicted vs. actual cost) and aggregated into [`PlanTotals`] — the
//! scheduler surfaces both in metrics and, on request, in the response
//! tag, so benches and tests can assert *why* a kernel was chosen.
//!
//! ## Correctness of the early exits
//!
//! [`crate::SortKey::key_cmp`] equality implies bit equality (the
//! comparison is on the injective ordered bit pattern), so a sorted
//! sequence of any key multiset is a *unique byte sequence*. The sorted
//! check therefore returns exactly what any kernel would produce, and
//! reversing a non-increasing sequence produces that same unique
//! sequence. Stability for key–value jobs is inherited: [`crate::Record`]s
//! carry a tie-breaking index in their low bits, so records are never
//! `key_cmp`-equal — a reverse-sorted-by-key run with duplicate keys is
//! *not* non-increasing as records (the index ascends inside a tie) and
//! takes the full sort instead of a stability-breaking reversal.

use super::plan;
use crate::error::{Error, Result};
use crate::util::Json;
use crate::{KernelKind, SortKey};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::Mutex;

/// Elements probed by the run-detection scan (matches the planner's
/// sketch granularity: O(1) in the input size).
pub const PROFILE_SAMPLES: usize = 128;

/// Cost-model JSON format version this build reads and writes.
pub const COST_MODEL_VERSION: u64 = 1;

/// What the profile measured about one input, from O(sample) work.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InputProfile {
    /// Input length.
    pub n: usize,
    /// Elements probed (≤ [`PROFILE_SAMPLES`]).
    pub sampled: usize,
    /// Ordered pairs of consecutive probes compared.
    pub pairs: usize,
    /// Probe pairs that were strictly descending.
    pub descending_pairs: usize,
    /// Probe pairs that were equal (bit-identical keys).
    pub equal_pairs: usize,
    /// Distinct bit patterns among the probes (duplicate-density /
    /// entropy estimate).
    pub distinct_sampled: usize,
    /// Bit positions the occupancy sketch *proved* vary.
    pub varying_bits: u32,
    /// Radix passes the sketch plan would execute (a lower bound: an
    /// unproven-constant digit may still vary off the sample grid).
    pub planned_passes: usize,
    /// Radix passes the key width implies before any skipping.
    pub nominal_passes: usize,
}

impl InputProfile {
    /// Profile `data`: the planner's occupancy sketch plus an
    /// equidistant direction/duplicate probe.
    pub fn sample<K: SortKey>(data: &[K], digit_bits: u32) -> InputProfile {
        let n = data.len();
        let occ = plan::Occupancy::sketch(data);
        let sketch_plan = plan::plan_from_occupancy::<K>(&occ, digit_bits);
        let stride = (n / PROFILE_SAMPLES).max(1);
        let mut bits: Vec<K::Bits> = Vec::with_capacity(n.div_ceil(stride).min(n));
        let (mut pairs, mut descending, mut equal) = (0usize, 0usize, 0usize);
        let mut prev: Option<K> = None;
        let mut i = 0usize;
        while i < n {
            let x = data[i];
            bits.push(x.to_bits());
            if let Some(p) = prev {
                pairs += 1;
                match K::key_cmp(&p, &x) {
                    std::cmp::Ordering::Greater => descending += 1,
                    std::cmp::Ordering::Equal => equal += 1,
                    std::cmp::Ordering::Less => {}
                }
            }
            prev = Some(x);
            i += stride;
        }
        let sampled = bits.len();
        bits.sort_unstable();
        bits.dedup();
        InputProfile {
            n,
            sampled,
            pairs,
            descending_pairs: descending,
            equal_pairs: equal,
            distinct_sampled: bits.len(),
            varying_bits: occ.varying_bits(K::WIDTH_BYTES),
            planned_passes: sketch_plan.passes.len(),
            nominal_passes: sketch_plan.nominal_passes,
        }
    }

    /// No probe pair descended — the input *may* be sorted (always true
    /// for a genuinely sorted input, since sortedness is transitive
    /// across the probe grid).
    pub fn looks_sorted(&self) -> bool {
        self.descending_pairs == 0
    }

    /// Every probe pair was non-increasing and at least one strictly
    /// descended — the input *may* be reverse sorted.
    pub fn looks_reverse_sorted(&self) -> bool {
        self.descending_pairs > 0 && self.descending_pairs + self.equal_pairs == self.pairs
    }

    /// Estimated fraction of duplicate keys (0 = all probes distinct,
    /// → 1 = all probes equal).
    pub fn duplicate_density(&self) -> f64 {
        if self.sampled == 0 {
            return 0.0;
        }
        1.0 - self.distinct_sampled as f64 / self.sampled as f64
    }

    /// Fraction of probe pairs that descended (sampled disorder).
    pub fn inversion_fraction(&self) -> f64 {
        self.descending_pairs as f64 / self.pairs.max(1) as f64
    }
}

/// Per-kernel cost coefficients: nanosecond budgets the planner uses to
/// predict each candidate path from an [`InputProfile`].
///
/// The built-in [`Default`] is a sane portable estimate; the calibrated
/// set for a given host is produced by `cargo bench --bench adaptive`
/// (which prints and writes the fitted JSON) and checked in at
/// `configs/cost_model.json`. Load order: `--cost-model PATH` /
/// `config.cost_model` → built-in defaults when empty.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// One sequential read pass over the input (verify scan, occupancy
    /// confirm), per key.
    pub scan_ns_per_key: f64,
    /// One radix counting+scatter pass, per key.
    pub radix_ns_per_key_pass: f64,
    /// Fixed per-pass cost (bin clear + prefix over 2^digit_bits bins).
    pub radix_pass_overhead_ns: f64,
    /// Comparison sort, per key per log2(n) (pdqsort on bit patterns).
    pub comparison_ns_per_key_log: f64,
    /// In-place reversal, per key.
    pub reverse_ns_per_key: f64,
    /// Multiplier on the comparison estimate when the sampled disorder
    /// is below [`CostModel::nearly_sorted_max_inversions`] (pdqsort
    /// exploits long runs). 1.0 disables the discount.
    pub nearly_sorted_comparison_factor: f64,
    /// Sampled inversion fraction below which an input counts as
    /// nearly sorted.
    pub nearly_sorted_max_inversions: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            scan_ns_per_key: 0.25,
            radix_ns_per_key_pass: 2.0,
            radix_pass_overhead_ns: 2000.0,
            comparison_ns_per_key_log: 0.45,
            reverse_ns_per_key: 0.15,
            nearly_sorted_comparison_factor: 0.65,
            nearly_sorted_max_inversions: 0.02,
        }
    }
}

impl CostModel {
    /// All coefficient names, in serialization order (shared by the
    /// reader, the writer and the calibration bench).
    pub const FIELDS: [&'static str; 7] = [
        "scan_ns_per_key",
        "radix_ns_per_key_pass",
        "radix_pass_overhead_ns",
        "comparison_ns_per_key_log",
        "reverse_ns_per_key",
        "nearly_sorted_comparison_factor",
        "nearly_sorted_max_inversions",
    ];

    fn field(&self, name: &str) -> f64 {
        match name {
            "scan_ns_per_key" => self.scan_ns_per_key,
            "radix_ns_per_key_pass" => self.radix_ns_per_key_pass,
            "radix_pass_overhead_ns" => self.radix_pass_overhead_ns,
            "comparison_ns_per_key_log" => self.comparison_ns_per_key_log,
            "reverse_ns_per_key" => self.reverse_ns_per_key,
            "nearly_sorted_comparison_factor" => self.nearly_sorted_comparison_factor,
            "nearly_sorted_max_inversions" => self.nearly_sorted_max_inversions,
            _ => unreachable!("unknown cost-model field {name}"),
        }
    }

    fn field_mut(&mut self, name: &str) -> &mut f64 {
        match name {
            "scan_ns_per_key" => &mut self.scan_ns_per_key,
            "radix_ns_per_key_pass" => &mut self.radix_ns_per_key_pass,
            "radix_pass_overhead_ns" => &mut self.radix_pass_overhead_ns,
            "comparison_ns_per_key_log" => &mut self.comparison_ns_per_key_log,
            "reverse_ns_per_key" => &mut self.reverse_ns_per_key,
            "nearly_sorted_comparison_factor" => &mut self.nearly_sorted_comparison_factor,
            "nearly_sorted_max_inversions" => &mut self.nearly_sorted_max_inversions,
            _ => unreachable!("unknown cost-model field {name}"),
        }
    }

    /// The versioned JSON form (`{"version": 1, "<coefficient>": ...}`).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("version", Json::num(COST_MODEL_VERSION as f64))];
        for name in Self::FIELDS {
            pairs.push((name, Json::num(self.field(name))));
        }
        Json::obj(pairs)
    }

    /// Parse the versioned JSON form. Rejects unknown fields and wrong
    /// versions (a misspelt coefficient must not silently keep its
    /// default); missing coefficients keep their defaults so the file
    /// can carry a partial calibration.
    pub fn from_json(text: &str) -> Result<CostModel> {
        let v = Json::parse(text).map_err(|e| Error::Config(format!("cost model: {e}")))?;
        let pairs = match &v {
            Json::Obj(pairs) => pairs,
            _ => return Err(Error::Config("cost model: expected a JSON object".into())),
        };
        let version = v
            .req("version")
            .map_err(|_| Error::Config("cost model: missing \"version\"".into()))?
            .as_u64()
            .ok_or_else(|| Error::Config("cost model: \"version\" must be an integer".into()))?;
        if version != COST_MODEL_VERSION {
            return Err(Error::Config(format!(
                "cost model: version {version} unsupported (this build reads {COST_MODEL_VERSION})"
            )));
        }
        let mut model = CostModel::default();
        for (key, value) in pairs {
            if key == "version" {
                continue;
            }
            if !Self::FIELDS.contains(&key.as_str()) {
                return Err(Error::Config(format!("cost model: unknown field {key:?}")));
            }
            let num = value.as_f64().ok_or_else(|| {
                Error::Config(format!("cost model: field {key:?} must be a number"))
            })?;
            if !num.is_finite() || num < 0.0 {
                return Err(Error::Config(format!(
                    "cost model: field {key:?} must be finite and non-negative, got {num}"
                )));
            }
            *model.field_mut(key) = num;
        }
        Ok(model)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<CostModel> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cost model {path:?}: {e}")))?;
        Self::from_json(&text)
    }

    /// Resolve a config/CLI path: empty → built-in defaults, otherwise
    /// load the file.
    pub fn resolve(path: &str) -> Result<CostModel> {
        if path.is_empty() {
            Ok(CostModel::default())
        } else {
            Self::load(path)
        }
    }

    /// Predicted cost of the planned radix path, in milliseconds. Uses
    /// the sketch's pass count plus the confirming occupancy scan the
    /// planner performs whenever the sketch left skips unproven.
    pub fn predict_radix_ms(&self, p: &InputProfile) -> f64 {
        let passes = p.planned_passes as f64;
        let mut ns = p.n as f64 * passes * self.radix_ns_per_key_pass
            + passes * self.radix_pass_overhead_ns;
        if p.planned_passes < p.nominal_passes {
            ns += p.n as f64 * self.scan_ns_per_key;
        }
        ns / 1e6
    }

    /// Predicted cost of the comparison path, in milliseconds, with the
    /// nearly-sorted discount when the sampled disorder is low.
    pub fn predict_comparison_ms(&self, p: &InputProfile) -> f64 {
        let n = p.n as f64;
        let mut ns = n * n.max(2.0).log2() * self.comparison_ns_per_key_log;
        if p.descending_pairs > 0 && p.inversion_fraction() <= self.nearly_sorted_max_inversions {
            ns *= self.nearly_sorted_comparison_factor;
        }
        ns / 1e6
    }

    /// Predicted cost of the sorted early exit (one verify scan).
    pub fn predict_verify_ms(&self, n: usize) -> f64 {
        n as f64 * self.scan_ns_per_key / 1e6
    }

    /// Predicted cost of the reverse early exit (verify + reversal).
    pub fn predict_reverse_ms(&self, n: usize) -> f64 {
        n as f64 * (self.scan_ns_per_key + self.reverse_ns_per_key) / 1e6
    }

    /// Pick the cheaper executed kernel for this profile.
    pub fn decide(&self, p: &InputProfile) -> (KernelKind, f64) {
        let radix = self.predict_radix_ms(p);
        let comparison = self.predict_comparison_ms(p);
        if comparison < radix {
            (KernelKind::Bitonic, comparison)
        } else {
            (KernelKind::Radix, radix)
        }
    }
}

/// The path the adaptive front-end chose for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Choice {
    /// Input verified already sorted: returned untouched.
    EarlyExitSorted,
    /// Input verified non-increasing: one in-place reversal.
    EarlyExitReverse,
    /// Planned wide-digit radix kernel.
    Radix,
    /// Comparison kernel (tiny or nearly-sorted run).
    Comparison,
}

impl Choice {
    /// Stable identifier (metrics keys, bench JSON, response tags).
    pub fn id(&self) -> &'static str {
        match self {
            Choice::EarlyExitSorted => "early_exit_sorted",
            Choice::EarlyExitReverse => "early_exit_reverse",
            Choice::Radix => "radix",
            Choice::Comparison => "comparison",
        }
    }
}

impl std::fmt::Display for Choice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// One recorded adaptive decision: what was chosen, for how many keys,
/// and the predicted vs. measured cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanChoice {
    /// Chosen path.
    pub chosen: Choice,
    /// Keys in the request.
    pub n: usize,
    /// Cost-model prediction for the chosen path (ms).
    pub predicted_ms: f64,
    /// Measured wall time of the request (ms), filled after execution.
    pub actual_ms: f64,
    /// Sketch-planned radix passes at decision time.
    pub planned_passes: usize,
    /// Sampled duplicate density at decision time.
    pub duplicate_density: f64,
}

impl PlanChoice {
    /// Compact single-token summary for response tags:
    /// `choice=<id>;n=<n>;passes=<p>;pred_ms=<x>;act_ms=<y>`.
    pub fn summary(&self) -> String {
        format!(
            "choice={};n={};passes={};pred_ms={:.3};act_ms={:.3}",
            self.chosen.id(),
            self.n,
            self.planned_passes,
            self.predicted_ms,
            self.actual_ms
        )
    }
}

/// Lifetime totals of adaptive decisions, for metrics deltas (the
/// scheduler polls these the same way it polls coalescing totals).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PlanTotals {
    /// Requests that went through the adaptive front-end.
    pub requests: u64,
    /// Sorted early exits taken.
    pub early_exit_sorted: u64,
    /// Reverse early exits taken.
    pub early_exit_reverse: u64,
    /// Requests dispatched to the planned radix kernel.
    pub chose_radix: u64,
    /// Requests dispatched to the comparison kernel.
    pub chose_comparison: u64,
}

/// Thread-safe decision log an engine embeds: monotonic counters for
/// metrics plus the most recent [`PlanChoice`] for response tagging.
#[derive(Debug, Default)]
pub struct ChoiceLog {
    requests: AtomicU64,
    early_exit_sorted: AtomicU64,
    early_exit_reverse: AtomicU64,
    chose_radix: AtomicU64,
    chose_comparison: AtomicU64,
    last: Mutex<Option<PlanChoice>>,
}

impl ChoiceLog {
    /// Record one decision.
    pub fn record(&self, choice: &PlanChoice) {
        self.requests.fetch_add(1, AtomicOrdering::Relaxed);
        let counter = match choice.chosen {
            Choice::EarlyExitSorted => &self.early_exit_sorted,
            Choice::EarlyExitReverse => &self.early_exit_reverse,
            Choice::Radix => &self.chose_radix,
            Choice::Comparison => &self.chose_comparison,
        };
        counter.fetch_add(1, AtomicOrdering::Relaxed);
        *self.last.lock().expect("choice log poisoned") = Some(*choice);
    }

    /// Snapshot of the lifetime totals.
    pub fn totals(&self) -> PlanTotals {
        PlanTotals {
            requests: self.requests.load(AtomicOrdering::Relaxed),
            early_exit_sorted: self.early_exit_sorted.load(AtomicOrdering::Relaxed),
            early_exit_reverse: self.early_exit_reverse.load(AtomicOrdering::Relaxed),
            chose_radix: self.chose_radix.load(AtomicOrdering::Relaxed),
            chose_comparison: self.chose_comparison.load(AtomicOrdering::Relaxed),
        }
    }

    /// The most recent decision, if any.
    pub fn last(&self) -> Option<PlanChoice> {
        *self.last.lock().expect("choice log poisoned")
    }
}

/// Outcome of [`resolve`]: either the data is already in final order
/// (the early exit ran), or the caller must run the named concrete
/// kernel over it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolved {
    /// Early exit already applied — `data` is sorted in place.
    Done,
    /// Run this concrete kernel (never [`KernelKind::Adaptive`]).
    Run(KernelKind),
}

/// The adaptive front-end: profile `data`, take an early exit when the
/// verify scan confirms the profile's hint, otherwise pick the cheaper
/// kernel. `PlanChoice::actual_ms` is left 0.0 for the caller to fill
/// after execution.
pub fn resolve<K: SortKey>(
    data: &mut [K],
    cost: &CostModel,
    digit_bits: u32,
) -> (Resolved, PlanChoice) {
    let profile = InputProfile::sample(data, digit_bits);
    let n = data.len();
    let choice = |chosen: Choice, predicted_ms: f64| PlanChoice {
        chosen,
        n,
        predicted_ms,
        actual_ms: 0.0,
        planned_passes: profile.planned_passes,
        duplicate_density: profile.duplicate_density(),
    };
    // The verify scans abort at the first violation, so a wrong hint
    // costs O(prefix), not O(n).
    if profile.looks_sorted() && data.windows(2).all(|w| w[0].key_le(&w[1])) {
        return (
            Resolved::Done,
            choice(Choice::EarlyExitSorted, cost.predict_verify_ms(n)),
        );
    }
    if profile.looks_reverse_sorted() && data.windows(2).all(|w| w[1].key_le(&w[0])) {
        data.reverse();
        return (
            Resolved::Done,
            choice(Choice::EarlyExitReverse, cost.predict_reverse_ms(n)),
        );
    }
    let (kernel, predicted_ms) = cost.decide(&profile);
    let chosen = match kernel {
        KernelKind::Bitonic => Choice::Comparison,
        _ => Choice::Radix,
    };
    (Resolved::Run(kernel), choice(chosen, predicted_ms))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Record;

    fn scrambled(n: usize) -> Vec<u32> {
        (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect()
    }

    #[test]
    fn profile_detects_direction_and_duplicates() {
        let sorted: Vec<u32> = (0..50_000).collect();
        let p = InputProfile::sample(&sorted, plan::DEFAULT_DIGIT_BITS);
        assert!(p.looks_sorted());
        assert!(!p.looks_reverse_sorted());
        assert!(p.duplicate_density() < 0.01);

        let reversed: Vec<u32> = (0..50_000).rev().collect();
        let p = InputProfile::sample(&reversed, plan::DEFAULT_DIGIT_BITS);
        assert!(!p.looks_sorted());
        assert!(p.looks_reverse_sorted());

        let constant = vec![42u32; 50_000];
        let p = InputProfile::sample(&constant, plan::DEFAULT_DIGIT_BITS);
        // All-equal counts as sorted (and never as reverse sorted).
        assert!(p.looks_sorted());
        assert!(!p.looks_reverse_sorted());
        assert!((p.duplicate_density() - 1.0).abs() < 1e-9);
        assert_eq!(p.planned_passes, 0);

        let random = scrambled(50_000);
        let p = InputProfile::sample(&random, plan::DEFAULT_DIGIT_BITS);
        assert!(!p.looks_sorted());
        assert!(!p.looks_reverse_sorted());
        assert_eq!(p.planned_passes, 3);
        assert!(p.varying_bits > 24);
    }

    #[test]
    fn profile_handles_degenerate_sizes() {
        for n in [0usize, 1, 2, 3, 127, 128, 129] {
            let data: Vec<u32> = (0..n as u32).collect();
            let p = InputProfile::sample(&data, plan::DEFAULT_DIGIT_BITS);
            assert_eq!(p.n, n);
            assert!(p.looks_sorted(), "n={n}");
            assert!(p.sampled <= n.max(1));
        }
    }

    #[test]
    fn cost_model_json_round_trips() {
        let m = CostModel {
            radix_ns_per_key_pass: 3.25,
            ..Default::default()
        };
        let text = m.to_json().to_string_pretty();
        let back = CostModel::from_json(&text).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn cost_model_rejects_bad_input() {
        // Unknown fields are typos, not extensions.
        assert!(CostModel::from_json(r#"{"version":1,"scan_ns":1.0}"#).is_err());
        // Version gate.
        assert!(CostModel::from_json(r#"{"version":2}"#).is_err());
        assert!(CostModel::from_json(r#"{"scan_ns_per_key":1.0}"#).is_err());
        // Values must be finite non-negative numbers.
        assert!(CostModel::from_json(r#"{"version":1,"scan_ns_per_key":-1}"#).is_err());
        assert!(CostModel::from_json(r#"{"version":1,"scan_ns_per_key":"fast"}"#).is_err());
        // Not an object / not JSON.
        assert!(CostModel::from_json("[1,2]").is_err());
        assert!(CostModel::from_json("{nope").is_err());
        // Partial calibration keeps defaults for the rest.
        let m = CostModel::from_json(r#"{"version":1,"scan_ns_per_key":9.5}"#).unwrap();
        assert_eq!(m.scan_ns_per_key, 9.5);
        assert_eq!(
            m.radix_ns_per_key_pass,
            CostModel::default().radix_ns_per_key_pass
        );
    }

    #[test]
    fn cost_model_resolve_empty_is_default() {
        assert_eq!(CostModel::resolve("").unwrap(), CostModel::default());
        assert!(CostModel::resolve("/nonexistent/cost.json").is_err());
    }

    #[test]
    fn decide_prefers_comparison_for_tiny_and_radix_for_large() {
        let m = CostModel::default();
        let tiny = InputProfile::sample(&scrambled(200), plan::DEFAULT_DIGIT_BITS);
        assert_eq!(m.decide(&tiny).0, KernelKind::Bitonic);
        let large = InputProfile::sample(&scrambled(4_000_000), plan::DEFAULT_DIGIT_BITS);
        assert_eq!(m.decide(&large).0, KernelKind::Radix);
    }

    #[test]
    fn resolve_early_exits_sorted_and_reverse() {
        let m = CostModel::default();
        let mut sorted: Vec<u32> = (0..10_000).collect();
        let (r, c) = resolve(&mut sorted, &m, plan::DEFAULT_DIGIT_BITS);
        assert_eq!(r, Resolved::Done);
        assert_eq!(c.chosen, Choice::EarlyExitSorted);
        assert!(crate::is_sorted(&sorted));

        let mut reversed: Vec<u32> = (0..10_000).rev().collect();
        let (r, c) = resolve(&mut reversed, &m, plan::DEFAULT_DIGIT_BITS);
        assert_eq!(r, Resolved::Done);
        assert_eq!(c.chosen, Choice::EarlyExitReverse);
        assert!(crate::is_sorted(&reversed));

        // Non-increasing with duplicate runs still reverses correctly:
        // equal keys are bit-identical, so any sorted arrangement is
        // the unique sorted byte sequence.
        let mut dups: Vec<u32> = (0..10_000u32).rev().map(|x| x / 7).collect();
        let input = dups.clone();
        let (r, _) = resolve(&mut dups, &m, plan::DEFAULT_DIGIT_BITS);
        assert_eq!(r, Resolved::Done);
        assert!(crate::is_sorted_permutation(&input, &dups));
    }

    #[test]
    fn resolve_rejects_false_hints() {
        let m = CostModel::default();
        // Sorted except one off-grid violation: the hint says sorted,
        // the verify scan must catch it and fall through to a kernel.
        let mut nearly: Vec<u32> = (0..100_000).collect();
        nearly.swap(11, 12);
        let before = nearly.clone();
        let (r, c) = resolve(&mut nearly, &m, plan::DEFAULT_DIGIT_BITS);
        assert!(matches!(r, Resolved::Run(_)));
        assert_ne!(c.chosen, Choice::EarlyExitSorted);
        assert_eq!(nearly, before, "resolve must not mutate on Run");
    }

    #[test]
    fn resolve_never_reverses_records_with_duplicate_keys() {
        let m = CostModel::default();
        // Keys descend with duplicates; record indices ascend. A naive
        // reversal would flip the tie order — the record total order
        // (key, idx) makes the run non-monotonic, forcing a full sort.
        let recs: Vec<Record<u32>> = (0..1000u32)
            .map(|i| Record {
                key: (1000 - i) / 4,
                idx: i,
            })
            .collect();
        let mut data = recs.clone();
        let (r, _) = resolve(&mut data, &m, plan::DEFAULT_DIGIT_BITS);
        assert!(matches!(r, Resolved::Run(_)), "must not early-exit");
        assert_eq!(data, recs);

        // Strictly descending records reverse safely.
        let mut strict: Vec<Record<u32>> = (0..1000u32)
            .map(|i| Record {
                key: 1000 - i,
                idx: i,
            })
            .collect();
        let (r, _) = resolve(&mut strict, &m, plan::DEFAULT_DIGIT_BITS);
        assert_eq!(r, Resolved::Done);
        assert!(crate::is_sorted(&strict));
    }

    #[test]
    fn resolve_handles_empty_and_single() {
        let m = CostModel::default();
        let mut empty: Vec<u32> = vec![];
        let (r, c) = resolve(&mut empty, &m, plan::DEFAULT_DIGIT_BITS);
        assert_eq!(r, Resolved::Done);
        assert_eq!(c.chosen, Choice::EarlyExitSorted);
        let mut one = vec![7u32];
        let (r, _) = resolve(&mut one, &m, plan::DEFAULT_DIGIT_BITS);
        assert_eq!(r, Resolved::Done);
    }

    #[test]
    fn choice_log_accumulates_and_reports_last() {
        let log = ChoiceLog::default();
        assert_eq!(log.totals(), PlanTotals::default());
        assert_eq!(log.last(), None);
        let c = PlanChoice {
            chosen: Choice::Radix,
            n: 100,
            predicted_ms: 1.0,
            actual_ms: 2.0,
            planned_passes: 3,
            duplicate_density: 0.0,
        };
        log.record(&c);
        log.record(&PlanChoice {
            chosen: Choice::EarlyExitSorted,
            ..c
        });
        let t = log.totals();
        assert_eq!(t.requests, 2);
        assert_eq!(t.chose_radix, 1);
        assert_eq!(t.early_exit_sorted, 1);
        assert_eq!(log.last().unwrap().chosen, Choice::EarlyExitSorted);
    }

    #[test]
    fn plan_choice_summary_is_parseable() {
        let c = PlanChoice {
            chosen: Choice::EarlyExitReverse,
            n: 4096,
            predicted_ms: 0.5,
            actual_ms: 0.75,
            planned_passes: 0,
            duplicate_density: 0.25,
        };
        let s = c.summary();
        assert!(s.contains("choice=early_exit_reverse"));
        assert!(s.contains("n=4096"));
        assert!(s.contains("pred_ms=0.500"));
        assert!(s.contains("act_ms=0.750"));
    }
}

//! Baseline: **Thrust Merge** — the comparison-based merge sort of
//! Satish, Harris & Garland (IPDPS 2009) [14], the best GPU comparison
//! sort before sample sort.
//!
//! Structure (following [14]):
//! * split the input into shared-memory tiles and sort each with an
//!   **odd-even merge network** (their Batcher's-network choice; same
//!   O(t log² t) class as our bitonic tile sort);
//! * then log₂(m) rounds of pairwise **two-way merge**, each round
//!   streaming the whole array: pairs of sorted runs are merged by
//!   splitting them into parallel chunks via rank binary searches and
//!   merging each chunk in shared memory.
//!
//! The merge path is the weak spot the paper exploits: unlike a bitonic
//! pass, a two-way merge advances data-dependently, so its inner loop
//! branches diverge across a warp (§2's SIMT discussion) — we charge the
//! per-key merge work as divergent ops, which is what makes this
//! baseline land at the paper's reported ~3–5× deficit against both
//! sample sorts (Figures 6 & 7).
//!
//! The published code could not sort beyond 16M items ("the current
//! Thrust Merge Sort code shows memory errors", §5 citing Garland [5]);
//! [`ThrustMergeSort::MAX_N`] reproduces that operational ceiling.

use super::bitonic;
use crate::error::{Error, Result};
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::sim::{CostModel, GpuSim};
use crate::{Key, KEY_BYTES};

/// Parameters of the Thrust Merge baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ThrustMergeParams {
    /// Shared-memory tile size for the initial odd-even sort.
    pub tile: usize,
}

impl Default for ThrustMergeParams {
    fn default() -> Self {
        ThrustMergeParams { tile: 1024 }
    }
}

/// Report of one Thrust Merge run.
#[derive(Debug, Clone)]
pub struct ThrustMergeReport {
    /// Input size.
    pub n: usize,
    /// Traffic ledger.
    pub ledger: Ledger,
    /// Merge rounds executed.
    pub rounds: usize,
}

impl ThrustMergeReport {
    /// Estimated milliseconds on `spec`.
    pub fn total_estimated_ms(&self, spec: &crate::sim::GpuSpec) -> f64 {
        CostModel::default_params(spec).ledger_ms(&self.ledger)
    }
}

/// The Thrust Merge sorter.
#[derive(Debug, Clone)]
pub struct ThrustMergeSort {
    params: ThrustMergeParams,
}

impl ThrustMergeSort {
    /// Operational ceiling of the published implementation: 16M items
    /// (§5, [5]). Inputs beyond this return [`Error::Runtime`].
    pub const MAX_N: usize = 16 << 20;

    /// Peak device footprint per key: input + output ping-pong buffers
    /// plus rank/offset arrays per round.
    pub const BYTES_PER_KEY: usize = 16;

    /// Construct with the given parameters.
    pub fn new(params: ThrustMergeParams) -> Self {
        assert!(params.tile.is_power_of_two());
        ThrustMergeSort { params }
    }

    /// Sort `keys` on the simulated device.
    pub fn sort(&self, keys: &mut [Key], sim: &mut GpuSim) -> Result<ThrustMergeReport> {
        let n = keys.len();
        if n > Self::MAX_N {
            return Err(Error::Runtime(format!(
                "Thrust Merge code fails beyond {}M items (memory errors; Garland, private communication [5]) — requested {}M",
                Self::MAX_N >> 20,
                n >> 20
            )));
        }
        let alloc = sim.alloc(n * Self::BYTES_PER_KEY)?;
        let mut ledger = Ledger::default();
        let tile = self.params.tile;

        // Phase 1: pad to tile multiple, odd-even/bitonic network per tile.
        let padded = n.div_ceil(tile).max(1) * tile;
        let mut work: Vec<Key> = Vec::with_capacity(padded);
        work.extend_from_slice(keys);
        work.resize(padded, Key::MAX);
        let m = padded / tile;
        for t in work.chunks_exact_mut(tile) {
            bitonic::sort_slice(t);
        }
        record_tile_sort(padded, tile, m, &mut ledger);

        // Phase 2: log2(m) two-way merge rounds.
        let mut rounds = 0usize;
        let mut run = tile;
        let mut src = work;
        let mut dst = vec![0 as Key; padded];
        while run < padded {
            for pair_start in (0..padded).step_by(2 * run) {
                let a_end = (pair_start + run).min(padded);
                let b_end = (pair_start + 2 * run).min(padded);
                merge_into(
                    &src[pair_start..a_end],
                    &src[a_end..b_end],
                    &mut dst[pair_start..b_end],
                );
            }
            record_merge_round(padded, tile, &mut ledger);
            std::mem::swap(&mut src, &mut dst);
            run *= 2;
            rounds += 1;
        }
        keys.copy_from_slice(&src[..n]);

        sim.free(alloc);
        sim.ledger_mut().extend_from(&ledger);
        Ok(ThrustMergeReport { n, ledger, rounds })
    }
}

impl ThrustMergeSort {
    /// Ledger-only twin of [`ThrustMergeSort::sort`]: Thrust Merge's
    /// pass structure is input-independent (tile sort + ⌈log₂ m⌉ full
    /// merge rounds), so the analytic ledger matches the executed one
    /// exactly — this is what runs the paper-scale points of
    /// Figures 6 & 7.
    pub fn sort_analytic(&self, n: usize, sim: &mut GpuSim) -> Result<ThrustMergeReport> {
        if n > Self::MAX_N {
            return Err(Error::Runtime(format!(
                "Thrust Merge code fails beyond {}M items (memory errors; Garland, private communication [5]) — requested {}M",
                Self::MAX_N >> 20,
                n >> 20
            )));
        }
        let alloc = sim.alloc(n * Self::BYTES_PER_KEY)?;
        let mut ledger = Ledger::default();
        let tile = self.params.tile;
        let padded = n.div_ceil(tile).max(1) * tile;
        let m = padded / tile;
        record_tile_sort(padded, tile, m, &mut ledger);
        let mut rounds = 0usize;
        let mut run = tile;
        while run < padded {
            record_merge_round(padded, tile, &mut ledger);
            run *= 2;
            rounds += 1;
        }
        sim.free(alloc);
        sim.ledger_mut().extend_from(&ledger);
        Ok(ThrustMergeReport { n, ledger, rounds })
    }
}

/// Phase 1: one consolidated launch odd-even-sorting every tile in
/// shared memory.
fn record_tile_sort(padded: usize, tile: usize, m: usize, ledger: &mut Ledger) {
    let ces = m as u64 * bitonic::ce_count(tile);
    ledger.begin_kernel(KernelClass::LocalSort, m as u64, MAX_BLOCK_THREADS);
    ledger.add_coalesced(2 * (padded * KEY_BYTES) as u64);
    ledger.add_smem(4 * ces);
    ledger.add_compute(ces);
    ledger.end_kernel();
}

/// Sequential two-way merge (the real work standing in for the GPU's
/// chunked parallel merge).
fn merge_into(a: &[Key], b: &[Key], out: &mut [Key]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j) = (0usize, 0usize);
    for slot in out.iter_mut() {
        let take_a = j >= b.len() || (i < a.len() && a[i] <= b[j]);
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

/// One merge round over the whole array.
///
/// Traffic: coalesced read + write of every key; per key, the rank
/// binary-search and merge-advance work. The merge inner loop is data-
/// dependent, so the bulk of its per-key work is charged as divergent
/// (§2) — calibrated to [14]'s reported ~55 Mkeys/s merge throughput.
fn record_merge_round(n: usize, tile: usize, ledger: &mut Ledger) {
    let blocks = n.div_ceil(tile) as u64;
    ledger.begin_kernel(KernelClass::Merge, blocks, MAX_BLOCK_THREADS);
    ledger.add_coalesced(2 * (n * KEY_BYTES) as u64);
    // Rank searches: log2(run) ≈ log2(tile..n) probes; charge log2(n).
    let probes = (n.max(2) as f64).log2().ceil() as u64;
    ledger.add_compute(n as u64 * 2 + (n as u64 / tile as u64) * probes);
    ledger.add_smem(n as u64 * 2);
    // Divergent merge-advance: ~4 serialized ops per key per round.
    ledger.add_divergent(4 * n as u64);
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::is_sorted_permutation;

    fn sorter() -> ThrustMergeSort {
        ThrustMergeSort::new(ThrustMergeParams { tile: 256 })
    }

    #[test]
    fn sorts_various_sizes() {
        for n in [0usize, 1, 255, 256, 1000, 4096, 50_000] {
            let mut keys: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();
            let orig = keys.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            sorter().sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&orig, &keys), "n={n}");
        }
    }

    #[test]
    fn sorts_duplicates_and_sorted_input() {
        for input in [vec![9u32; 5000], (0..5000u32).collect(), (0..5000u32).rev().collect()] {
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            sorter().sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&input, &keys));
        }
    }

    #[test]
    fn round_count() {
        let mut keys: Vec<Key> = (0..4096u32).rev().collect();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let r = sorter().sort(&mut keys, &mut sim).unwrap();
        // 4096 / 256 = 16 tiles → 4 merge rounds.
        assert_eq!(r.rounds, 4);
    }

    #[test]
    fn sixteen_million_ceiling() {
        let s = ThrustMergeSort::new(ThrustMergeParams::default());
        let mut sim = GpuSim::new(GpuModel::TeslaC1060.spec());
        let mut too_big = vec![0u32; ThrustMergeSort::MAX_N + 1];
        let err = s.sort(&mut too_big, &mut sim).unwrap_err();
        assert!(matches!(err, Error::Runtime(_)));
        assert!(err.to_string().contains("16M"));
    }

    #[test]
    fn analytic_matches_executed() {
        for n in [1000usize, 4096, 100_000] {
            let mut keys: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();
            let mut sim_e = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let exec = sorter().sort(&mut keys, &mut sim_e).unwrap();
            let mut sim_a = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let ana = sorter().sort_analytic(n, &mut sim_a).unwrap();
            assert_eq!(exec.ledger, ana.ledger, "n={n}");
            assert_eq!(exec.rounds, ana.rounds);
        }
    }

    #[test]
    fn slower_than_deterministic_sample_sort() {
        // Figures 6 & 7 at the paper's own scale (16M keys, GTX 285):
        // both sample sorts clearly beat Thrust Merge.
        use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
        let spec = GpuModel::Gtx285_2G.spec();
        let n = 16 << 20;

        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let tm = ThrustMergeSort::new(ThrustMergeParams::default())
            .sort_analytic(n, &mut sim)
            .unwrap();
        let mut sim2 = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let bs = BucketSort::new(BucketSortParams::default())
            .sort_analytic(n, &mut sim2)
            .unwrap();

        let t_tm = tm.total_estimated_ms(&spec);
        let t_bs = bs.total_estimated_ms(&spec);
        assert!(
            t_tm > 1.5 * t_bs,
            "thrust merge {t_tm:.1} ms should clearly exceed bucket sort {t_bs:.1} ms"
        );
    }
}

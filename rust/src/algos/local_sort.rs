//! Steps 1–2 of Algorithm 1: split the input into m sublists of
//! shared-memory size (n/m = 2K items on Table 1 hardware) and bitonic-
//! sort each sublist on one SM.
//!
//! On the GPU this is one kernel launch of m blocks × 512 threads: each
//! block performs a coalesced read of its tile into shared memory, runs
//! the bitonic network there (each thread owning n/m/512 = 4 items), and
//! writes the sorted tile back with a coalesced write (§4). The paper
//! measured bitonic consistently fastest here against quicksort and
//! adaptive bitonic sort, because tiles are always 2K items regardless
//! of n.

use super::{bitonic, plan, sampling, ExecContext, KernelKind};
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::util::pool;
use crate::{SortKey, KEY_BYTES};

/// Sort every `tile`-sized sublist of `keys` in place and record the
/// launch (traffic scales with [`SortKey::WIDTH_BYTES`]). `keys.len()`
/// must be a multiple of `tile`; `tile` a power of two. Returns the
/// number of tiles (m). Uses a transient default [`ExecContext`]; the
/// engines pass a persistent one through [`run_in`].
pub fn run<K: SortKey>(keys: &mut [K], tile: usize, ledger: &mut Ledger) -> usize {
    run_in(keys, tile, &ExecContext::default(), ledger)
}

/// [`run`] with explicit execution resources: tiles are sorted in
/// parallel on the resident worker pool (disjoint tiles, so the output
/// is byte-identical at any worker count) with the context's selected
/// kernel, per-worker scratch coming from the context's arena. The
/// recorded launch is identical for either kernel — the ledger keeps
/// the paper's Step-2 bitonic analytics.
pub fn run_in<K: SortKey>(
    keys: &mut [K],
    tile: usize,
    ctx: &ExecContext,
    ledger: &mut Ledger,
) -> usize {
    assert!(tile.is_power_of_two(), "tile must be a power of two");
    assert_eq!(keys.len() % tile, 0, "input must be tile-aligned");
    let m = keys.len() / tile;
    if m == 0 {
        return 0;
    }
    let workers = ctx.effective_workers();
    pool::parallel_chunks_mut(keys, tile, workers, |_, t| sort_tile(t, ctx));
    record(m, tile, K::WIDTH_BYTES, ledger);
    m
}

/// Fused Steps 2+3: sort every tile **and** extract its `s` equidistant
/// samples in the same traversal — the worker that just sorted a tile
/// reads the sample positions while the tile is still cache-hot, so
/// [`sampling::local_samples_into`]'s separate pass over the sorted
/// array disappears. `samples` is resized to `m·s` and filled in tile
/// order (disjoint rows, so the parallel write is race-free and
/// byte-identical at any worker count).
///
/// The ledger records the *same two launches* as the unfused pair
/// (Step 2 local sort, then Step 3 sampling) — fusion is a host
/// execution detail; the paper's analytic figures are unchanged.
pub fn run_sampled<K: SortKey>(
    keys: &mut [K],
    tile: usize,
    s: usize,
    ctx: &ExecContext,
    samples: &mut Vec<K>,
    ledger: &mut Ledger,
) -> usize {
    assert!(tile.is_power_of_two(), "tile must be a power of two");
    assert_eq!(keys.len() % tile, 0, "input must be tile-aligned");
    assert!(s >= 1 && s <= tile, "need 1 <= s <= tile");
    assert_eq!(tile % s, 0, "s must divide the tile size");
    let m = keys.len() / tile;
    samples.clear();
    if m == 0 {
        return 0;
    }
    samples.resize(m * s, keys[0]);
    let stride = tile / s;
    let pairs: Vec<(&mut [K], &mut [K])> = keys
        .chunks_mut(tile)
        .zip(samples.chunks_mut(s))
        .collect();
    pool::parallel_map(pairs, ctx.effective_workers(), |(t, row)| {
        sort_tile(t, ctx);
        for (p, slot) in row.iter_mut().enumerate() {
            *slot = t[(p + 1) * stride - 1];
        }
    });
    record(m, tile, K::WIDTH_BYTES, ledger);
    sampling::analytic_local_bytes(m * tile, tile, s, K::WIDTH_BYTES, ledger);
    m
}

/// Sort one tile with the context's kernel (planned wide-digit LSD, or
/// the bitonic network), scratch from the arena.
fn sort_tile<K: SortKey>(t: &mut [K], ctx: &ExecContext) {
    match ctx.kernel {
        KernelKind::Bitonic => {
            let ces = bitonic::sort_slice(t);
            debug_assert_eq!(ces, bitonic::ce_count(t.len()));
        }
        // The adaptive front-end decides at whole-request granularity;
        // inside a tile it executes as the radix kernel so the
        // simulated engines stay kernel-invariant.
        KernelKind::Radix | KernelKind::Adaptive => {
            let mut scratch = ctx.arena.take_empty::<K>();
            let mut counts = ctx.arena.take_empty::<usize>();
            plan::planned_sort(t, &mut scratch, &mut counts, ctx.digit_bits, None);
        }
    }
}

/// Ledger-only twin of [`run`] at the classic `u32` width.
pub fn analytic(n: usize, tile: usize, ledger: &mut Ledger) -> usize {
    analytic_bytes(n, tile, KEY_BYTES, ledger)
}

/// Ledger-only twin of [`run`] for paper-scale n, at an explicit
/// per-element width.
pub fn analytic_bytes(n: usize, tile: usize, elem_bytes: usize, ledger: &mut Ledger) -> usize {
    assert!(tile.is_power_of_two());
    assert_eq!(n % tile, 0);
    let m = n / tile;
    if m > 0 {
        record(m, tile, elem_bytes, ledger);
    }
    m
}

/// One launch, m blocks: coalesced read+write of the whole array plus
/// the in-shared-memory network (4 shared accesses per compare-exchange:
/// two loads, two stores).
fn record(m: usize, tile: usize, elem_bytes: usize, ledger: &mut Ledger) {
    let n = m * tile;
    let ces = m as u64 * bitonic::ce_count(tile);
    ledger.begin_kernel(
        KernelClass::LocalSort,
        m as u64,
        MAX_BLOCK_THREADS.min((tile / 2).max(1) as u32),
    );
    ledger.tag_step(2);
    ledger.add_coalesced(2 * (n * elem_bytes) as u64);
    ledger.add_smem(4 * ces);
    ledger.add_compute(ces);
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{is_sorted, Key};

    fn scrambled(n: usize) -> Vec<Key> {
        (0..n as u32).map(|x| x.wrapping_mul(2654435761) ^ 0xABCD).collect()
    }

    #[test]
    fn sorts_each_tile_independently() {
        let tile = 256;
        let mut keys = scrambled(4 * tile);
        let mut led = Ledger::default();
        let m = run(&mut keys, tile, &mut led);
        assert_eq!(m, 4);
        for t in keys.chunks_exact(tile) {
            assert!(is_sorted(t));
        }
        // Whole array is (almost surely) not globally sorted.
        assert!(!is_sorted(&keys));
    }

    #[test]
    fn ledger_matches_analytic() {
        let tile = 128;
        let mut keys = scrambled(8 * tile);
        let mut led_exec = Ledger::default();
        run(&mut keys, tile, &mut led_exec);
        let mut led_ana = Ledger::default();
        analytic(8 * tile, tile, &mut led_ana);
        assert_eq!(led_exec, led_ana);
    }

    #[test]
    fn launch_shape() {
        let mut led = Ledger::default();
        analytic(16 * 2048, 2048, &mut led);
        assert_eq!(led.kernel_count(), 1);
        let k = &led.kernels()[0];
        assert_eq!(k.step, 2);
        assert_eq!(k.blocks, 16);
        assert_eq!(k.threads_per_block, 512);
        assert_eq!(k.coalesced_bytes, 2 * 16 * 2048 * 4);
    }

    #[test]
    fn kernels_agree_and_record_identically() {
        let tile = 256;
        let input = scrambled(16 * tile);
        let mut by_bitonic = input.clone();
        let mut led_b = Ledger::default();
        run_in(
            &mut by_bitonic,
            tile,
            &crate::ExecContext::new(crate::KernelKind::Bitonic, 2),
            &mut led_b,
        );
        let mut by_radix = input.clone();
        let mut led_r = Ledger::default();
        run_in(
            &mut by_radix,
            tile,
            &crate::ExecContext::new(crate::KernelKind::Radix, 4),
            &mut led_r,
        );
        assert_eq!(by_bitonic, by_radix);
        assert_eq!(led_b, led_r, "ledger must not depend on the executed kernel");
        for t in by_radix.chunks_exact(tile) {
            assert!(is_sorted(t));
        }
    }

    #[test]
    fn fused_sampling_matches_unfused_pair() {
        // run_sampled must equal run_in + local_samples_into exactly:
        // same sorted tiles, same samples, same two-launch ledger — at
        // any worker count and for either kernel.
        let (tile, s) = (256usize, 16usize);
        let input = scrambled(8 * tile);
        let mut unfused = input.clone();
        let mut led_u = Ledger::default();
        let base_ctx = crate::ExecContext::default();
        run_in(&mut unfused, tile, &base_ctx, &mut led_u);
        let mut ref_samples: Vec<Key> = Vec::new();
        sampling::local_samples_into(&unfused, tile, s, &mut ref_samples, &mut led_u);
        for kernel in [crate::KernelKind::Bitonic, crate::KernelKind::Radix] {
            for workers in [1usize, 2, 4] {
                let ctx = crate::ExecContext::new(kernel, workers);
                let mut fused = input.clone();
                let mut samples = Vec::new();
                let mut led_f = Ledger::default();
                let m = run_sampled(&mut fused, tile, s, &ctx, &mut samples, &mut led_f);
                assert_eq!(m, 8);
                assert_eq!(fused, unfused, "{kernel} × {workers}w");
                assert_eq!(samples, ref_samples, "{kernel} × {workers}w");
                assert_eq!(led_f, led_u, "fusion must not change the ledger");
            }
        }
    }

    #[test]
    fn fused_sampling_handles_empty_input() {
        let mut keys: Vec<Key> = vec![];
        let mut samples = vec![1u32; 3]; // stale content must be cleared
        let mut led = Ledger::default();
        let ctx = crate::ExecContext::default();
        assert_eq!(run_sampled(&mut keys, 64, 16, &ctx, &mut samples, &mut led), 0);
        assert!(samples.is_empty());
        assert_eq!(led.kernel_count(), 0);
    }

    #[test]
    #[should_panic(expected = "tile-aligned")]
    fn rejects_misaligned() {
        let mut keys = scrambled(100);
        run(&mut keys, 64, &mut Ledger::default());
    }

    #[test]
    fn empty_input_no_launch() {
        let mut keys: Vec<Key> = vec![];
        let mut led = Ledger::default();
        assert_eq!(run(&mut keys, 64, &mut led), 0);
        assert_eq!(led.kernel_count(), 0);
    }
}

//! Step 8 of Algorithm 1: Data Relocation — move every bucket A_ij to
//! its start location l_ij, producing the s sublists B_1 … B_s.
//!
//! The paper singles this step out as "perfectly suited for a GPU": one
//! parallel coalesced read followed by one parallel coalesced write per
//! key (§4, and visibly cheap in Figure 5). Each block handles one
//! sublist A_i: its keys are already contiguous and sorted, each bucket
//! A_ij is a contiguous segment `[b_{i,j-1}, b_ij)` of the tile, and the
//! destination of that segment is the contiguous range starting at
//! l_ij — so both sides of the copy stream linearly.

use super::indexing;
use super::prefix::BucketLayout;
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::{SortKey, KEY_BYTES};

/// Relocate all buckets. `keys` is the tile-aligned, per-tile-sorted
/// array; `boundaries` the m×s boundary matrix of Step 6; `layout` the
/// Step-7 result. `out` must have `keys.len()` capacity and is fully
/// overwritten. For [`crate::Record`] elements the payload index moves
/// with its key — this is the key–value half of Step 8.
pub fn relocate<K: SortKey>(
    keys: &[K],
    tile: usize,
    boundaries_mat: &[u32],
    layout: &BucketLayout,
    out: &mut [K],
    ledger: &mut Ledger,
) {
    relocate_inner(keys, tile, boundaries_mat, layout, out, ledger, None);
}

/// [`relocate`] fused with the Step-9 radix kernel's first counting
/// pass: while each bucket segment streams through the scatter, the
/// per-bucket histogram of the **bit-0 digit** (`digit_bits` wide) is
/// accumulated into `bucket_counts` (a typically arena-recycled
/// buffer, sized to `s × 2^digit_bits` and zeroed here in one pass).
/// The Step-9 planned sorts then start with pass 1 prebuilt — their
/// first counting traversal disappears (see
/// [`crate::algos::plan::execute`]; the histogram is ignored when
/// planning elides the bit-0 digit, where it would be single-bin
/// anyway).
///
/// Byte-identical to the unfused [`relocate`] (the histogram is
/// write-only here), and the recorded launch is the same Step-8 record
/// — the paper's analytic figures never see the fusion.
#[allow(clippy::too_many_arguments)]
pub fn relocate_with_prep<K: SortKey>(
    keys: &[K],
    tile: usize,
    boundaries_mat: &[u32],
    layout: &BucketLayout,
    out: &mut [K],
    ledger: &mut Ledger,
    digit_bits: u32,
    bucket_counts: &mut Vec<usize>,
) {
    relocate_inner(
        keys,
        tile,
        boundaries_mat,
        layout,
        out,
        ledger,
        Some((digit_bits, bucket_counts)),
    );
}

fn relocate_inner<K: SortKey>(
    keys: &[K],
    tile: usize,
    boundaries_mat: &[u32],
    layout: &BucketLayout,
    out: &mut [K],
    ledger: &mut Ledger,
    prep: Option<(u32, &mut Vec<usize>)>,
) {
    assert_eq!(keys.len(), out.len(), "out must match input length");
    assert_eq!(keys.len() % tile, 0, "input must be tile-aligned");
    let m = keys.len() / tile;
    if m == 0 {
        return;
    }
    let s = boundaries_mat.len() / m;
    assert_eq!(boundaries_mat.len(), m * s);
    assert_eq!(layout.loc.len(), m * s);
    let mut prep = prep.map(|(digit_bits, counts)| {
        let radix = 1usize << digit_bits;
        // One zeroing pass: clear is O(1) for plain counts, resize
        // writes the zeros (recycled capacity makes this the only
        // touch of the buffer before accumulation).
        counts.clear();
        counts.resize(s * radix, 0);
        (digit_bits, radix, counts)
    });

    for (i, t) in keys.chunks_exact(tile).enumerate() {
        let row = &boundaries_mat[i * s..(i + 1) * s];
        let sizes = indexing::row_bucket_sizes(row);
        let mut seg_start = 0usize;
        for j in 0..s {
            let len = sizes[j] as usize;
            let dst = layout.loc[i * s + j] as usize;
            let seg = &t[seg_start..seg_start + len];
            out[dst..dst + len].copy_from_slice(seg);
            if let Some((digit_bits, radix, ref mut counts)) = prep {
                let row = &mut counts[j * radix..(j + 1) * radix];
                for &x in seg {
                    row[x.radix_digit(0, digit_bits)] += 1;
                }
            }
            seg_start += len;
        }
        debug_assert_eq!(seg_start, tile);
    }
    record(m, tile, s, K::WIDTH_BYTES, ledger);
}

/// Ledger-only twin of [`relocate`] at the classic `u32` width.
pub fn analytic(n: usize, tile: usize, s: usize, ledger: &mut Ledger) {
    analytic_bytes(n, tile, s, KEY_BYTES, ledger);
}

/// Ledger-only twin of [`relocate`] at an explicit element width.
pub fn analytic_bytes(n: usize, tile: usize, s: usize, elem_bytes: usize, ledger: &mut Ledger) {
    assert_eq!(n % tile, 0);
    let m = n / tile;
    if m > 0 {
        record(m, tile, s, elem_bytes, ledger);
    }
}

fn record(m: usize, tile: usize, s: usize, elem_bytes: usize, ledger: &mut Ledger) {
    let n = m * tile;
    ledger.begin_kernel(KernelClass::Relocation, m as u64, MAX_BLOCK_THREADS);
    ledger.tag_step(8);
    // Coalesced read of every key plus the per-block boundary/location
    // rows; the write side streams one segment (avg tile/s keys) per
    // bucket. Segments at least one memory transaction long coalesce
    // fully; shorter ones each burn a whole transaction — this is the
    // high-s coalescing degradation behind Figure 3's right edge.
    // Wider elements (u64 keys, key–value records) reach the coalescing
    // threshold at proportionally higher s. The boundary/location
    // matrices hold u32 counts regardless of key type, so their reads
    // do not widen.
    ledger.add_coalesced((n * elem_bytes) as u64);
    ledger.add_coalesced(2 * (m * s * KEY_BYTES) as u64);
    let seg_bytes = (tile / s).max(1) * elem_bytes;
    if seg_bytes >= crate::sim::spec::MEM_TRANSACTION_BYTES {
        ledger.add_coalesced((n * elem_bytes) as u64);
    } else {
        ledger.add_scattered((m * s) as u64);
    }
    ledger.add_compute((m * s) as u64);
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::prefix::column_prefix;
    use crate::algos::{indexing::boundaries, sampling};
    use crate::{is_sorted_permutation, Key};

    /// End-to-end Steps 6–8 on a small instance: after relocation, every
    /// key of bucket j is ≤ every key of bucket j+1, and the array is a
    /// permutation of the input.
    #[test]
    fn buckets_are_ordered_after_relocation() {
        let tile = 16usize;
        let m = 8usize;
        let n = tile * m;
        let mut keys: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761) % 1000).collect();
        let orig = keys.clone();
        for t in keys.chunks_exact_mut(tile) {
            t.sort_unstable();
        }
        let s = 4usize;
        let mut led = Ledger::default();
        let samples = sampling::local_samples(&keys, tile, s, &mut led);
        let mut sorted_samples = samples.clone();
        sorted_samples.sort_unstable();
        let splitters = sampling::select_splitters(&sorted_samples, s, &mut led);
        let b = boundaries(&keys, tile, &splitters, &mut led);
        let counts: Vec<u32> = b
            .chunks_exact(s)
            .flat_map(|row| indexing::row_bucket_sizes(row))
            .collect();
        let layout = column_prefix(&counts, m, s, &mut led);
        let mut out = vec![0u32; n];
        relocate(&keys, tile, &b, &layout, &mut out, &mut led);

        // Bucket ordering: every element of B_j < splitter_j ≤ B_{j+1}.
        for j in 0..s {
            let st = layout.bucket_start[j] as usize;
            let en = st + layout.bucket_size[j] as usize;
            for &x in &out[st..en] {
                if j > 0 {
                    assert!(x >= splitters[j - 1]);
                }
                if j < s - 1 {
                    assert!(x < splitters[j]);
                }
            }
        }
        // Permutation check: sorting each bucket yields a full sort.
        let mut full = out.clone();
        for j in 0..s {
            let st = layout.bucket_start[j] as usize;
            let en = st + layout.bucket_size[j] as usize;
            full[st..en].sort_unstable();
        }
        assert!(is_sorted_permutation(&orig, &full));
    }

    #[test]
    fn fused_prep_matches_unfused_relocation_and_recount() {
        use crate::SortKey;
        // Same Steps 6–8 harness as above, with the fused variant: the
        // output and ledger must match plain relocate exactly, and the
        // accumulated per-bucket histograms must equal a recount over
        // the relocated buckets.
        let tile = 16usize;
        let m = 8usize;
        let n = tile * m;
        let mut keys: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761) % 1000).collect();
        for t in keys.chunks_exact_mut(tile) {
            t.sort_unstable();
        }
        let s = 4usize;
        let mut led = Ledger::default();
        let samples = sampling::local_samples(&keys, tile, s, &mut led);
        let mut sorted_samples = samples.clone();
        sorted_samples.sort_unstable();
        let splitters = sampling::select_splitters(&sorted_samples, s, &mut led);
        let b = boundaries(&keys, tile, &splitters, &mut led);
        let counts_mat: Vec<u32> = b
            .chunks_exact(s)
            .flat_map(|row| indexing::row_bucket_sizes(row))
            .collect();
        let layout = column_prefix(&counts_mat, m, s, &mut led);

        let mut plain_out = vec![0u32; n];
        let mut led_plain = Ledger::default();
        relocate(&keys, tile, &b, &layout, &mut plain_out, &mut led_plain);

        let digit_bits = 5u32;
        let radix = 1usize << digit_bits;
        let mut hist = vec![7usize; s * radix]; // dirty: must be zeroed inside
        let mut fused_out = vec![0u32; n];
        let mut led_fused = Ledger::default();
        relocate_with_prep(
            &keys,
            tile,
            &b,
            &layout,
            &mut fused_out,
            &mut led_fused,
            digit_bits,
            &mut hist,
        );
        assert_eq!(fused_out, plain_out, "fusion must not move bytes differently");
        assert_eq!(led_fused, led_plain, "fusion must not change the ledger");

        // Histogram check: recount each relocated bucket's first digit.
        for j in 0..s {
            let st = layout.bucket_start[j] as usize;
            let en = st + layout.bucket_size[j] as usize;
            let mut expect = vec![0usize; radix];
            for &x in &fused_out[st..en] {
                expect[SortKey::radix_digit(x, 0, digit_bits)] += 1;
            }
            assert_eq!(&hist[j * radix..(j + 1) * radix], &expect[..], "bucket {j}");
        }
    }

    #[test]
    fn ledger_matches_analytic() {
        let tile = 8;
        let keys: Vec<Key> = (0..32).collect();
        let b: Vec<u32> = keys
            .chunks_exact(tile)
            .flat_map(|_| vec![4u32, 8])
            .collect();
        let counts: Vec<u32> = b
            .chunks_exact(2)
            .flat_map(|row| indexing::row_bucket_sizes(row))
            .collect();
        let layout = column_prefix(&counts, 4, 2, &mut Ledger::default());
        let mut out = vec![0u32; 32];
        let mut a = Ledger::default();
        relocate(&keys, tile, &b, &layout, &mut out, &mut a);
        let mut bb = Ledger::default();
        analytic(32, tile, 2, &mut bb);
        assert_eq!(a, bb);
    }

    #[test]
    fn coalesced_traffic_is_two_passes() {
        let mut led = Ledger::default();
        analytic(1 << 20, 2048, 64, &mut led);
        let k = &led.kernels()[0];
        // 2 passes × 4 B/key dominate; matrix reads are the small extra.
        let expect_min = 2 * (1u64 << 20) * 4;
        assert!(k.coalesced_bytes >= expect_min);
        assert!(k.coalesced_bytes < expect_min + (1 << 20));
        assert_eq!(
            k.scattered_transactions, 0,
            "Step 8 is fully coalesced at s=64 (segments of 32 keys = 128 B)"
        );

        // At very large s the segments drop under one transaction and
        // the write side degrades (Figure 3's right edge).
        let mut led2 = Ledger::default();
        analytic(1 << 20, 2048, 512, &mut led2);
        assert!(led2.kernels()[0].scattered_transactions > 0);
    }
}

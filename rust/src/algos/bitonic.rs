//! Bitonic sorting networks — the engine behind Steps 2, 4 and 9 of
//! Algorithm 1.
//!
//! The paper selects bitonic sort for all three sorting sub-phases
//! despite its O(n log² n) work, because for the sizes involved "the
//! simplicity of bitonic sort, its small constants in the running time,
//! and its perfect match for SIMD style parallelism outweigh the
//! disadvantage of additional work" (§4). The network is data-oblivious:
//! no data-dependent branches, hence no SIMT divergence (§2) — every
//! compare-exchange is a branch-free min/max.
//!
//! Two execution contexts:
//! * [`sort_tile`] — one shared-memory-resident tile (Step 2), all passes
//!   on SM-local memory;
//! * [`global_sort`] — an arbitrary power-of-two array in global memory
//!   (Steps 4 and 9), where merge substages with span ≥ tile are global
//!   passes (one coalesced read+write of the array each) and the dense
//!   low-span substages of each merge stage are consolidated into a
//!   single tile-resident launch, exactly the classic hybrid
//!   global/shared bitonic of GPUTeraSort [6].
//!
//! Every function returns or records exact operation counts; the
//! `*_analytic` twins produce the same ledger without touching data
//! (verified equal by property tests), which is what lets the benchmark
//! harness run the paper's 512M-key configurations.

use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::{SortKey, KEY_BYTES};

/// log2 of a power of two.
#[inline]
pub fn log2_exact(n: usize) -> u32 {
    debug_assert!(n.is_power_of_two());
    n.trailing_zeros()
}

/// Number of compare-exchange operations of a full bitonic sort network
/// over `n` (power-of-two) keys: `n/2 · log n · (log n + 1) / 2`.
pub fn ce_count(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let ln = log2_exact(n) as u64;
    (n as u64 / 2) * ln * (ln + 1) / 2
}

/// Number of compare-exchange substages ("passes") of the network:
/// `log n (log n + 1) / 2`.
pub fn pass_count(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    let ln = log2_exact(n) as u64;
    ln * (ln + 1) / 2
}

/// In-place bitonic sort of a power-of-two slice, ordering by
/// [`SortKey::to_bits`]. Returns the number of compare-exchanges
/// performed (always [`ce_count`]`(len)` — the network is oblivious).
///
/// This is the host-side "real work" of the simulated Step 2; it mirrors
/// exactly the compare-exchange sequence a 512-thread block would run.
pub fn sort_slice<K: SortKey>(a: &mut [K]) -> u64 {
    let n = a.len();
    if n <= 1 {
        return 0;
    }
    assert!(n.is_power_of_two(), "bitonic sort requires power-of-two length");
    let mut ces: u64 = 0;
    let mut k = 2usize;
    while k <= n {
        let mut j = k >> 1;
        while j > 0 {
            ces += half_cleaner(a, k, j);
            j >>= 1;
        }
        k <<= 1;
    }
    ces
}

/// One substage (fixed `k`, `j`): compare-exchange all pairs `(i, i^j)`
/// with direction given by bit `k` of `i`. Branch-free on the GPU; here
/// a blocked loop that visits each pair exactly once — pairs with span
/// `j` sit in 2j-aligned blocks, lower half vs upper half — with a
/// select-style min/max on the key bits in the inner loop (§Perf: ~2.4×
/// over the naive full-index scan with its data-dependent swap branch).
#[inline]
fn half_cleaner<K: SortKey>(a: &mut [K], k: usize, j: usize) -> u64 {
    let n = a.len();
    let mut ces = 0u64;
    let mut base = 0usize;
    while base < n {
        // Direction is constant across a 2j-block only when j < k;
        // within one block `i & k` is constant iff 2j ≤ k, which holds
        // for every substage (j ranges k/2 … 1).
        let ascending = (base & k) == 0;
        // Zipped halves: no bounds checks in the hot loop (§Perf).
        let (lo, hi) = a[base..base + 2 * j].split_at_mut(j);
        if ascending {
            for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                let (mn, mx) = if x.key_le(y) { (*x, *y) } else { (*y, *x) };
                *x = mn;
                *y = mx;
            }
        } else {
            for (x, y) in lo.iter_mut().zip(hi.iter_mut()) {
                let (mn, mx) = if x.key_le(y) { (*x, *y) } else { (*y, *x) };
                *x = mx;
                *y = mn;
            }
        }
        ces += j as u64;
        base += 2 * j;
    }
    ces
}

/// Merge an already-bitonic sequence (ascending result). Used by the
/// Thrust Merge baseline's odd-even stages. Returns compare-exchanges.
pub fn bitonic_merge<K: SortKey>(a: &mut [K]) -> u64 {
    let n = a.len();
    if n <= 1 {
        return 0;
    }
    assert!(n.is_power_of_two());
    let mut ces = 0u64;
    let mut j = n >> 1;
    while j > 0 {
        // k = 2n ⇒ every i has bit-k zero ⇒ all ascending.
        ces += half_cleaner(a, n << 1, j);
        j >>= 1;
    }
    ces
}

/// Traffic description of one hybrid global bitonic sort, split into
/// global-memory substages and tile-consolidated (shared-memory)
/// substages. `n` and `tile` are in keys; both powers of two.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlobalSortPlan {
    /// Keys being sorted.
    pub n: usize,
    /// Tile (shared-memory window) size in keys.
    pub tile: usize,
    /// Substages executed as whole-array global passes (span ≥ tile).
    pub global_passes: u64,
    /// Consolidated tile-resident launches (one per merge stage that has
    /// any span < tile, plus the initial local sort of each tile).
    pub local_launches: u64,
    /// Compare-exchanges executed inside tile-resident launches.
    pub local_ces: u64,
    /// Compare-exchanges executed by global passes.
    pub global_ces: u64,
}

impl GlobalSortPlan {
    /// Build the plan for sorting `n` keys with shared-memory tiles of
    /// `tile` keys.
    pub fn new(n: usize, tile: usize) -> Self {
        assert!(n.is_power_of_two() && tile.is_power_of_two());
        if n <= tile {
            // Whole problem fits in one tile: a single local launch.
            return GlobalSortPlan {
                n,
                tile,
                global_passes: 0,
                local_launches: 1,
                local_ces: ce_count(n),
                global_ces: 0,
            };
        }
        let ln = log2_exact(n) as u64;
        let lt = log2_exact(tile) as u64;
        // Initial phase: sort every tile locally = merge stages k ≤ tile.
        let mut local_ces = (n as u64 / tile as u64) * ce_count(tile);
        let mut local_launches = 1u64; // consolidated: one launch sorts all tiles
        let mut global_passes = 0u64;
        let mut global_ces = 0u64;
        // Merge stages k = 2·tile … n: substages j = k/2 … 1.
        // j ≥ tile → global pass; the j < tile suffix of each stage is
        // one consolidated tile-resident launch.
        for k in (lt + 1)..=ln {
            // Substages with span ≥ tile: j = 2^(k-1) … 2^lt ⇒ k - lt of them.
            let g = k - lt;
            global_passes += g;
            global_ces += g * (n as u64 / 2);
            // Substages with span < tile: lt of them, consolidated.
            local_launches += 1;
            local_ces += lt * (n as u64 / 2);
        }
        GlobalSortPlan {
            n,
            tile,
            global_passes,
            local_launches,
            local_ces,
            global_ces,
        }
    }

    /// Total compare-exchanges (must equal [`ce_count`]`(n)`).
    pub fn total_ces(&self) -> u64 {
        self.local_ces + self.global_ces
    }

    /// Record this plan's traffic scaled by `num/den` — the virtual-
    /// padding model: a bitonic network padded from `num` real keys up
    /// to the power-of-two `den` executes the full pass structure, but
    /// predicated compare-exchanges against virtual `PAD` elements touch
    /// no memory and retire immediately, so traffic and useful compute
    /// scale with the real fraction. `elem_bytes` is the device width
    /// of one element (key, or key + payload index).
    pub fn record_scaled(
        &self,
        ledger: &mut Ledger,
        step: u8,
        num: usize,
        den: usize,
        elem_bytes: usize,
    ) {
        assert!(num <= den && den > 0);
        let mut scaled = Ledger::default();
        self.record(&mut scaled, step, elem_bytes);
        for k in scaled.kernels() {
            let mut k = k.clone();
            k.coalesced_bytes = k.coalesced_bytes * num as u64 / den as u64;
            k.scattered_transactions = k.scattered_transactions * num as u64 / den as u64;
            k.smem_ops = k.smem_ops * num as u64 / den as u64;
            k.compute_ops = k.compute_ops * num as u64 / den as u64;
            k.divergent_ops = k.divergent_ops * num as u64 / den as u64;
            k.blocks = (k.blocks * num as u64 / den as u64).max(1);
            ledger.record(k);
        }
    }

    /// Record this plan's traffic into `ledger` tagged as Algorithm-1
    /// step `step`, with `elem_bytes` bytes moved per element (the key
    /// width from [`SortKey::WIDTH_BYTES`], plus the payload index for
    /// record sorts).
    ///
    /// Per launch:
    /// * global pass — coalesced read+write of the whole array, n/2
    ///   compare ops;
    /// * consolidated local launch — coalesced read+write of the whole
    ///   array once (tiles stream through shared memory), 4 shared-memory
    ///   accesses per compare-exchange (2 loads + 2 stores), and the
    ///   compare ops.
    pub fn record(&self, ledger: &mut Ledger, step: u8, elem_bytes: usize) {
        let bytes = (self.n * elem_bytes) as u64;
        let blocks = (self.n / self.tile).max(1) as u64;
        let threads = MAX_BLOCK_THREADS.min(self.tile as u32 / 2).max(1);

        if self.n <= self.tile {
            ledger.begin_kernel(KernelClass::GlobalBitonic, 1, threads);
            ledger.tag_step(step);
            ledger.add_coalesced(2 * bytes);
            ledger.add_smem(4 * self.local_ces);
            ledger.add_compute(self.local_ces);
            ledger.end_kernel();
            return;
        }

        let ln = log2_exact(self.n) as u64;
        let lt = log2_exact(self.tile) as u64;
        let tile_ces = (self.n as u64 / self.tile as u64) * ce_count(self.tile);

        // Initial local sort of all tiles (one consolidated launch).
        ledger.begin_kernel(KernelClass::GlobalBitonic, blocks, threads);
        ledger.tag_step(step);
        ledger.add_coalesced(2 * bytes);
        ledger.add_smem(4 * tile_ces);
        ledger.add_compute(tile_ces);
        ledger.end_kernel();

        for k in (lt + 1)..=ln {
            // Global passes of this merge stage.
            for _ in 0..(k - lt) {
                ledger.begin_kernel(KernelClass::GlobalBitonic, blocks, threads);
                ledger.tag_step(step);
                ledger.add_coalesced(2 * bytes);
                ledger.add_compute(self.n as u64 / 2);
                ledger.end_kernel();
            }
            // Consolidated low-span launch of this merge stage.
            let ces = lt * (self.n as u64 / 2);
            ledger.begin_kernel(KernelClass::GlobalBitonic, blocks, threads);
            ledger.tag_step(step);
            ledger.add_coalesced(2 * bytes);
            ledger.add_smem(4 * ces);
            ledger.add_compute(ces);
            ledger.end_kernel();
        }
    }
}

/// Sort `a` (power-of-two length) with the hybrid global bitonic network,
/// recording its traffic into `ledger` tagged as step `step`. The data
/// work is performed for real; the recorded ledger is identical to
/// [`global_sort_analytic_bytes`] with the same `(n, tile)` and the
/// key type's width.
pub fn global_sort<K: SortKey>(a: &mut [K], tile: usize, ledger: &mut Ledger, step: u8) -> u64 {
    let plan = GlobalSortPlan::new(a.len().max(1), tile);
    let ces = sort_slice(a);
    debug_assert_eq!(
        ces,
        plan.total_ces(),
        "executed CE count diverged from the analytic plan"
    );
    if !a.is_empty() {
        plan.record(ledger, step, K::WIDTH_BYTES);
    }
    ces
}

/// Ledger-only twin of [`global_sort`] at the classic `u32` width.
pub fn global_sort_analytic(n: usize, tile: usize, ledger: &mut Ledger, step: u8) {
    global_sort_analytic_bytes(n, tile, KEY_BYTES, ledger, step);
}

/// Ledger-only twin of [`global_sort`] for paper-scale configurations,
/// at an explicit per-element width.
pub fn global_sort_analytic_bytes(
    n: usize,
    tile: usize,
    elem_bytes: usize,
    ledger: &mut Ledger,
    step: u8,
) {
    if n == 0 {
        return;
    }
    GlobalSortPlan::new(n, tile).record(ledger, step, elem_bytes);
}

/// Record the cost of bitonic-sorting `n_effective` real keys under
/// virtual padding to the next power of two, at the classic `u32`
/// width.
pub fn global_sort_virtual(n_effective: usize, tile: usize, ledger: &mut Ledger, step: u8) {
    global_sort_virtual_bytes(n_effective, tile, KEY_BYTES, ledger, step);
}

/// Record the cost of bitonic-sorting `n_effective` real elements of
/// `elem_bytes` each under virtual padding to the next power of two
/// (see [`GlobalSortPlan::record_scaled`]). This is how Step 9 prices
/// each sublist B_j: the network shape comes from the padded size, the
/// traffic from the real elements.
pub fn global_sort_virtual_bytes(
    n_effective: usize,
    tile: usize,
    elem_bytes: usize,
    ledger: &mut Ledger,
    step: u8,
) {
    if n_effective == 0 {
        return;
    }
    let padded = next_pow2(n_effective);
    GlobalSortPlan::new(padded, tile).record_scaled(ledger, step, n_effective, padded, elem_bytes);
}

/// Round up to the next power of two (min 1).
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{is_sorted, Key};

    #[test]
    fn ce_count_closed_form() {
        // n=2: 1 CE. n=4: 2*2*3/2 = 6. n=8: 4*3*4/2 = 24.
        assert_eq!(ce_count(1), 0);
        assert_eq!(ce_count(2), 1);
        assert_eq!(ce_count(4), 6);
        assert_eq!(ce_count(8), 24);
        assert_eq!(pass_count(8), 6);
    }

    #[test]
    fn sorts_and_counts_match() {
        for ln in 0..=12 {
            let n = 1usize << ln;
            let mut v: Vec<Key> = (0..n as u32).rev().map(|x| x.wrapping_mul(2654435761)).collect();
            let ces = sort_slice(&mut v);
            assert!(is_sorted(&v), "n={n}");
            assert_eq!(ces, ce_count(n), "n={n}");
        }
    }

    #[test]
    fn sorts_with_duplicates() {
        let mut v: Vec<Key> = (0..1024u32).map(|x| x % 7).collect();
        sort_slice(&mut v);
        assert!(is_sorted(&v));
        assert_eq!(v.iter().filter(|&&x| x == 0).count(), 1024 / 7 + 1);
    }

    #[test]
    fn merge_of_bitonic_sequence() {
        // ascending then descending = bitonic.
        let mut v: Vec<Key> = (0..512u32).chain((0..512u32).rev()).collect();
        bitonic_merge(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn plan_conserves_ces() {
        for (n, tile) in [(1 << 14, 1 << 11), (1 << 16, 1 << 11), (1 << 11, 1 << 11), (1 << 8, 1 << 11)] {
            let p = GlobalSortPlan::new(n, tile);
            assert_eq!(p.total_ces(), ce_count(n), "n={n} tile={tile}");
        }
    }

    #[test]
    fn plan_pass_structure() {
        // n = 2^14, tile = 2^11: merge stages 12..14, global passes
        // (1)+(2)+(3)=6, local launches 1 + 3.
        let p = GlobalSortPlan::new(1 << 14, 1 << 11);
        assert_eq!(p.global_passes, 6);
        assert_eq!(p.local_launches, 4);
    }

    #[test]
    fn executed_ledger_equals_analytic() {
        for ln in [8usize, 11, 13, 14] {
            let n = 1 << ln;
            let tile = 1 << 11;
            let mut v: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2246822519)).collect();
            let mut led_exec = Ledger::default();
            global_sort(&mut v, tile, &mut led_exec, 4);
            assert!(is_sorted(&v));
            let mut led_ana = Ledger::default();
            global_sort_analytic(n, tile, &mut led_ana, 4);
            assert_eq!(led_exec, led_ana, "n={n}");
        }
    }

    #[test]
    fn global_traffic_grows_with_n() {
        let mut small = Ledger::default();
        global_sort_analytic(1 << 16, 1 << 11, &mut small, 4);
        let mut big = Ledger::default();
        global_sort_analytic(1 << 20, 1 << 11, &mut big, 4);
        assert!(big.total().coalesced_bytes > small.total().coalesced_bytes * 10);
    }

    #[test]
    fn next_pow2_rounding() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }
}

//! Baseline: the **randomized sample sort** of Leischner, Osipov &
//! Sanders (IPDPS 2010) [9] — the method the paper matches while
//! removing its input-dependence.
//!
//! Structure (following [9]):
//! * while a segment is larger than the base-case threshold M, pick
//!   `a·k` *random* keys, sort them, take every a-th as one of k−1
//!   splitters, then distribute the segment into k buckets in two
//!   passes — a histogram pass and a scatter pass — traversing an
//!   implicit binary search tree of splitters for each key;
//! * segments ≤ M are sorted with the small-case sorter (a
//!   shared-memory-tiled bitonic, as in GPU-quicksort descendants);
//! * buckets whose keys are all equal (detected when adjacent splitters
//!   collide) terminate immediately — without this, skewed inputs
//!   recurse forever.
//!
//! Because splitters are random, bucket sizes are only *expected* to be
//! n/k: skewed inputs yield oversized buckets and extra distribution
//! levels, which is exactly the data-dependent fluctuation the paper's
//! deterministic method eliminates (§1, §5). The effect emerges
//! naturally here because the recursion follows the *actual* bucket
//! sizes.

use super::bitonic;
use crate::error::Result;
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::sim::{CostModel, GpuSim};
use crate::{Key, KEY_BYTES};
use crate::util::Rng;

/// Parameters of randomized sample sort [9].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RandomizedParams {
    /// Bucket fan-out k per distribution level ([9] uses 128).
    pub k: usize,
    /// Oversampling factor a (splitters are drawn from a·k random
    /// samples).
    pub oversample: usize,
    /// Base-case threshold M: segments at most this size go to the
    /// small-case sorter.
    pub base_case: usize,
    /// Shared-memory tile for the small-case sorter.
    pub tile: usize,
    /// RNG seed — [9]'s runtime varies over this; fixing it makes a run
    /// reproducible.
    pub seed: u64,
}

impl Default for RandomizedParams {
    fn default() -> Self {
        RandomizedParams {
            k: 128,
            oversample: 32,
            base_case: 1 << 18,
            tile: 2048,
            seed: 0x5EED_5A17,
        }
    }
}

/// Report of one randomized sample sort run.
#[derive(Debug, Clone)]
pub struct RandomizedReport {
    /// Input size.
    pub n: usize,
    /// Traffic ledger (steps untagged — this baseline has no Algorithm-1
    /// step structure).
    pub ledger: Ledger,
    /// Number of distribution levels executed (max over the recursion).
    pub max_depth: usize,
    /// Largest bucket produced by any single distribution step,
    /// normalized by its expected size n_segment/k — the fluctuation
    /// measure.
    pub worst_bucket_skew: f64,
}

impl RandomizedReport {
    /// Estimated milliseconds on `spec`.
    pub fn total_estimated_ms(&self, spec: &crate::sim::GpuSpec) -> f64 {
        CostModel::default_params(spec).ledger_ms(&self.ledger)
    }
}

/// The randomized sample sorter.
#[derive(Debug, Clone)]
pub struct RandomizedSampleSort {
    params: RandomizedParams,
}

/// Memory model of [9]: the implementation keeps the input, an output
/// buffer, per-block histogram matrices and recursion bookkeeping; its
/// reported ceilings (≤32M keys on a 1 GB GTX 285, ≤128M on a 4 GB
/// Tesla — §5) bracket the peak footprint into (15.9, 31.7] bytes per
/// key; we charge 24. This is what reproduces the paper's "GPU BUCKET
/// SORT is more memory efficient" observation (8.25 B/key, Figures 6–7).
pub const BYTES_PER_KEY: usize = 24;

impl RandomizedSampleSort {
    /// Construct with the given parameters.
    pub fn new(params: RandomizedParams) -> Self {
        assert!(params.k >= 2 && params.oversample >= 1 && params.base_case >= params.tile);
        assert!(params.tile.is_power_of_two());
        RandomizedSampleSort { params }
    }

    /// The parameters in use.
    pub fn params(&self) -> &RandomizedParams {
        &self.params
    }

    /// Sort `keys` on the simulated device.
    pub fn sort(&self, keys: &mut [Key], sim: &mut GpuSim) -> Result<RandomizedReport> {
        let n = keys.len();
        let alloc = sim.alloc(n * BYTES_PER_KEY)?;
        let mut ledger = Ledger::default();
        let mut rng = Rng::new(self.params.seed);
        let mut max_depth = 0usize;
        let mut worst_skew = 0.0f64;
        self.sort_rec(keys, &mut rng, &mut ledger, 1, &mut max_depth, &mut worst_skew);
        sim.free(alloc);
        sim.ledger_mut().extend_from(&ledger);
        Ok(RandomizedReport {
            n,
            ledger,
            max_depth,
            worst_bucket_skew: worst_skew,
        })
    }

    fn sort_rec(
        &self,
        seg: &mut [Key],
        rng: &mut Rng,
        ledger: &mut Ledger,
        depth: usize,
        max_depth: &mut usize,
        worst_skew: &mut f64,
    ) {
        let n = seg.len();
        *max_depth = (*max_depth).max(depth);
        if n <= self.params.base_case {
            self.base_sort(seg, ledger);
            return;
        }
        // Degenerate-input guard ([9] relies on fresh randomness making
        // progress w.h.p.; a near-degenerate value distribution can keep
        // missing minority values in the sample): beyond depth 64, hand
        // the segment to the small-case sorter outright.
        if depth > 64 {
            self.base_sort(seg, ledger);
            return;
        }
        let k = self.params.k;

        // Draw and sort a·k random samples; take every a-th as splitter.
        let sample_n = (self.params.oversample * k).min(n);
        let mut sample: Vec<Key> = (0..sample_n)
            .map(|_| seg[rng.gen_range(n)])
            .collect();
        sample.sort_unstable();
        let splitters: Vec<Key> = (1..k)
            .map(|i| sample[i * sample_n / k])
            .collect();
        record_sample(sample_n, ledger);

        // Histogram pass: every key traverses the splitter search tree.
        let mut counts = vec![0usize; k];
        for &x in seg.iter() {
            counts[bucket_of(&splitters, x)] += 1;
        }
        record_pass(n, k, self.params.tile, false, ledger);

        // Prefix + scatter pass.
        let mut starts = vec![0usize; k + 1];
        for j in 0..k {
            starts[j + 1] = starts[j] + counts[j];
        }
        let mut out = vec![0 as Key; n];
        let mut cursor = starts.clone();
        for &x in seg.iter() {
            let b = bucket_of(&splitters, x);
            out[cursor[b]] = x;
            cursor[b] += 1;
        }
        seg.copy_from_slice(&out);
        record_pass(n, k, self.params.tile, true, ledger);

        let expected = n as f64 / k as f64;
        for j in 0..k {
            let (st, en) = (starts[j], starts[j + 1]);
            let len = en - st;
            *worst_skew = worst_skew.max(len as f64 / expected);
            if len <= 1 {
                continue;
            }
            // Equality bucket: adjacent splitters collide ⇒ all keys in
            // this bucket are equal ⇒ already sorted ([9]'s degenerate-
            // case handling).
            let all_equal = (j > 0 && j < k - 1 && splitters[j - 1] == splitters[j])
                || seg[st..en].iter().all(|&x| x == seg[st]);
            if all_equal {
                continue;
            }
            if len == n {
                // No progress this level (every key fell into a single
                // bucket): bail to the small-case sorter instead of
                // re-spinning the same partition.
                self.base_sort(&mut seg[st..en], ledger);
                continue;
            }
            self.sort_rec(&mut seg[st..en], rng, ledger, depth + 1, max_depth, worst_skew);
        }
    }

    /// Small-case sorter: tiled bitonic over the padded segment (the
    /// shared-memory sorter of the GPU implementations).
    fn base_sort(&self, seg: &mut [Key], ledger: &mut Ledger) {
        let n = seg.len();
        if n <= 1 {
            return;
        }
        let p = bitonic::next_pow2(n);
        let mut buf: Vec<Key> = Vec::with_capacity(p);
        buf.extend_from_slice(seg);
        buf.resize(p, Key::MAX);
        bitonic::global_sort(&mut buf, self.params.tile, ledger, 0);
        seg.copy_from_slice(&buf[..n]);
    }
}

impl RandomizedSampleSort {
    /// Ledger-only estimate under the **balanced-bucket assumption**
    /// (uniform input, every distribution level splits exactly k ways) —
    /// the best case for randomized sample sort, which is precisely the
    /// workload of the paper's Figures 6 & 7. Unlike
    /// [`RandomizedSampleSort::sort`] this does not capture the
    /// input-dependent fluctuation; it is the paper-scale stand-in for
    /// the uniform-data comparison only.
    pub fn sort_analytic(&self, n: usize, sim: &mut GpuSim) -> Result<RandomizedReport> {
        let alloc = sim.alloc(n * BYTES_PER_KEY)?;
        let mut ledger = Ledger::default();
        let k = self.params.k;
        let mut depth = 1usize;
        let mut seg = n;
        let mut segments = 1usize;
        while seg > self.params.base_case {
            record_sample((self.params.oversample * k).min(seg), &mut ledger);
            // One histogram + one scatter pass per segment at this level;
            // consolidated launches cover all segments of the level.
            for _ in 0..segments {
                record_pass(seg, k, self.params.tile, false, &mut ledger);
                record_pass(seg, k, self.params.tile, true, &mut ledger);
            }
            seg = seg.div_ceil(k);
            segments *= k;
            depth += 1;
        }
        for _ in 0..segments {
            bitonic::global_sort_analytic(
                bitonic::next_pow2(seg.max(2)),
                self.params.tile,
                &mut ledger,
                0,
            );
        }
        sim.free(alloc);
        sim.ledger_mut().extend_from(&ledger);
        Ok(RandomizedReport {
            n,
            ledger,
            max_depth: depth,
            worst_bucket_skew: 1.0,
        })
    }
}

/// Locate the bucket of `x` by branch-free binary search over the
/// sorted splitters (the implicit search tree of [9]).
#[inline]
fn bucket_of(splitters: &[Key], x: Key) -> usize {
    splitters.partition_point(|&sp| sp <= x)
}

fn record_sample(sample_n: usize, ledger: &mut Ledger) {
    ledger.begin_kernel(KernelClass::Sample, 1, MAX_BLOCK_THREADS);
    // Random gathers are scattered by construction.
    ledger.add_scattered(sample_n as u64);
    ledger.add_compute((sample_n as f64 * (sample_n as f64).log2().max(1.0)) as u64);
    ledger.end_kernel();
}

/// One distribution pass over `n` keys with fan-out `k`.
///
/// Histogram pass: coalesced read + log2(k) tree steps per key.
/// Scatter pass: coalesced read, and the write side achieves only
/// partial coalescing — [9] stages through shared memory, but k open
/// output streams per block still cost extra transactions; we charge
/// one scattered transaction per tile-per-bucket stream flush.
fn record_pass(n: usize, k: usize, tile: usize, scatter: bool, ledger: &mut Ledger) {
    let blocks = (n.div_ceil(tile)) as u64;
    let class = if scatter {
        KernelClass::ScatterAtomic
    } else {
        KernelClass::BucketFind
    };
    ledger.begin_kernel(class, blocks, MAX_BLOCK_THREADS);
    ledger.add_coalesced((n * KEY_BYTES) as u64);
    let tree_steps = (k as f64).log2().ceil() as u64;
    ledger.add_compute(n as u64 * tree_steps);
    ledger.add_smem(n as u64 * tree_steps);
    if scatter {
        ledger.add_coalesced((n * KEY_BYTES) as u64);
        ledger.add_scattered(blocks * k as u64);
        // Atomic cursor updates serialize within a warp — a divergent op
        // per key.
        ledger.add_divergent(n as u64 / 4);
    }
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::{is_sorted, is_sorted_permutation};

    fn small() -> RandomizedSampleSort {
        RandomizedSampleSort::new(RandomizedParams {
            k: 8,
            oversample: 4,
            base_case: 512,
            tile: 256,
            seed: 42,
        })
    }

    #[test]
    fn sorts_uniform() {
        let mut keys: Vec<Key> = (0..20_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
        let orig = keys.clone();
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let r = small().sort(&mut keys, &mut sim).unwrap();
        assert!(is_sorted_permutation(&orig, &keys));
        assert!(r.max_depth >= 2, "should have recursed");
    }

    #[test]
    fn sorts_all_equal_without_diverging() {
        let mut keys = vec![77u32; 50_000];
        let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let r = small().sort(&mut keys, &mut sim).unwrap();
        assert!(is_sorted(&keys));
        // Equality detection terminates the recursion quickly.
        assert!(r.max_depth <= 3, "depth={}", r.max_depth);
    }

    #[test]
    fn sorts_sorted_and_reverse() {
        for input in [
            (0..30_000u32).collect::<Vec<_>>(),
            (0..30_000u32).rev().collect::<Vec<_>>(),
        ] {
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            small().sort(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&input, &keys));
        }
    }

    #[test]
    fn runtime_fluctuates_with_distribution() {
        // The paper's core robustness contrast (§1, §5): randomized
        // sample sort's cost varies with the input distribution, the
        // deterministic method's launch/traffic profile does not.
        use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
        use crate::workload::Distribution;
        let spec = GpuModel::Gtx285_2G.spec();
        let n = 60_000;
        let sorter = small();
        let dets = BucketSort::new(BucketSortParams { tile: 256, s: 16 });

        let mut rss_ms = Vec::new();
        let mut gbs_ledgers = Vec::new();
        let mut worst_skews = Vec::new();
        for dist in [
            Distribution::Uniform,
            Distribution::Gaussian,
            Distribution::Staggered,
            Distribution::NearlySorted,
        ] {
            let keys = dist.generate(n, 42);
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let r = sorter.sort(&mut keys.clone(), &mut sim).unwrap();
            rss_ms.push(r.total_estimated_ms(&spec));
            worst_skews.push(r.worst_bucket_skew);
            let mut sim2 = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let g = dets.sort(&mut keys.clone(), &mut sim2).unwrap();
            gbs_ledgers.push(g.ledger);
        }
        // Randomized: bucket sizes skew away from n/k and cost varies.
        let max = rss_ms.iter().copied().fold(0.0f64, f64::max);
        let min = rss_ms.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(max / min > 1.01, "rss should fluctuate: {rss_ms:?}");
        assert!(
            worst_skews.iter().any(|&s| s > 1.5),
            "some distribution should skew buckets: {worst_skews:?}"
        );
        // Deterministic: identical launch/traffic profile on every input.
        for l in &gbs_ledgers[1..] {
            assert_eq!(l, &gbs_ledgers[0]);
        }
    }

    #[test]
    fn reproducible_with_same_seed() {
        let mk = || {
            let mut keys: Vec<Key> = (0..10_000u32).map(|x| x.wrapping_mul(7919)).collect();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            small().sort(&mut keys, &mut sim).unwrap().ledger
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn memory_ceiling_below_bucket_sort() {
        // §5: randomized sample sort sorts ≤32M on 1 GB, ≤128M on 4 GB.
        let sorter = RandomizedSampleSort::new(RandomizedParams::default());
        let mut sim = GpuSim::new(GpuModel::Gtx285_1G.spec());
        // 32M keys × 32 B/key = 1 GB > usable → borderline: check the
        // ceiling ordering rather than exact values.
        let need_32m = (32usize << 20) * BYTES_PER_KEY;
        assert!(need_32m > sim.spec().usable_global_memory_bytes() / 2);
        // 64M must not fit on the 1 GB card.
        assert!(sim.alloc((64 << 20) * BYTES_PER_KEY).is_err());
        let _ = sorter; // constructed for API parity
    }
}

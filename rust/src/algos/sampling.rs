//! Steps 3 & 5 of Algorithm 1: equidistant sampling.
//!
//! * Step 3 — from each sorted sublist take `s` equidistant samples
//!   (total s·m). The paper folds this into the write-back of Step 2; we
//!   keep the strided reads in their own launch record (tagged step 3)
//!   so Figure 5's per-step split stays observable, but charge them as
//!   scattered accesses only (no extra full-array pass).
//! * Step 5 — take `s` equidistant *global samples* from the s·m sorted
//!   samples; their first `s-1` values act as the bucket splitters. This
//!   is the deterministic, regular-sampling choice of Shi & Schaeffer
//!   [15] that yields the guaranteed bucket bound |B_j| ≤ 2n/s.

use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::{SortKey, KEY_BYTES};

/// Step 3: `s` equidistant samples from each sorted `tile`-sized sublist
/// of `keys` (positions `(p+1)·tile/s − 1` within each sublist).
/// Requires `s` dividing `tile`. Returns the s·m samples in sublist
/// order.
pub fn local_samples<K: SortKey>(keys: &[K], tile: usize, s: usize, ledger: &mut Ledger) -> Vec<K> {
    let mut out = Vec::new();
    local_samples_into(keys, tile, s, &mut out, ledger);
    out
}

/// [`local_samples`] into a caller-provided (typically arena-recycled)
/// buffer — the allocation-free form the engines use.
pub fn local_samples_into<K: SortKey>(
    keys: &[K],
    tile: usize,
    s: usize,
    out: &mut Vec<K>,
    ledger: &mut Ledger,
) {
    validate(tile, s);
    assert_eq!(keys.len() % tile, 0, "input must be tile-aligned");
    let m = keys.len() / tile;
    let stride = tile / s;
    out.clear();
    out.reserve(m * s);
    for t in keys.chunks_exact(tile) {
        for p in 0..s {
            out.push(t[(p + 1) * stride - 1]);
        }
    }
    if m > 0 {
        record_local(m, s, K::WIDTH_BYTES, ledger);
    }
}

/// Ledger-only twin of [`local_samples`] at the classic `u32` width.
pub fn analytic_local(n: usize, tile: usize, s: usize, ledger: &mut Ledger) -> usize {
    analytic_local_bytes(n, tile, s, KEY_BYTES, ledger)
}

/// Ledger-only twin of [`local_samples`] at an explicit element width.
pub fn analytic_local_bytes(
    n: usize,
    tile: usize,
    s: usize,
    elem_bytes: usize,
    ledger: &mut Ledger,
) -> usize {
    validate(tile, s);
    assert_eq!(n % tile, 0);
    let m = n / tile;
    if m > 0 {
        record_local(m, s, elem_bytes, ledger);
    }
    m * s
}

fn record_local(m: usize, s: usize, elem_bytes: usize, ledger: &mut Ledger) {
    ledger.begin_kernel(KernelClass::Sample, m as u64, s.min(MAX_BLOCK_THREADS as usize) as u32);
    ledger.tag_step(3);
    // Strided reads from the sorted tiles (one transaction each), plus a
    // coalesced write of the sample array.
    ledger.add_scattered((m * s) as u64);
    ledger.add_coalesced((m * s * elem_bytes) as u64);
    ledger.add_compute((m * s) as u64);
    ledger.end_kernel();
}

/// Step 5: the `s-1` bucket splitters — equidistant global samples of
/// the globally sorted sample array (positions `(j+1)·len/s − 1`,
/// `j = 0..s-1`; the s-th sample is the array maximum and bounds no
/// bucket, so it is not materialized).
pub fn select_splitters<K: SortKey>(sorted_samples: &[K], s: usize, ledger: &mut Ledger) -> Vec<K> {
    assert!(s >= 1);
    let len = sorted_samples.len();
    assert!(len >= s, "need at least s samples to select from");
    let stride = len / s;
    let splitters: Vec<K> = (0..s - 1)
        .map(|j| sorted_samples[(j + 1) * stride - 1])
        .collect();
    debug_assert!(splitters.windows(2).all(|w| w[0].key_le(&w[1])));
    record_splitters(s, K::WIDTH_BYTES, ledger);
    splitters
}

/// Ledger-only twin of [`select_splitters`] at the classic `u32` width.
pub fn analytic_splitters(len: usize, s: usize, ledger: &mut Ledger) {
    analytic_splitters_bytes(len, s, KEY_BYTES, ledger);
}

/// Ledger-only twin of [`select_splitters`] at an explicit element
/// width.
pub fn analytic_splitters_bytes(len: usize, s: usize, elem_bytes: usize, ledger: &mut Ledger) {
    assert!(len >= s && s >= 1);
    record_splitters(s, elem_bytes, ledger);
}

fn record_splitters(s: usize, elem_bytes: usize, ledger: &mut Ledger) {
    ledger.begin_kernel(KernelClass::Sample, 1, s.min(MAX_BLOCK_THREADS as usize) as u32);
    ledger.tag_step(5);
    ledger.add_scattered(s as u64);
    ledger.add_coalesced((s * elem_bytes) as u64);
    ledger.add_compute(s as u64);
    ledger.end_kernel();
}

fn validate(tile: usize, s: usize) {
    assert!(s >= 1 && s <= tile, "need 1 <= s <= tile");
    assert_eq!(tile % s, 0, "s must divide the tile size");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Key;

    #[test]
    fn samples_are_equidistant_maxima() {
        // Tile [0..16) sorted; s=4 → stride 4 → samples at 3,7,11,15.
        let keys: Vec<Key> = (0..16).collect();
        let mut led = Ledger::default();
        let s = local_samples(&keys, 16, 4, &mut led);
        assert_eq!(s, vec![3, 7, 11, 15]);
    }

    #[test]
    fn per_tile_sampling() {
        let mut keys: Vec<Key> = (0..8).collect();
        keys.extend(100..108);
        let mut led = Ledger::default();
        let s = local_samples(&keys, 8, 2, &mut led);
        assert_eq!(s, vec![3, 7, 103, 107]);
        assert_eq!(led.kernels()[0].step, 3);
        assert_eq!(led.kernels()[0].scattered_transactions, 4);
    }

    #[test]
    fn ledger_matches_analytic() {
        let keys: Vec<Key> = (0..64).collect();
        let mut a = Ledger::default();
        local_samples(&keys, 16, 8, &mut a);
        let mut b = Ledger::default();
        assert_eq!(analytic_local(64, 16, 8, &mut b), 32);
        assert_eq!(a, b);
    }

    #[test]
    fn splitters_from_sorted_samples() {
        let sorted: Vec<Key> = (0..32).collect();
        let mut led = Ledger::default();
        let sp = select_splitters(&sorted, 4, &mut led);
        // stride 8 → positions 7, 15, 23 (3 = s-1 splitters).
        assert_eq!(sp, vec![7, 15, 23]);
        assert_eq!(led.kernels()[0].step, 5);
    }

    #[test]
    fn single_bucket_means_no_splitters() {
        let sorted: Vec<Key> = (0..8).collect();
        let sp = select_splitters(&sorted, 1, &mut Ledger::default());
        assert!(sp.is_empty());
    }

    #[test]
    #[should_panic(expected = "s must divide")]
    fn rejects_non_dividing_s() {
        let keys: Vec<Key> = (0..16).collect();
        local_samples(&keys, 16, 3, &mut Ledger::default());
    }

    #[test]
    fn splitter_count_guarantee() {
        // Property: for any sorted input and valid s, we get exactly s-1
        // sorted splitters.
        for s in [1usize, 2, 4, 8, 16] {
            let sorted: Vec<Key> = (0..256u32).map(|x| x * 3).collect();
            let sp = select_splitters(&sorted, s, &mut Ledger::default());
            assert_eq!(sp.len(), s - 1);
            assert!(sp.windows(2).all(|w| w[0] <= w[1]));
        }
    }
}

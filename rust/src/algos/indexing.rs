//! Step 6 of Algorithm 1: Sample Indexing — locate every global sample
//! (splitter) inside every sorted sublist, partitioning each sublist
//! A_i into s buckets A_i1 … A_is of sizes a_i1 … a_is.
//!
//! On the GPU, each sublist is handled by one block on one SM: the
//! splitters are loaded to shared memory (done in Step 5) and located by
//! **parallel binary search with thread doubling** — one thread searches
//! the s/2-th splitter, then two threads search the s/4-th and 3s/4-th
//! in the respective halves, iterated log s times (§4). The doubling
//! order avoids shared-memory contention; the searches themselves are
//! branch-free fixed-trip-count binary searches, so the ledger records
//! them as uniform (non-divergent) shared-memory work.
//!
//! We return the *boundary matrix* `b[i][j]` = number of keys in sublist
//! i strictly below splitter j (row-major m×(s-1) stored as m×s with a
//! final column fixed at `tile`), from which bucket sizes are
//! `a_ij = b[i][j] − b[i][j−1]`.

use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::{SortKey, KEY_BYTES};

/// Branch-free lower bound: number of elements of sorted `t` strictly
/// less than `key` (under the [`SortKey`] total order), in exactly
/// `log2(len)+1` probe steps for power-of-two `len` — the fixed trip
/// count a SIMT warp would execute. Returns `(position, probes)`.
#[inline]
pub fn fixed_lower_bound<K: SortKey>(t: &[K], key: K) -> (usize, u64) {
    let mut base = 0usize;
    let mut size = t.len();
    let mut probes = 0u64;
    while size > 1 {
        let half = size / 2;
        // Branch-free select on the GPU (predicated); a plain compare here.
        if t[base + half - 1].key_lt(&key) {
            base += half;
        }
        size -= half;
        probes += 1;
    }
    if !t.is_empty() {
        probes += 1;
        if t[base].key_lt(&key) {
            base += 1;
        }
    }
    (base, probes)
}

/// Compute the boundary matrix for all sublists. `keys` is tile-aligned
/// and each tile sorted; `splitters` has length s−1 (sorted). Output is
/// row-major m×s: `out[i·s + j] = |{x ∈ A_i : x < splitter_j}|` for
/// j < s−1 and `out[i·s + s−1] = tile`.
pub fn boundaries<K: SortKey>(
    keys: &[K],
    tile: usize,
    splitters: &[K],
    ledger: &mut Ledger,
) -> Vec<u32> {
    let mut out = Vec::new();
    boundaries_into(keys, tile, splitters, &mut out, ledger);
    out
}

/// [`boundaries`] into a caller-provided (typically arena-recycled)
/// buffer — the allocation-free form the engines use.
pub fn boundaries_into<K: SortKey>(
    keys: &[K],
    tile: usize,
    splitters: &[K],
    out: &mut Vec<u32>,
    ledger: &mut Ledger,
) {
    assert!(tile.is_power_of_two());
    assert_eq!(keys.len() % tile, 0, "input must be tile-aligned");
    let m = keys.len() / tile;
    let s = splitters.len() + 1;
    out.clear();
    out.resize(m * s, 0);
    let mut probes = 0u64;
    for (i, t) in keys.chunks_exact(tile).enumerate() {
        debug_assert!(t.windows(2).all(|w| w[0].key_le(&w[1])), "tile {i} not sorted");
        for (j, &sp) in splitters.iter().enumerate() {
            let (pos, p) = fixed_lower_bound(t, sp);
            out[i * s + j] = pos as u32;
            probes += p;
        }
        out[i * s + (s - 1)] = tile as u32;
    }
    if m > 0 {
        record(m, tile, s, probes, K::WIDTH_BYTES, ledger);
    }
}

/// Ledger-only twin of [`boundaries`] at the classic `u32` width: the
/// probe count of the fixed-trip search is shape-determined
/// (`(s−1)·(log2 tile + 1)` per sublist), so the analytic ledger is
/// exact.
pub fn analytic(n: usize, tile: usize, s: usize, ledger: &mut Ledger) {
    analytic_bytes(n, tile, s, KEY_BYTES, ledger);
}

/// Ledger-only twin of [`boundaries`] at an explicit element width.
pub fn analytic_bytes(n: usize, tile: usize, s: usize, elem_bytes: usize, ledger: &mut Ledger) {
    assert!(tile.is_power_of_two());
    assert_eq!(n % tile, 0);
    let m = n / tile;
    if m == 0 {
        return;
    }
    let probes = m as u64 * (s as u64 - 1) * (tile.trailing_zeros() as u64 + 1);
    record(m, tile, s, probes, elem_bytes, ledger);
}

fn record(m: usize, tile: usize, s: usize, probes: u64, elem_bytes: usize, ledger: &mut Ledger) {
    ledger.begin_kernel(
        KernelClass::SampleIndex,
        m as u64,
        (s.min(MAX_BLOCK_THREADS as usize)) as u32,
    );
    ledger.tag_step(6);
    // Each block re-reads its tile through shared memory once (coalesced)
    // and reads the splitters already resident in shared memory.
    ledger.add_coalesced((m * tile * elem_bytes) as u64);
    // Every probe is one shared-memory read + one compare.
    ledger.add_smem(probes);
    ledger.add_compute(probes);
    // Boundary matrix write-back — u32 counts regardless of key type,
    // so this term does not widen with `elem_bytes`.
    ledger.add_coalesced((m * s * KEY_BYTES) as u64);
    ledger.end_kernel();
}

/// Bucket sizes from a boundary row: `a_ij = b_j − b_{j−1}` (`b_{−1}=0`).
pub fn row_bucket_sizes(boundary_row: &[u32]) -> Vec<u32> {
    let mut prev = 0u32;
    boundary_row
        .iter()
        .map(|&b| {
            let a = b - prev;
            prev = b;
            a
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Key;

    #[test]
    fn lower_bound_matches_std() {
        let t: Vec<Key> = vec![1, 3, 3, 5, 7, 9, 11, 13];
        for key in 0..16u32 {
            let (pos, probes) = fixed_lower_bound(&t, key);
            assert_eq!(pos, t.partition_point(|&x| x < key), "key={key}");
            assert_eq!(probes, 4); // log2(8) + 1 — fixed trip count.
        }
    }

    #[test]
    fn lower_bound_edge_sizes() {
        assert_eq!(fixed_lower_bound(&[], 5), (0, 0));
        assert_eq!(fixed_lower_bound(&[3], 5), (1, 1));
        assert_eq!(fixed_lower_bound(&[7], 5), (0, 1));
    }

    #[test]
    fn boundary_matrix_correct() {
        // Two sorted tiles of 8; splitters 4, 10 → s = 3 buckets.
        let keys: Vec<Key> = vec![0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15];
        let mut led = Ledger::default();
        let b = boundaries(&keys, 8, &[4, 10], &mut led);
        // Tile 0 = 0..8: below 4 → 4, below 10 → 8, total 8.
        assert_eq!(&b[0..3], &[4, 8, 8]);
        // Tile 1 = 8..16: below 4 → 0, below 10 → 2, total 8.
        assert_eq!(&b[3..6], &[0, 2, 8]);
    }

    #[test]
    fn bucket_sizes_from_boundaries() {
        assert_eq!(row_bucket_sizes(&[4, 8, 8]), vec![4, 4, 0]);
        assert_eq!(row_bucket_sizes(&[0, 2, 8]), vec![0, 2, 6]);
    }

    #[test]
    fn sizes_sum_to_tile() {
        let tile = 64usize;
        let keys: Vec<Key> = (0..256u32).map(|x| x.wrapping_mul(37) % 97).collect();
        let mut sorted = keys.clone();
        for t in sorted.chunks_exact_mut(tile) {
            t.sort_unstable();
        }
        let b = boundaries(&sorted, tile, &[10, 20, 80], &mut Ledger::default());
        for row in b.chunks_exact(4) {
            let sizes = row_bucket_sizes(row);
            assert_eq!(sizes.iter().sum::<u32>(), tile as u32);
        }
    }

    #[test]
    fn ledger_matches_analytic() {
        let tile = 32usize;
        let mut keys: Vec<Key> = (0..128u32).map(|x| x.wrapping_mul(41)).collect();
        for t in keys.chunks_exact_mut(tile) {
            t.sort_unstable();
        }
        let splitters: Vec<Key> = vec![100, 2000, 4000];
        let mut a = Ledger::default();
        boundaries(&keys, tile, &splitters, &mut a);
        let mut b = Ledger::default();
        analytic(128, tile, 4, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_splitters_single_bucket() {
        let keys: Vec<Key> = (0..8).collect();
        let b = boundaries(&keys, 8, &[], &mut Ledger::default());
        assert_eq!(b, vec![8]);
    }
}

//! Sharded (multi-device) deterministic sample sort — the first step
//! past the paper's hardware.
//!
//! Figures 6 & 7 of the paper end where the device's global memory
//! ends: 64M keys on the GTX 260, 256M on the GTX 285 (2 GB), 512M on
//! the Tesla C1060. This module removes that ceiling by running the
//! same splitter discipline **one level up**: partition the input
//! across a [`DevicePool`], run [`BucketSort`] (Algorithm 1) per
//! device, then combine the shards with a deterministic cross-device
//! sample sort — regular sampling of every sorted shard, a global
//! splitter sort, a partition/exchange, and a p-way merge per
//! destination device (the multiway-merge structure of Casanova et
//! al., arXiv:1702.07961).
//!
//! Determinism is preserved at both levels. Within a device, bucket
//! sizes are guaranteed by the paper's regular sampling; across
//! devices, the same regular-sampling argument (Shi & Schaeffer)
//! bounds every destination shard, so — unlike a randomized
//! splitter choice — no device becomes a data-dependent straggler or
//! OOMs on a skewed input. The combine step's launch/traffic ledger is
//! **input-independent** by construction: merge work is priced at the
//! capacity-weighted balanced shard size, exactly as Step 9 of
//! [`BucketSort`] prices buckets at their guaranteed capacity.
//!
//! Two entry points mirror the single-device API:
//! * [`ShardedSort::sort`] — executes everything for real on the host
//!   while each [`crate::sim::GpuSim`] in the pool records the traffic
//!   its device would generate;
//! * [`ShardedSort::sort_analytic`] — the identical per-device ledgers
//!   from closed forms, enabling pool configurations beyond any single
//!   device's memory (≥ 512M keys) without materializing data.

use super::bucket_sort::{BucketSort, BucketSortParams, BucketSortReport};
use super::{bitonic, indexing, prefix, sampling, ExecContext};
use crate::error::{Error, Result};
use crate::key::Record;
use crate::sim::fault::DeviceFault;
use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::pool::DevicePool;
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::sim::CostModel;
use crate::util::ScratchArena;
use crate::{SortKey, KEY_BYTES};

/// Tunable parameters of the sharded sort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardedSortParams {
    /// Algorithm-1 parameters used by every device's local sort.
    pub sort: BucketSortParams,
    /// Regular samples taken from each sorted shard for cross-device
    /// splitter selection (the inter-device analogue of the paper's
    /// `s`). More samples tighten the destination-shard balance bound.
    pub merge_samples: usize,
}

impl Default for ShardedSortParams {
    fn default() -> Self {
        ShardedSortParams {
            sort: BucketSortParams::default(),
            merge_samples: 64,
        }
    }
}

impl ShardedSortParams {
    /// Validate the combination.
    pub fn validate(&self) -> Result<()> {
        self.sort.validate()?;
        if self.merge_samples == 0 {
            return Err(crate::Error::InvalidParams(
                "merge_samples must be positive".into(),
            ));
        }
        Ok(())
    }
}

/// Everything recorded about one sharded sort. Per-device vectors are
/// indexed like the pool that produced them.
#[derive(Debug, Clone)]
pub struct ShardedSortReport {
    /// Requested key count.
    pub n: usize,
    /// Capacity-weighted input shard per device (sums to `n`).
    pub shard_sizes: Vec<usize>,
    /// Per-device Algorithm-1 report for the local sort phase.
    pub local: Vec<BucketSortReport>,
    /// Coordinator-side combine traffic (sampling, splitter sort,
    /// partition, prefix, exchange), recorded on the coordinating
    /// device ([`ShardedSortReport::coordinator`]).
    pub combine: Ledger,
    /// Pool index of the device that coordinated the combine phase —
    /// the lowest-indexed *healthy* device (0 on a fault-free run).
    pub coordinator: usize,
    /// Device-lost failovers survived during this run: each one marked
    /// a device unhealthy and re-planned the sort over the survivors.
    pub failovers: u32,
    /// Per-destination-device merge traffic.
    pub merge: Vec<Ledger>,
    /// Peak simulated memory per device over the whole run.
    pub peak_device_bytes: Vec<usize>,
    /// Largest destination shard observed (`0` for analytic runs); the
    /// regular-sampling discipline keeps it near the balanced share.
    pub max_out_shard: u64,
}

impl ShardedSortReport {
    /// Number of devices the run was sharded over.
    pub fn devices(&self) -> usize {
        self.shard_sizes.len()
    }

    /// Estimated wall-clock milliseconds of the sharded run on `pool`
    /// (which must be the pool that produced this report): devices run
    /// each phase in parallel, so the makespan is the slowest device's
    /// local sort, plus the coordinator's combine pass, plus the
    /// slowest device's merge.
    pub fn makespan_ms(&self, pool: &DevicePool) -> f64 {
        let local = self
            .local
            .iter()
            .enumerate()
            .map(|(d, r)| CostModel::default_params(pool.spec(d)).ledger_ms(&r.ledger))
            .fold(0.0, f64::max);
        let combine =
            CostModel::default_params(pool.spec(self.coordinator)).ledger_ms(&self.combine);
        let merge = self
            .merge
            .iter()
            .enumerate()
            .map(|(d, l)| CostModel::default_params(pool.spec(d)).ledger_ms(l))
            .fold(0.0, f64::max);
        local + combine + merge
    }

    /// Pool-level sorting rate in Mkeys/s (the §5 metric, scaled out).
    pub fn sort_rate_mkeys_s(&self, pool: &DevicePool) -> f64 {
        CostModel::sort_rate_mkeys_s(self.n, self.makespan_ms(pool))
    }
}

/// Shape-determined structure of the combine phase — computed once from
/// the shard sizes and shared by the Execute and Analytic paths so
/// their ledgers agree by construction.
struct CombinePlan {
    /// Samples contributed by each shard: `min(merge_samples, share)`.
    sample_counts: Vec<usize>,
    /// Σ sample_counts.
    total_samples: usize,
    /// Sample array padded to a power of two for the bitonic sort.
    padded_samples: usize,
    /// Binary-search probes of the partition step (fixed trip counts,
    /// so shape-determined).
    probes: u64,
    /// Pairwise-merge rounds per destination: ⌈log2 p⌉.
    merge_rounds: u32,
}

/// The multi-device deterministic sample sorter.
#[derive(Debug, Clone)]
pub struct ShardedSort {
    params: ShardedSortParams,
}

impl ShardedSort {
    /// Construct with the given parameters (panics on invalid ones; use
    /// [`ShardedSort::try_new`] for fallible construction).
    pub fn new(params: ShardedSortParams) -> Self {
        params.validate().expect("invalid ShardedSortParams");
        ShardedSort { params }
    }

    /// Fallible constructor.
    pub fn try_new(params: ShardedSortParams) -> Result<Self> {
        params.validate()?;
        Ok(ShardedSort { params })
    }

    /// The parameters in use.
    pub fn params(&self) -> &ShardedSortParams {
        &self.params
    }

    /// Sort `keys` in place across the pool, recording per-device
    /// traffic and enforcing every device's memory capacity. Generic
    /// over [`SortKey`]; the ledgers scale with the key width.
    ///
    /// The output is the fully sorted permutation of the input —
    /// byte-identical to what a single-device [`BucketSort`] with
    /// enough memory would produce.
    pub fn sort<K: SortKey>(
        &self,
        keys: &mut [K],
        pool: &mut DevicePool,
    ) -> Result<ShardedSortReport> {
        self.sort_in(keys, pool, &ExecContext::default())
    }

    /// [`ShardedSort::sort`] with explicit execution resources: shard
    /// copies, the exchange target and the merge ping-pong buffers come
    /// from `ctx.arena`, and the per-device [`BucketSort`] phase runs
    /// with the context's kernel, planner digit width and worker
    /// budget — each shard's Algorithm 1 inherits the fused
    /// Step 2+3 / Step 8+9 traversals and the wide-digit pass schedule
    /// (see [`crate::algos::plan`]) exactly like the single-device
    /// path.
    ///
    /// **Failover:** a [`Error::DeviceLost`] mid-attempt (fault
    /// injection, or a real device dropping off) marks the device
    /// unhealthy in the pool and re-plans the whole sort over the
    /// survivors — deterministic splitter selection re-runs at the new
    /// shard count, and because a sorted sequence is the unique ordering
    /// of its input multiset (key–value jobs carry tie-breaking
    /// indices), the recovered output is **byte-identical** to the
    /// fault-free run. `keys` is never written by a failed attempt (the
    /// final `copy_from_slice` is the only write), so retrying is safe.
    /// The pool's sims are reset between attempts: ledgers and peaks
    /// describe the final, successful attempt. The loss of the last
    /// healthy device is returned as the typed error.
    pub fn sort_in<K: SortKey>(
        &self,
        keys: &mut [K],
        pool: &mut DevicePool,
        ctx: &ExecContext,
    ) -> Result<ShardedSortReport> {
        let mut failovers = 0u32;
        loop {
            match self.sort_attempt(keys, pool, ctx) {
                Ok(mut report) => {
                    report.failovers = failovers;
                    return Ok(report);
                }
                Err(Error::DeviceLost { device, name }) if pool.healthy_count() > 1 => {
                    let _ = name;
                    pool.mark_unhealthy(device)?;
                    pool.reset();
                    failovers += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One attempt of the sharded sort over the pool's *healthy*
    /// devices. Report vectors stay pool-aligned (dead devices hold an
    /// empty local report, zero share and an empty merge ledger) so
    /// callers can keep indexing by pool position.
    fn sort_attempt<K: SortKey>(
        &self,
        keys: &mut [K],
        pool: &mut DevicePool,
        ctx: &ExecContext,
    ) -> Result<ShardedSortReport> {
        let n = keys.len();
        let elem_bytes = K::WIDTH_BYTES;
        let p = pool.len();
        let active = pool.healthy_indices();
        let ap = active.len();
        let shares = pool.shares(n); // pool-aligned; zero at dead devices
        // Inputs too small to give every (healthy) device at least one
        // tile are not worth sharding (the combine overhead dominates):
        // route them to the highest-capacity device. The rule depends
        // only on (n, pool, health), keeping Execute/Analytic agreement.
        if ap == 1 || active.iter().any(|&d| shares[d] < self.params.sort.tile) {
            return self.fallback(FallbackInput::Execute(keys), pool, ctx);
        }
        let c0 = active[0];
        let sorter = BucketSort::try_new(self.params.sort)?;

        // Phase 1: per-device Algorithm 1 over the capacity-weighted
        // shards (devices run in parallel; ledgers are per-sim). Dead
        // devices idle with an empty report; each live device's step is
        // an instrumented fault point.
        let mut local = Vec::with_capacity(p);
        let mut shards: Vec<crate::util::ScratchBuf<K>> = Vec::with_capacity(ap);
        let mut off = 0usize;
        for (d, &len) in shares.iter().enumerate() {
            if !pool.is_healthy(d) {
                local.push(sorter.sort_in(&mut [] as &mut [K], pool.sim_mut(d), ctx)?);
                continue;
            }
            probe_device(pool, ctx, d)?;
            let mut shard = ctx.arena.take_from(&keys[off..off + len]);
            off += len;
            local.push(sorter.sort_in(shard.as_mut_slice(), pool.sim_mut(d), ctx)?);
            shards.push(shard);
        }

        // Phase 2: deterministic cross-device splitter selection and
        // exchange, coordinated on the lowest-indexed healthy device.
        let ashares: Vec<usize> = active.iter().map(|&d| shares[d]).collect();
        let plan = self.combine_plan(&ashares);
        let mut combine = Ledger::default();
        let combine_alloc = pool
            .sim_mut(c0)
            .alloc(plan.padded_samples * elem_bytes + 3 * ap * ap * KEY_BYTES)?;

        // Regular samples from every sorted shard (the PSRS step).
        let mut samples = ctx.arena.take_empty::<K>();
        samples.reserve(plan.padded_samples);
        for (shard, &t) in shards.iter().zip(&plan.sample_counts) {
            for k in 0..t {
                samples.push(shard[(k + 1) * shard.len() / t - 1]);
            }
        }
        debug_assert_eq!(samples.len(), plan.total_samples);
        record_shard_samples(
            ap,
            self.params.merge_samples,
            plan.total_samples,
            elem_bytes,
            &mut combine,
        );

        // Sort all samples globally; ap−1 equidistant picks become the
        // cross-device splitters.
        samples.resize(plan.padded_samples, K::PAD);
        bitonic::global_sort(samples.as_mut_slice(), self.params.sort.tile, &mut combine, 0);
        let splitters =
            sampling::select_splitters(&samples[..plan.total_samples], ap, &mut combine);

        // Partition every sorted shard by the splitters (fixed-trip
        // binary searches, shape-determined probe counts).
        let mut counts = vec![0u32; ap * ap];
        let mut probes = 0u64;
        for (i, shard) in shards.iter().enumerate() {
            let mut prev = 0usize;
            for (j, bound) in splitters
                .iter()
                .map(|&sp| {
                    let (pos, pr) = indexing::fixed_lower_bound(shard.as_slice(), sp);
                    probes += pr;
                    pos
                })
                .chain(std::iter::once(shard.len()))
                .enumerate()
            {
                counts[i * ap + j] = (bound - prev) as u32;
                prev = bound;
            }
        }
        debug_assert_eq!(probes, plan.probes);
        record_partition(ap, plan.probes, &mut combine);

        // Destination layout (column-major, exactly Step 7's machinery
        // with m = s = ap) and the all-to-all exchange.
        let layout = prefix::column_prefix(&counts, ap, ap, &mut combine);
        let mut out = ctx.arena.take(n, K::PAD);
        for (i, shard) in shards.iter().enumerate() {
            let mut seg_start = 0usize;
            for j in 0..ap {
                let len = counts[i * ap + j] as usize;
                let dst = layout.loc[i * ap + j] as usize;
                out[dst..dst + len].copy_from_slice(&shard[seg_start..seg_start + len]);
                seg_start += len;
            }
            debug_assert_eq!(seg_start, shard.len());
        }
        record_exchange(n, ap, elem_bytes, &mut combine);
        pool.sim_mut(c0).free(combine_alloc);
        pool.sim_mut(c0).ledger_mut().extend_from(&combine);

        // Phase 3: every destination device ap-way merges its sorted
        // runs. Priced at the balanced (capacity-weighted) size so the
        // ledger stays input-independent — the same discipline as
        // Step 9's guaranteed-capacity pricing. Each destination step is
        // an instrumented fault point.
        let mut merge = vec![Ledger::default(); p];
        let mut max_out_shard = 0u64;
        for (j, &dj) in active.iter().enumerate() {
            probe_device(pool, ctx, dj)?;
            let start = layout.bucket_start[j] as usize;
            let len = layout.bucket_size[j] as usize;
            max_out_shard = max_out_shard.max(len as u64);
            let alloc = pool.sim_mut(dj).alloc(2 * ashares[j] * elem_bytes)?;
            let mut bounds = Vec::with_capacity(ap + 1);
            bounds.push(0usize);
            for i in 0..ap {
                bounds.push(bounds[i] + counts[i * ap + j] as usize);
            }
            debug_assert_eq!(bounds[ap], len);
            let rounds = merge_runs(&mut out[start..start + len], &bounds, &ctx.arena);
            debug_assert_eq!(rounds, plan.merge_rounds);
            let mut ledger = Ledger::default();
            record_merge(
                ashares[j],
                self.params.sort.tile,
                plan.merge_rounds,
                elem_bytes,
                &mut ledger,
            );
            pool.sim_mut(dj).free(alloc);
            pool.sim_mut(dj).ledger_mut().extend_from(&ledger);
            merge[dj] = ledger;
        }

        keys.copy_from_slice(out.as_slice());
        Ok(ShardedSortReport {
            n,
            shard_sizes: shares,
            local,
            combine,
            coordinator: c0,
            failovers: 0,
            merge,
            peak_device_bytes: pool.sims().iter().map(|s| s.peak_bytes()).collect(),
            max_out_shard,
        })
    }

    /// Sort a key–value job across the pool: `keys` in place, `payload`
    /// permuted so `payload[i]` still belongs to `keys[i]` afterwards.
    /// Runs both levels of the splitter discipline over [`Record`]s
    /// (stable, byte-deterministic; widened ledger accounting).
    pub fn sort_pairs<K: SortKey>(
        &self,
        keys: &mut [K],
        payload: &mut Vec<u64>,
        pool: &mut DevicePool,
    ) -> Result<ShardedSortReport> {
        self.sort_pairs_in(keys, payload, pool, &ExecContext::default())
    }

    /// [`ShardedSort::sort_pairs`] with explicit execution resources.
    pub fn sort_pairs_in<K: SortKey>(
        &self,
        keys: &mut [K],
        payload: &mut Vec<u64>,
        pool: &mut DevicePool,
        ctx: &ExecContext,
    ) -> Result<ShardedSortReport> {
        crate::key::validate_key_value(keys.len(), payload.len())?;
        let mut recs = ctx.arena.take_empty::<Record<K>>();
        crate::key::tag_records_into(keys, &mut recs)?;
        let report = self.sort_in(recs.as_mut_slice(), pool, ctx)?;
        crate::key::untag_records_in(recs.as_slice(), keys, payload, &ctx.arena);
        Ok(report)
    }

    /// Produce the per-device ledgers and memory profile of sharding
    /// `n` keys across `pool` without touching data, at the classic
    /// `u32` width.
    pub fn sort_analytic(&self, n: usize, pool: &mut DevicePool) -> Result<ShardedSortReport> {
        self.sort_analytic_bytes(n, KEY_BYTES, pool)
    }

    /// Ledger-only twin of [`ShardedSort::sort`] at an explicit
    /// per-element width — identical launches and allocations. This is
    /// what demonstrates sorts beyond any single device's ceiling
    /// (≥ 512M keys) at negligible host cost.
    pub fn sort_analytic_bytes(
        &self,
        n: usize,
        elem_bytes: usize,
        pool: &mut DevicePool,
    ) -> Result<ShardedSortReport> {
        let p = pool.len();
        let active = pool.healthy_indices();
        let ap = active.len();
        let shares = pool.shares(n);
        if ap == 1 || active.iter().any(|&d| shares[d] < self.params.sort.tile) {
            return self.fallback(
                FallbackInput::<u32>::Analytic(n, elem_bytes),
                pool,
                &ExecContext::default(),
            );
        }
        let c0 = active[0];
        let sorter = BucketSort::try_new(self.params.sort)?;

        let mut local = Vec::with_capacity(p);
        for (d, &len) in shares.iter().enumerate() {
            local.push(sorter.sort_analytic_bytes(len, elem_bytes, pool.sim_mut(d))?);
        }

        let ashares: Vec<usize> = active.iter().map(|&d| shares[d]).collect();
        let plan = self.combine_plan(&ashares);
        let mut combine = Ledger::default();
        let combine_alloc = pool
            .sim_mut(c0)
            .alloc(plan.padded_samples * elem_bytes + 3 * ap * ap * KEY_BYTES)?;
        record_shard_samples(
            ap,
            self.params.merge_samples,
            plan.total_samples,
            elem_bytes,
            &mut combine,
        );
        bitonic::global_sort_analytic_bytes(
            plan.padded_samples,
            self.params.sort.tile,
            elem_bytes,
            &mut combine,
            0,
        );
        sampling::analytic_splitters_bytes(plan.total_samples, ap, elem_bytes, &mut combine);
        record_partition(ap, plan.probes, &mut combine);
        prefix::analytic(ap, ap, &mut combine);
        record_exchange(n, ap, elem_bytes, &mut combine);
        pool.sim_mut(c0).free(combine_alloc);
        pool.sim_mut(c0).ledger_mut().extend_from(&combine);

        let mut merge = vec![Ledger::default(); p];
        for (j, &dj) in active.iter().enumerate() {
            let alloc = pool.sim_mut(dj).alloc(2 * ashares[j] * elem_bytes)?;
            let mut ledger = Ledger::default();
            record_merge(
                ashares[j],
                self.params.sort.tile,
                plan.merge_rounds,
                elem_bytes,
                &mut ledger,
            );
            pool.sim_mut(dj).free(alloc);
            pool.sim_mut(dj).ledger_mut().extend_from(&ledger);
            merge[dj] = ledger;
        }

        Ok(ShardedSortReport {
            n,
            shard_sizes: shares,
            local,
            combine,
            coordinator: c0,
            failovers: 0,
            merge,
            peak_device_bytes: pool.sims().iter().map(|s| s.peak_bytes()).collect(),
            max_out_shard: 0,
        })
    }

    /// Single-device route for pools of one and inputs too small to
    /// shard: the highest-capacity *healthy* device sorts everything,
    /// the others idle (empty reports, empty combine/merge ledgers).
    fn fallback<K: SortKey>(
        &self,
        input: FallbackInput<'_, K>,
        pool: &mut DevicePool,
        ctx: &ExecContext,
    ) -> Result<ShardedSortReport> {
        let p = pool.len();
        let n = input.len();
        let target = (0..p)
            .filter(|&d| pool.is_healthy(d))
            .max_by_key(|&d| (pool.spec(d).max_sortable_keys(), std::cmp::Reverse(d)))
            .expect("a pool always has a healthy device");
        let sorter = BucketSort::try_new(self.params.sort)?;
        let mut shard_sizes = vec![0usize; p];
        shard_sizes[target] = n;
        let mut local = Vec::with_capacity(p);
        let mut max_out_shard = 0u64;
        match input {
            FallbackInput::Execute(keys) => {
                probe_device(pool, ctx, target)?;
                for d in 0..p {
                    local.push(if d == target {
                        max_out_shard = n as u64;
                        sorter.sort_in(&mut keys[..], pool.sim_mut(d), ctx)?
                    } else {
                        sorter.sort_in(&mut [] as &mut [K], pool.sim_mut(d), ctx)?
                    });
                }
            }
            FallbackInput::Analytic(_, elem_bytes) => {
                for d in 0..p {
                    let len = if d == target { n } else { 0 };
                    local.push(sorter.sort_analytic_bytes(len, elem_bytes, pool.sim_mut(d))?);
                }
            }
        }
        Ok(ShardedSortReport {
            n,
            shard_sizes,
            local,
            combine: Ledger::default(),
            coordinator: target,
            failovers: 0,
            merge: vec![Ledger::default(); p],
            peak_device_bytes: pool.sims().iter().map(|s| s.peak_bytes()).collect(),
            max_out_shard,
        })
    }

    /// Build the shape-determined combine plan for the given shards.
    fn combine_plan(&self, shares: &[usize]) -> CombinePlan {
        let p = shares.len();
        let sample_counts: Vec<usize> = shares
            .iter()
            .map(|&len| self.params.merge_samples.min(len))
            .collect();
        let total_samples: usize = sample_counts.iter().sum();
        let probes = shares
            .iter()
            .map(|&len| (p as u64 - 1) * probe_count(len))
            .sum();
        CombinePlan {
            sample_counts,
            total_samples,
            padded_samples: bitonic::next_pow2(total_samples),
            probes,
            merge_rounds: merge_rounds(p),
        }
    }
}

/// Input carrier for the single-device fallback route.
enum FallbackInput<'a, K> {
    /// Execute path: the keys to sort in place.
    Execute(&'a mut [K]),
    /// Analytic path: key count and per-element width.
    Analytic(usize, usize),
}

impl<K> FallbackInput<'_, K> {
    fn len(&self) -> usize {
        match self {
            FallbackInput::Execute(keys) => keys.len(),
            FallbackInput::Analytic(n, _) => *n,
        }
    }
}

/// Ask the context's fault injector (if any) whether pool device `d`
/// fails at this step, and map the injected fault onto the typed error
/// the recovery machinery dispatches on. One `Option` check when no
/// plan is loaded.
fn probe_device(pool: &DevicePool, ctx: &ExecContext, d: usize) -> Result<()> {
    let Some(inj) = ctx.faults.as_ref() else {
        return Ok(());
    };
    match inj.device_fault(d) {
        None => Ok(()),
        Some(DeviceFault::Lost) => Err(Error::DeviceLost {
            device: d,
            name: pool.spec(d).name.clone(),
        }),
        // An injected mid-step allocation failure: capacity errors are
        // fatal for the request (retrying cannot grow the device).
        Some(DeviceFault::Oom) => Err(Error::DeviceOom {
            requested: pool.spec(d).usable_global_memory_bytes(),
            available: 0,
            device: pool.spec(d).name.clone(),
        }),
    }
}

/// Probe count of [`indexing::fixed_lower_bound`] over a slice of
/// `len` elements — shape-determined (the search is fixed-trip), so the
/// analytic ledger can reproduce it without data.
fn probe_count(len: usize) -> u64 {
    if len == 0 {
        return 0;
    }
    let mut size = len;
    let mut probes = 0u64;
    while size > 1 {
        size -= size / 2;
        probes += 1;
    }
    probes + 1
}

/// ⌈log2 p⌉ pairwise-merge rounds to combine `p` sorted runs.
fn merge_rounds(p: usize) -> u32 {
    p.next_power_of_two().trailing_zeros()
}

/// Bottom-up pairwise merge of the sorted runs delimited by `bounds`
/// (ascending positions; `bounds[0] == 0`,
/// `bounds[last] == region.len()`; empty runs allowed). Returns the
/// number of rounds executed — always [`merge_rounds`] of the run
/// count, the shape the ledger prices. Ping-pong buffers come from the
/// arena.
fn merge_runs<K: SortKey>(region: &mut [K], bounds: &[usize], arena: &ScratchArena) -> u32 {
    let mut a = arena.take_from(region);
    let mut b = arena.take(region.len(), K::PAD);
    let mut cur: Vec<usize> = bounds.to_vec();
    let mut rounds = 0u32;
    while cur.len() > 2 {
        let mut next = Vec::with_capacity(cur.len() / 2 + 2);
        next.push(0usize);
        let mut i = 0usize;
        while i + 2 < cur.len() {
            merge_two(
                &a[cur[i]..cur[i + 1]],
                &a[cur[i + 1]..cur[i + 2]],
                &mut b[cur[i]..cur[i + 2]],
            );
            next.push(cur[i + 2]);
            i += 2;
        }
        if i + 1 < cur.len() {
            // Odd run out: carried into the next round unchanged.
            b[cur[i]..cur[i + 1]].copy_from_slice(&a[cur[i]..cur[i + 1]]);
            next.push(cur[i + 1]);
        }
        std::mem::swap(&mut a, &mut b);
        cur = next;
        rounds += 1;
    }
    region.copy_from_slice(&a);
    rounds
}

/// Stable two-way merge of sorted `x` and `y` into `out`
/// (`out.len() == x.len() + y.len()`).
fn merge_two<K: SortKey>(x: &[K], y: &[K], out: &mut [K]) {
    debug_assert_eq!(out.len(), x.len() + y.len());
    let (mut i, mut j) = (0usize, 0usize);
    for slot in out.iter_mut() {
        if i < x.len() && (j >= y.len() || x[i].key_le(&y[j])) {
            *slot = x[i];
            i += 1;
        } else {
            *slot = y[j];
            j += 1;
        }
    }
}

/// Regular-sample extraction from every shard: one block per shard,
/// strided (scattered) reads plus a coalesced write of the sample
/// array — the cross-device twin of Step 3.
fn record_shard_samples(
    p: usize,
    samples_per_shard: usize,
    total: usize,
    elem_bytes: usize,
    ledger: &mut Ledger,
) {
    ledger.begin_kernel(
        KernelClass::Sample,
        p as u64,
        samples_per_shard.min(MAX_BLOCK_THREADS as usize) as u32,
    );
    ledger.add_scattered(total as u64);
    ledger.add_coalesced((total * elem_bytes) as u64);
    ledger.add_compute(total as u64);
    ledger.end_kernel();
}

/// Splitter location in every sorted shard: `p−1` fixed-trip binary
/// searches per shard (scattered probes into global memory) plus the
/// p×p boundary-matrix write-back — the cross-device twin of Step 6.
fn record_partition(p: usize, probes: u64, ledger: &mut Ledger) {
    ledger.begin_kernel(
        KernelClass::SampleIndex,
        p as u64,
        p.min(MAX_BLOCK_THREADS as usize) as u32,
    );
    ledger.add_scattered(probes);
    ledger.add_compute(probes);
    // Boundary matrix: u32 counts regardless of key type.
    ledger.add_coalesced((p * p * KEY_BYTES) as u64);
    ledger.end_kernel();
}

/// The all-to-all segment exchange: every key crosses the interconnect
/// once (coalesced read + write), plus the small boundary/location
/// matrices — the cross-device twin of Step 8.
fn record_exchange(n: usize, p: usize, elem_bytes: usize, ledger: &mut Ledger) {
    ledger.begin_kernel(KernelClass::Transfer, p as u64, MAX_BLOCK_THREADS);
    // Keys widen with the element type; the count/location matrices
    // stay u32.
    ledger.add_coalesced((2 * n * elem_bytes + 2 * p * p * KEY_BYTES) as u64);
    ledger.add_compute((p * p) as u64);
    ledger.end_kernel();
}

/// One destination device's merge: `rounds` streaming passes over its
/// balanced share (read + write + one compare per key per round).
fn record_merge(balanced: usize, tile: usize, rounds: u32, elem_bytes: usize, ledger: &mut Ledger) {
    let blocks = (balanced / tile).max(1) as u64;
    for _ in 0..rounds {
        ledger.begin_kernel(KernelClass::Merge, blocks, MAX_BLOCK_THREADS);
        ledger.add_coalesced((2 * balanced * elem_bytes) as u64);
        ledger.add_compute(balanced as u64);
        ledger.end_kernel();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{GpuModel, GpuSpec};
    use crate::{is_sorted_permutation, Key};

    fn small_params() -> ShardedSortParams {
        ShardedSortParams {
            sort: BucketSortParams { tile: 256, s: 16 },
            merge_samples: 16,
        }
    }

    fn scrambled(n: usize) -> Vec<Key> {
        (0..n as u32).map(|x| x.wrapping_mul(2654435761) ^ 0x5BD1).collect()
    }

    #[test]
    fn sorts_across_heterogeneous_pool() {
        let sorter = ShardedSort::new(small_params());
        for n in [0usize, 1, 100, 4096, 50_000, 200_000] {
            let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
            let mut keys = scrambled(n);
            let orig = keys.clone();
            let report = sorter.sort(&mut keys, &mut pool).unwrap();
            assert!(is_sorted_permutation(&orig, &keys), "n={n}");
            assert_eq!(report.n, n);
            assert_eq!(report.shard_sizes.iter().sum::<usize>(), n);
            assert_eq!(report.devices(), 4);
            for sim in pool.sims() {
                assert_eq!(sim.allocated_bytes(), 0, "all allocations freed");
            }
        }
    }

    #[test]
    fn matches_single_device_bucket_sort() {
        let sorter = ShardedSort::new(small_params());
        let single = BucketSort::new(small_params().sort);
        let n = 40_000;
        let input = scrambled(n);

        let mut sharded_out = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        sorter.sort(&mut sharded_out, &mut pool).unwrap();

        let mut single_out = input.clone();
        let mut sim = crate::sim::GpuSim::new(GpuModel::TeslaC1060.spec());
        single.sort(&mut single_out, &mut sim).unwrap();

        assert_eq!(sharded_out, single_out);
    }

    #[test]
    fn analytic_matches_executed() {
        let sorter = ShardedSort::new(small_params());
        for n in [0usize, 100, 4096, 50_000, 131_072] {
            let mut pool_e = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
            let mut keys = scrambled(n);
            let exec = sorter.sort(&mut keys, &mut pool_e).unwrap();
            let mut pool_a = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
            let ana = sorter.sort_analytic(n, &mut pool_a).unwrap();

            assert_eq!(exec.shard_sizes, ana.shard_sizes, "n={n}");
            assert_eq!(exec.combine, ana.combine, "n={n}");
            assert_eq!(exec.merge, ana.merge, "n={n}");
            for d in 0..exec.local.len() {
                assert_eq!(exec.local[d].ledger, ana.local[d].ledger, "n={n} d={d}");
            }
            assert_eq!(exec.peak_device_bytes, ana.peak_device_bytes, "n={n}");
            // The whole-sim ledgers agree too.
            for (se, sa) in pool_e.sims().iter().zip(pool_a.sims()) {
                assert_eq!(se.ledger(), sa.ledger(), "n={n}");
            }
        }
    }

    #[test]
    fn combine_ledger_is_input_independent() {
        let sorter = ShardedSort::new(small_params());
        let n = 30_000;
        let inputs: Vec<Vec<Key>> = vec![
            scrambled(n),
            (0..n as u32).collect(),
            (0..n as u32).rev().collect(),
            (0..n as u32).map(|x| x % 7).collect(),
        ];
        let mut reports = Vec::new();
        for mut keys in inputs {
            let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
            reports.push(sorter.sort(&mut keys, &mut pool).unwrap());
        }
        for r in &reports[1..] {
            assert_eq!(r.combine, reports[0].combine);
            assert_eq!(r.merge, reports[0].merge);
        }
    }

    #[test]
    fn pool_exceeds_single_device_capacity() {
        // Two tiny 4 MB devices: ~500K keys OOM a single device (needs
        // 2·n·4 B = 4.8 MB) but fit the pool (2.4 MB per shard).
        let tiny = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 4 << 20,
            ..GpuModel::Gtx260.spec()
        };
        let params = small_params();
        let n = 600_000;

        let single = BucketSort::new(params.sort);
        let mut sim = crate::sim::GpuSim::new(tiny.clone());
        assert!(single.sort_analytic(n, &mut sim).unwrap_err().is_oom());

        let sorter = ShardedSort::new(params);
        let mut pool = DevicePool::from_specs(vec![tiny.clone(), tiny]).unwrap();
        let mut keys = scrambled(n);
        let orig = keys.clone();
        let report = sorter.sort(&mut keys, &mut pool).unwrap();
        assert!(is_sorted_permutation(&orig, &keys));
        assert!(report.makespan_ms(&pool) > 0.0);
        assert!(report.sort_rate_mkeys_s(&pool) > 0.0);
    }

    #[test]
    fn fallback_routes_small_inputs_to_best_device() {
        let sorter = ShardedSort::new(small_params());
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let n = 100; // below p·tile ⇒ fallback
        let mut keys = scrambled(n);
        let report = sorter.sort(&mut keys, &mut pool).unwrap();
        assert!(crate::is_sorted(&keys));
        // Tesla (index 1) has the largest capacity in the default pool.
        assert_eq!(report.shard_sizes, vec![0, n, 0, 0]);
        assert_eq!(report.combine.kernel_count(), 0);
    }

    #[test]
    fn merge_helpers() {
        assert_eq!(merge_rounds(1), 0);
        assert_eq!(merge_rounds(2), 1);
        assert_eq!(merge_rounds(3), 2);
        assert_eq!(merge_rounds(4), 2);
        assert_eq!(merge_rounds(5), 3);

        // probe_count mirrors fixed_lower_bound's trip count.
        for len in [0usize, 1, 2, 3, 7, 8, 100, 4096] {
            let t: Vec<Key> = (0..len as u32).collect();
            let (_, probes) = indexing::fixed_lower_bound(&t, 1);
            assert_eq!(probes, probe_count(len), "len={len}");
        }

        // merge_runs over mixed-length (and empty) runs.
        let mut v: Vec<Key> = vec![5, 9, 42, 1, 3, 4, 8, 0, 2];
        let bounds = [0usize, 3, 3, 7, 9];
        let rounds = merge_runs(&mut v, &bounds, &ScratchArena::new());
        assert_eq!(v, vec![0, 1, 2, 3, 4, 5, 8, 9, 42]);
        assert_eq!(rounds, merge_rounds(4));
    }

    #[test]
    fn typed_and_key_value_sharding() {
        let sorter = ShardedSort::new(small_params());
        // u64 keys across the heterogeneous pool.
        let input: Vec<u64> = (0..60_000u64)
            .map(|x| x.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let mut keys = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        sorter.sort(&mut keys, &mut pool).unwrap();
        assert!(is_sorted_permutation(&input, &keys));

        // Key–value over f32 keys with NaNs: payloads stay married to
        // their keys through both levels of the splitter discipline.
        let mut fkeys: Vec<f32> = (0..50_000u32)
            .map(|x| x.wrapping_mul(2654435761) as f32 - 2e9)
            .collect();
        fkeys[11] = f32::NAN;
        fkeys[17] = f32::NEG_INFINITY;
        let payload: Vec<u64> = (0..fkeys.len() as u64).collect();
        let orig = fkeys.clone();
        let mut out_keys = fkeys.clone();
        let mut out_payload = payload.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        sorter
            .sort_pairs(&mut out_keys, &mut out_payload, &mut pool)
            .unwrap();
        assert!(is_sorted_permutation(&orig, &out_keys));
        for (k, p) in out_keys.iter().zip(&out_payload) {
            let original = orig[*p as usize];
            assert_eq!(
                f32::to_bits(original),
                f32::to_bits(*k),
                "payload {p} no longer points at its key"
            );
        }
    }

    fn fault_ctx(plan_json: &str) -> ExecContext {
        ExecContext::default()
            .with_faults(Some(crate::sim::FaultPlan::parse(plan_json).unwrap().injector()))
    }

    #[test]
    fn device_loss_fails_over_byte_identically() {
        let sorter = ShardedSort::new(small_params());
        let n = 60_000;
        let input = scrambled(n);

        let mut baseline = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        sorter.sort(&mut baseline, &mut pool).unwrap();

        // Lose each device in turn (including the coordinator, device 0)
        // mid-run: the output must match the fault-free bytes exactly.
        for dead in 0..4usize {
            let mut keys = input.clone();
            let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
            let ctx = fault_ctx(&format!(
                r#"{{"version":1,"rules":[{{"point":"device_lost","target":{dead}}}]}}"#
            ));
            let report = sorter.sort_in(&mut keys, &mut pool, &ctx).unwrap();
            assert_eq!(keys, baseline, "dead={dead}");
            assert_eq!(report.failovers, 1, "dead={dead}");
            assert_eq!(report.shard_sizes[dead], 0, "dead={dead}");
            assert!(!pool.is_healthy(dead));
            assert_eq!(pool.healthy_count(), 3);
            // The combine moved off a dead coordinator.
            assert_ne!(report.coordinator, dead);
            for sim in pool.sims() {
                assert_eq!(sim.allocated_bytes(), 0, "dead={dead}");
            }
        }
    }

    #[test]
    fn failover_report_matches_analytic_on_degraded_pool() {
        // A run that failed over to 3 devices prices exactly like a run
        // that started with the same device already unhealthy.
        let sorter = ShardedSort::new(small_params());
        let n = 60_000;
        let mut keys = scrambled(n);
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let ctx =
            fault_ctx(r#"{"version":1,"rules":[{"point":"device_lost","target":2}]}"#);
        let exec = sorter.sort_in(&mut keys, &mut pool, &ctx).unwrap();

        let mut pool_a = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        pool_a.mark_unhealthy(2).unwrap();
        let ana = sorter.sort_analytic(n, &mut pool_a).unwrap();
        assert_eq!(exec.shard_sizes, ana.shard_sizes);
        assert_eq!(exec.combine, ana.combine);
        assert_eq!(exec.merge, ana.merge);
        assert_eq!(exec.coordinator, ana.coordinator);
        for d in 0..4 {
            assert_eq!(exec.local[d].ledger, ana.local[d].ledger, "d={d}");
        }
    }

    #[test]
    fn repeated_losses_survive_down_to_one_device() {
        let sorter = ShardedSort::new(small_params());
        let n = 50_000;
        let input = scrambled(n);
        let mut baseline = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        sorter.sort(&mut baseline, &mut pool).unwrap();

        // Three losses leave one healthy device; the sort still lands
        // byte-identically via the fallback route.
        let mut keys = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let ctx = fault_ctx(r#"{"version":1,"rules":[{"point":"device_lost","count":3}]}"#);
        let report = sorter.sort_in(&mut keys, &mut pool, &ctx).unwrap();
        assert_eq!(keys, baseline);
        assert_eq!(report.failovers, 3);
        assert_eq!(pool.healthy_count(), 1);

        // A fourth loss has nowhere to go: typed error, input untouched.
        let mut keys = input.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let ctx = fault_ctx(r#"{"version":1,"rules":[{"point":"device_lost","count":4}]}"#);
        let err = sorter.sort_in(&mut keys, &mut pool, &ctx).unwrap_err();
        assert!(matches!(err, Error::DeviceLost { .. }), "{err}");
        assert_eq!(keys, input, "failed sort must not touch the input");
    }

    #[test]
    fn injected_oom_is_fatal_not_retried() {
        let sorter = ShardedSort::new(small_params());
        let mut keys = scrambled(60_000);
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let ctx = fault_ctx(r#"{"version":1,"rules":[{"point":"device_oom","target":1}]}"#);
        let err = sorter.sort_in(&mut keys, &mut pool, &ctx).unwrap_err();
        assert!(err.is_oom(), "{err}");
        assert_eq!(pool.healthy_count(), 4, "OOM must not mark devices dead");
    }

    #[test]
    fn key_value_failover_keeps_payloads_married() {
        let sorter = ShardedSort::new(small_params());
        let keys_in: Vec<u64> = (0..50_000u64)
            .map(|x| (x % 97).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let payload_in: Vec<u64> = (0..keys_in.len() as u64).collect();

        let mut bk = keys_in.clone();
        let mut bp = payload_in.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        sorter.sort_pairs(&mut bk, &mut bp, &mut pool).unwrap();

        let mut fk = keys_in.clone();
        let mut fp = payload_in.clone();
        let mut pool = DevicePool::new(&DevicePool::DEFAULT_DEVICES).unwrap();
        let ctx =
            fault_ctx(r#"{"version":1,"rules":[{"point":"device_lost","target":0}]}"#);
        sorter.sort_pairs_in(&mut fk, &mut fp, &mut pool, &ctx).unwrap();
        // Duplicate-heavy keys: payload order is the tie-break proof.
        assert_eq!(fk, bk);
        assert_eq!(fp, bp, "tie-broken payload order must survive failover");
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(ShardedSort::try_new(ShardedSortParams {
            merge_samples: 0,
            ..Default::default()
        })
        .is_err());
        assert!(ShardedSort::try_new(ShardedSortParams {
            sort: BucketSortParams { tile: 100, s: 10 },
            merge_samples: 8,
        })
        .is_err());
    }
}

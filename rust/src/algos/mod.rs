//! Sorting algorithms: the paper's GPU BUCKET SORT (Algorithm 1, one
//! module per step) and every baseline its evaluation compares against.
//!
//! All algorithms execute their data movement for real on the host while
//! recording the exact traffic a Tesla-architecture GPU would generate
//! into a [`crate::sim::Ledger`]; see [`crate::sim`] for the
//! hardware-substitution rationale.
//!
//! | module | paper artifact |
//! |---|---|
//! | [`bitonic`] | the network engine of Steps 2, 4 and 9 |
//! | [`local_sort`] | Steps 1–2 (split + per-SM shared-memory sort) |
//! | [`sampling`] | Steps 3 & 5 (equidistant local/global samples) |
//! | [`indexing`] | Step 6 (parallel binary search → bucket sizes) |
//! | [`prefix`] | Step 7 (column-major prefix sum, Figure 1) |
//! | [`relocation`] | Step 8 (coalesced bucket move) |
//! | [`bucket_sort`] | Algorithm 1 end-to-end |
//! | [`sharded`] | Algorithm 1 sharded across a multi-GPU pool (beyond the paper) |
//! | [`randomized`] | Leischner et al. randomized sample sort [9] |
//! | [`thrust_merge`] | Satish et al. Thrust Merge [14] |
//! | [`radix`] | Satish et al. integer radix sort [14] |

pub mod bitonic;
pub mod bucket_sort;
pub mod indexing;
pub mod local_sort;
pub mod prefix;
pub mod radix;
pub mod randomized;
pub mod relocation;
pub mod sampling;
pub mod sharded;
pub mod thrust_merge;

use crate::error::Result;
use crate::sim::spec::GpuSpec;
use crate::sim::GpuSim;
use crate::Key;

/// The algorithms the benchmark harness can run, as a CLI-friendly enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// GPU BUCKET SORT (deterministic sample sort, this paper).
    BucketSort,
    /// Randomized sample sort (Leischner et al. [9]).
    Randomized,
    /// Thrust Merge (Satish et al. [14]).
    ThrustMerge,
    /// Radix sort (Satish et al. [14], integer special case).
    Radix,
}

/// Object-safe adapter every baseline sorter implements: sort `keys`
/// on `sim` with default parameters and report the estimated
/// milliseconds on `spec`. One `dyn` dispatch replaces the four
/// copy-pasted match arms [`Algorithm::run`] used to carry.
trait AlgorithmRunner {
    fn sort_ms(&self, keys: &mut [Key], sim: &mut GpuSim, spec: &GpuSpec) -> Result<f64>;
}

impl AlgorithmRunner for bucket_sort::BucketSort {
    fn sort_ms(&self, keys: &mut [Key], sim: &mut GpuSim, spec: &GpuSpec) -> Result<f64> {
        Ok(self.sort(keys, sim)?.total_estimated_ms(spec))
    }
}

impl AlgorithmRunner for randomized::RandomizedSampleSort {
    fn sort_ms(&self, keys: &mut [Key], sim: &mut GpuSim, spec: &GpuSpec) -> Result<f64> {
        Ok(self.sort(keys, sim)?.total_estimated_ms(spec))
    }
}

impl AlgorithmRunner for thrust_merge::ThrustMergeSort {
    fn sort_ms(&self, keys: &mut [Key], sim: &mut GpuSim, spec: &GpuSpec) -> Result<f64> {
        Ok(self.sort(keys, sim)?.total_estimated_ms(spec))
    }
}

impl AlgorithmRunner for radix::RadixSort {
    fn sort_ms(&self, keys: &mut [Key], sim: &mut GpuSim, spec: &GpuSpec) -> Result<f64> {
        Ok(self.sort(keys, sim)?.total_estimated_ms(spec))
    }
}

impl Algorithm {
    /// All algorithms, bucket sort first.
    pub const ALL: [Algorithm; 4] = [
        Algorithm::BucketSort,
        Algorithm::Randomized,
        Algorithm::ThrustMerge,
        Algorithm::Radix,
    ];

    /// The canonical CLI/config name: what `--algo` help prints, what
    /// CSV output uses, and a guaranteed [`Algorithm::parse`] round
    /// trip — so help text and parse aliases cannot drift apart again.
    pub fn canonical_name(self) -> &'static str {
        match self {
            Algorithm::BucketSort => "bucket-sort",
            Algorithm::Randomized => "randomized",
            Algorithm::ThrustMerge => "thrust-merge",
            Algorithm::Radix => "radix",
        }
    }

    /// Parse a CLI name ([`Algorithm::canonical_name`]s always parse;
    /// historical aliases are kept).
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "bucketsort" | "bucket" | "gbs" | "deterministic" | "dss" => {
                Some(Algorithm::BucketSort)
            }
            "randomized" | "samplesort" | "rss" => Some(Algorithm::Randomized),
            "thrustmerge" | "thrust" | "merge" => Some(Algorithm::ThrustMerge),
            "radix" => Some(Algorithm::Radix),
            _ => None,
        }
    }

    /// The default-parameter sorter behind this algorithm, as a
    /// dyn-dispatch runner.
    fn runner(self) -> Box<dyn AlgorithmRunner> {
        match self {
            Algorithm::BucketSort => Box::new(bucket_sort::BucketSort::new(Default::default())),
            Algorithm::Randomized => {
                Box::new(randomized::RandomizedSampleSort::new(Default::default()))
            }
            Algorithm::ThrustMerge => {
                Box::new(thrust_merge::ThrustMergeSort::new(Default::default()))
            }
            Algorithm::Radix => Box::new(radix::RadixSort::new(Default::default())),
        }
    }

    /// Run this algorithm on `keys` over `sim` with default parameters,
    /// returning the estimated milliseconds on the sim's own spec.
    pub fn run(self, keys: &mut [Key], sim: &mut GpuSim) -> Result<f64> {
        let spec = sim.spec().clone();
        self.runner().sort_ms(keys, sim, &spec)
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Algorithm::BucketSort => "GPU Bucket Sort (deterministic)",
            Algorithm::Randomized => "Randomized Sample Sort [9]",
            Algorithm::ThrustMerge => "Thrust Merge [14]",
            Algorithm::Radix => "Radix Sort [14]",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::is_sorted_permutation;

    #[test]
    fn parse_algorithms() {
        assert_eq!(Algorithm::parse("gbs"), Some(Algorithm::BucketSort));
        assert_eq!(Algorithm::parse("dss"), Some(Algorithm::BucketSort));
        assert_eq!(Algorithm::parse("Bucket-Sort"), Some(Algorithm::BucketSort));
        assert_eq!(Algorithm::parse("rss"), Some(Algorithm::Randomized));
        assert_eq!(Algorithm::parse("thrust"), Some(Algorithm::ThrustMerge));
        assert_eq!(Algorithm::parse("radix"), Some(Algorithm::Radix));
        assert_eq!(Algorithm::parse("bogo"), None);
    }

    #[test]
    fn canonical_names_round_trip_through_parse() {
        // The anti-drift guarantee: help text built from
        // canonical_name() always names something parse() accepts.
        for alg in Algorithm::ALL {
            assert_eq!(Algorithm::parse(alg.canonical_name()), Some(alg), "{alg}");
        }
        let names: Vec<&str> = Algorithm::ALL.map(Algorithm::canonical_name).to_vec();
        assert_eq!(
            names,
            vec!["bucket-sort", "randomized", "thrust-merge", "radix"]
        );
    }

    #[test]
    fn all_algorithms_sort_correctly() {
        for alg in Algorithm::ALL {
            let input: Vec<Key> = (0..30_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let ms = alg.run(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&input, &keys), "{alg}");
            assert!(ms > 0.0, "{alg}");
        }
    }
}

//! Sorting algorithms: the paper's GPU BUCKET SORT (Algorithm 1, one
//! module per step) and every baseline its evaluation compares against.
//!
//! All algorithms execute their data movement for real on the host while
//! recording the exact traffic a Tesla-architecture GPU would generate
//! into a [`crate::sim::Ledger`]; see [`crate::sim`] for the
//! hardware-substitution rationale.
//!
//! | module | paper artifact |
//! |---|---|
//! | [`bitonic`] | the network engine of Steps 2, 4 and 9 |
//! | [`local_sort`] | Steps 1–2 (split + per-SM shared-memory sort) |
//! | [`sampling`] | Steps 3 & 5 (equidistant local/global samples) |
//! | [`indexing`] | Step 6 (parallel binary search → bucket sizes) |
//! | [`prefix`] | Step 7 (column-major prefix sum, Figure 1) |
//! | [`relocation`] | Step 8 (coalesced bucket move) |
//! | [`bucket_sort`] | Algorithm 1 end-to-end |
//! | [`plan`] | execution planner: wide-digit pass schedules for the executed kernels (beyond the paper) |
//! | [`adaptive`] | cost-model-driven kernel selection + sorted/reverse early exits (beyond the paper) |
//! | [`sharded`] | Algorithm 1 sharded across a multi-GPU pool (beyond the paper) |
//! | [`randomized`] | Leischner et al. randomized sample sort [9] |
//! | [`thrust_merge`] | Satish et al. Thrust Merge [14] |
//! | [`radix`] | Satish et al. integer radix sort [14] |

pub mod adaptive;
pub mod bitonic;
pub mod bucket_sort;
pub mod indexing;
pub mod local_sort;
pub mod plan;
pub mod prefix;
pub mod radix;
pub mod randomized;
pub mod relocation;
pub mod sampling;
pub mod sharded;
pub mod thrust_merge;

use crate::error::Result;
use crate::sim::spec::GpuSpec;
use crate::sim::GpuSim;
use crate::util::ScratchArena;
use crate::Key;

/// Which executed kernel sorts the shared-memory tiles (Step 2) and the
/// guaranteed-capacity buckets (Step 9) across the bucket-sort, sharded
/// and native engines.
///
/// Kernel choice affects **host execution only**: outputs are
/// byte-identical either way (a sorted key sequence is the unique
/// ordering of its bit-pattern multiset, and key–value records carry a
/// tie-breaking index that makes their order total), and the recorded
/// ledger keeps the paper's bitonic CE/traffic analytics regardless, so
/// Figures 3–7 and every analytic twin are unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelKind {
    /// The paper's comparison path: the bitonic network on the
    /// simulated engines (§4's choice), `slice::sort_unstable` — its
    /// host-optimal comparison equivalent — on the native engine.
    Bitonic,
    /// Planner-scheduled wide-digit LSD counting sort over
    /// [`crate::SortKey::radix_digit`] digits ([`plan::planned_sort`]):
    /// O(n·⌈W·8/digit_bits⌉) passes with constant digits elided, the
    /// executed default since PR 4 (byte-wise) / PR 5 (planned).
    Radix,
    /// Cost-model-driven selection per request ([`adaptive`]): profile
    /// the input, take the sorted/reverse early exit when it verifies,
    /// otherwise run whichever concrete kernel the model predicts
    /// cheaper. The default since PR 7. On the simulated tile/bucket
    /// paths it executes exactly as [`KernelKind::Radix`] (the
    /// front-end lives on whole-request boundaries, not inside tiles).
    #[default]
    Adaptive,
}

impl KernelKind {
    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "bitonic" | "comparison" => Some(KernelKind::Bitonic),
            "radix" | "lsd" => Some(KernelKind::Radix),
            "adaptive" | "auto" => Some(KernelKind::Adaptive),
            _ => None,
        }
    }

    /// Stable CLI/config name.
    pub fn id(&self) -> &'static str {
        match self {
            KernelKind::Bitonic => "bitonic",
            KernelKind::Radix => "radix",
            KernelKind::Adaptive => "adaptive",
        }
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// Execution resources for the host-executed hot path: the scratch
/// arena (warm buffer reuse), the parallelism budget for the resident
/// worker pool, the tile/bucket kernel selection, and the planner's
/// digit width.
///
/// Engines hold one `ExecContext` for their lifetime, which is what
/// makes their steady state allocation-free; the one-shot library entry
/// points ([`bucket_sort::BucketSort::sort`] etc.) build a transient
/// default context, preserving their historical behaviour. Cloning
/// shares the arena (it is a handle).
#[derive(Debug, Clone)]
pub struct ExecContext {
    /// Recyclable scratch buffers for every executed phase.
    pub arena: ScratchArena,
    /// Worker-pool parallelism budget (0 = logical cores).
    pub workers: usize,
    /// Executed tile/bucket kernel.
    pub kernel: KernelKind,
    /// Digit width of the planned radix kernel
    /// ([`plan::DEFAULT_DIGIT_BITS`] unless overridden via
    /// `config.digit_bits` / `--digit-bits`). Ignored by the bitonic
    /// kernel. Affects wall time only — outputs and ledgers are
    /// digit-width-invariant.
    pub digit_bits: u32,
    /// Cost coefficients the [`KernelKind::Adaptive`] front-end
    /// consults (built-in defaults unless overridden via
    /// `config.cost_model` / `--cost-model`). Ignored by the concrete
    /// kernels. Affects wall time only — every candidate path produces
    /// the identical bytes.
    pub cost: adaptive::CostModel,
    /// Fault injector compiled from `config.fault_plan` / `--fault-plan`
    /// (None — the default — costs one pointer check at each
    /// instrumented point). The sharded engine probes it per device
    /// step; injected faults surface as typed errors that drive the
    /// failover/retry machinery.
    pub faults: Option<std::sync::Arc<crate::sim::fault::FaultInjector>>,
}

impl Default for ExecContext {
    fn default() -> Self {
        ExecContext::new(KernelKind::default(), 0)
    }
}

impl ExecContext {
    /// Context with a fresh arena, the given kernel and worker budget,
    /// at the default planner digit width.
    pub fn new(kernel: KernelKind, workers: usize) -> Self {
        ExecContext {
            arena: ScratchArena::new(),
            workers,
            kernel,
            digit_bits: plan::DEFAULT_DIGIT_BITS,
            cost: adaptive::CostModel::default(),
            faults: None,
        }
    }

    /// Override the planner digit width (builder style).
    pub fn with_digit_bits(mut self, digit_bits: u32) -> Self {
        self.digit_bits = digit_bits;
        self
    }

    /// Override the adaptive cost model (builder style).
    pub fn with_cost_model(mut self, cost: adaptive::CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Attach a fault injector (builder style). `None` — the default —
    /// keeps every instrumented point free.
    pub fn with_faults(
        mut self,
        faults: Option<std::sync::Arc<crate::sim::fault::FaultInjector>>,
    ) -> Self {
        self.faults = faults;
        self
    }

    /// The resolved parallelism budget.
    pub fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::pool::default_workers()
        } else {
            self.workers
        }
    }
}

/// The algorithms the benchmark harness can run, as a CLI-friendly enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// GPU BUCKET SORT (deterministic sample sort, this paper).
    BucketSort,
    /// Randomized sample sort (Leischner et al. [9]).
    Randomized,
    /// Thrust Merge (Satish et al. [14]).
    ThrustMerge,
    /// Radix sort (Satish et al. [14], integer special case).
    Radix,
}

/// Object-safe adapter every baseline sorter implements: sort `keys`
/// on `sim` with default parameters and report the estimated
/// milliseconds on `spec`. One `dyn` dispatch replaces the four
/// copy-pasted match arms [`Algorithm::run`] used to carry. The
/// execution context reaches the bucket-sort arm (kernel selection,
/// arena); the baselines execute their own fixed kernels and ignore
/// it.
trait AlgorithmRunner {
    fn sort_ms(
        &self,
        keys: &mut [Key],
        sim: &mut GpuSim,
        spec: &GpuSpec,
        ctx: &ExecContext,
    ) -> Result<f64>;
}

impl AlgorithmRunner for bucket_sort::BucketSort {
    fn sort_ms(
        &self,
        keys: &mut [Key],
        sim: &mut GpuSim,
        spec: &GpuSpec,
        ctx: &ExecContext,
    ) -> Result<f64> {
        Ok(self.sort_in(keys, sim, ctx)?.total_estimated_ms(spec))
    }
}

impl AlgorithmRunner for randomized::RandomizedSampleSort {
    fn sort_ms(
        &self,
        keys: &mut [Key],
        sim: &mut GpuSim,
        spec: &GpuSpec,
        _ctx: &ExecContext,
    ) -> Result<f64> {
        Ok(self.sort(keys, sim)?.total_estimated_ms(spec))
    }
}

impl AlgorithmRunner for thrust_merge::ThrustMergeSort {
    fn sort_ms(
        &self,
        keys: &mut [Key],
        sim: &mut GpuSim,
        spec: &GpuSpec,
        _ctx: &ExecContext,
    ) -> Result<f64> {
        Ok(self.sort(keys, sim)?.total_estimated_ms(spec))
    }
}

impl AlgorithmRunner for radix::RadixSort {
    fn sort_ms(
        &self,
        keys: &mut [Key],
        sim: &mut GpuSim,
        spec: &GpuSpec,
        ctx: &ExecContext,
    ) -> Result<f64> {
        // The baseline takes its ping-pong scratch from the context's
        // arena like the executed kernels (no per-run temp vectors).
        Ok(self.sort_in(keys, sim, ctx)?.total_estimated_ms(spec))
    }
}

impl Algorithm {
    /// All algorithms, bucket sort first.
    pub const ALL: [Algorithm; 4] = [
        Algorithm::BucketSort,
        Algorithm::Randomized,
        Algorithm::ThrustMerge,
        Algorithm::Radix,
    ];

    /// The canonical CLI/config name: what `--algo` help prints, what
    /// CSV output uses, and a guaranteed [`Algorithm::parse`] round
    /// trip — so help text and parse aliases cannot drift apart again.
    pub fn canonical_name(self) -> &'static str {
        match self {
            Algorithm::BucketSort => "bucket-sort",
            Algorithm::Randomized => "randomized",
            Algorithm::ThrustMerge => "thrust-merge",
            Algorithm::Radix => "radix",
        }
    }

    /// Parse a CLI name ([`Algorithm::canonical_name`]s always parse;
    /// historical aliases are kept).
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "bucketsort" | "bucket" | "gbs" | "deterministic" | "dss" => {
                Some(Algorithm::BucketSort)
            }
            "randomized" | "samplesort" | "rss" => Some(Algorithm::Randomized),
            "thrustmerge" | "thrust" | "merge" => Some(Algorithm::ThrustMerge),
            "radix" => Some(Algorithm::Radix),
            _ => None,
        }
    }

    /// The default-parameter sorter behind this algorithm, as a
    /// dyn-dispatch runner.
    fn runner(self) -> Box<dyn AlgorithmRunner> {
        match self {
            Algorithm::BucketSort => Box::new(bucket_sort::BucketSort::new(Default::default())),
            Algorithm::Randomized => {
                Box::new(randomized::RandomizedSampleSort::new(Default::default()))
            }
            Algorithm::ThrustMerge => {
                Box::new(thrust_merge::ThrustMergeSort::new(Default::default()))
            }
            Algorithm::Radix => Box::new(radix::RadixSort::new(Default::default())),
        }
    }

    /// Run this algorithm on `keys` over `sim` with default parameters,
    /// returning the estimated milliseconds on the sim's own spec.
    pub fn run(self, keys: &mut [Key], sim: &mut GpuSim) -> Result<f64> {
        self.run_in(keys, sim, &ExecContext::default())
    }

    /// [`Algorithm::run`] with explicit execution resources — the
    /// bucket-sort arm honours the context's kernel and arena; the
    /// baselines execute their own fixed kernels regardless.
    pub fn run_in(self, keys: &mut [Key], sim: &mut GpuSim, ctx: &ExecContext) -> Result<f64> {
        let spec = sim.spec().clone();
        self.runner().sort_ms(keys, sim, &spec, ctx)
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Algorithm::BucketSort => "GPU Bucket Sort (deterministic)",
            Algorithm::Randomized => "Randomized Sample Sort [9]",
            Algorithm::ThrustMerge => "Thrust Merge [14]",
            Algorithm::Radix => "Radix Sort [14]",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;
    use crate::is_sorted_permutation;

    #[test]
    fn kernel_kind_parse_round_trips() {
        for k in [KernelKind::Bitonic, KernelKind::Radix, KernelKind::Adaptive] {
            assert_eq!(KernelKind::parse(k.id()), Some(k));
        }
        assert_eq!(KernelKind::parse("LSD"), Some(KernelKind::Radix));
        assert_eq!(KernelKind::parse("comparison"), Some(KernelKind::Bitonic));
        assert_eq!(KernelKind::parse("auto"), Some(KernelKind::Adaptive));
        assert_eq!(KernelKind::parse("quick"), None);
        assert_eq!(KernelKind::default(), KernelKind::Adaptive);
    }

    #[test]
    fn exec_context_resolves_workers() {
        let ctx = ExecContext::default();
        assert!(ctx.effective_workers() >= 1);
        assert_eq!(ctx.digit_bits, plan::DEFAULT_DIGIT_BITS);
        let fixed = ExecContext::new(KernelKind::Bitonic, 3).with_digit_bits(8);
        assert_eq!(fixed.effective_workers(), 3);
        assert_eq!(fixed.kernel, KernelKind::Bitonic);
        assert_eq!(fixed.digit_bits, 8);
    }

    #[test]
    fn parse_algorithms() {
        assert_eq!(Algorithm::parse("gbs"), Some(Algorithm::BucketSort));
        assert_eq!(Algorithm::parse("dss"), Some(Algorithm::BucketSort));
        assert_eq!(Algorithm::parse("Bucket-Sort"), Some(Algorithm::BucketSort));
        assert_eq!(Algorithm::parse("rss"), Some(Algorithm::Randomized));
        assert_eq!(Algorithm::parse("thrust"), Some(Algorithm::ThrustMerge));
        assert_eq!(Algorithm::parse("radix"), Some(Algorithm::Radix));
        assert_eq!(Algorithm::parse("bogo"), None);
    }

    #[test]
    fn canonical_names_round_trip_through_parse() {
        // The anti-drift guarantee: help text built from
        // canonical_name() always names something parse() accepts.
        for alg in Algorithm::ALL {
            assert_eq!(Algorithm::parse(alg.canonical_name()), Some(alg), "{alg}");
        }
        let names: Vec<&str> = Algorithm::ALL.map(Algorithm::canonical_name).to_vec();
        assert_eq!(
            names,
            vec!["bucket-sort", "randomized", "thrust-merge", "radix"]
        );
    }

    #[test]
    fn run_in_is_kernel_invariant() {
        let input: Vec<Key> = (0..20_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
        let mut a = input.clone();
        let mut sim_a = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let ms_a = Algorithm::BucketSort
            .run_in(&mut a, &mut sim_a, &ExecContext::new(KernelKind::Bitonic, 2))
            .unwrap();
        let mut b = input.clone();
        let mut sim_b = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let ms_b = Algorithm::BucketSort
            .run_in(&mut b, &mut sim_b, &ExecContext::new(KernelKind::Radix, 4))
            .unwrap();
        assert_eq!(a, b, "kernel choice must not change the bytes");
        assert!(
            (ms_a - ms_b).abs() < 1e-9,
            "estimate must not depend on kernel: {ms_a} vs {ms_b}"
        );
        let mut c = input.clone();
        let mut sim_c = GpuSim::new(GpuModel::Gtx285_2G.spec());
        let ms_c = Algorithm::BucketSort
            .run_in(&mut c, &mut sim_c, &ExecContext::new(KernelKind::Adaptive, 2))
            .unwrap();
        assert_eq!(a, c, "adaptive kernel must not change the bytes");
        assert!(
            (ms_a - ms_c).abs() < 1e-9,
            "estimate must not depend on the adaptive kernel: {ms_a} vs {ms_c}"
        );
    }

    #[test]
    fn all_algorithms_sort_correctly() {
        for alg in Algorithm::ALL {
            let input: Vec<Key> = (0..30_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
            let mut keys = input.clone();
            let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
            let ms = alg.run(&mut keys, &mut sim).unwrap();
            assert!(is_sorted_permutation(&input, &keys), "{alg}");
            assert!(ms > 0.0, "{alg}");
        }
    }
}

//! Step 7 of Algorithm 1: the prefix-sum over bucket sizes that assigns
//! every bucket A_ij its starting location l_ij in the final sequence.
//!
//! The required order is **column-major**: a_11, …, a_m1, a_12, …, a_m2,
//! …, a_1s, …, a_ms — all sublists' bucket-1 pieces first, then all
//! bucket-2 pieces, etc., so the relocated array becomes B_1 ∪ … ∪ B_s
//! with B_j = A_1j ∪ … ∪ A_mj.
//!
//! The paper implements it exactly as Figure 1 (three launches, all
//! coalesced):
//!   1. parallel **column sums** over the m×s matrix (all SMs),
//!   2. a prefix sum over the s column sums (one SM, shared memory),
//!   3. a parallel **update** adding each column's start to the running
//!      within-column prefix (all SMs).

use crate::sim::ledger::{KernelClass, Ledger};
use crate::sim::spec::MAX_BLOCK_THREADS;
use crate::KEY_BYTES;

/// The output of Step 7: per-bucket start locations plus the global
/// layout of the s sublists B_j.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketLayout {
    /// Row-major m×s matrix: `loc[i·s + j]` = start of bucket A_ij in
    /// the relocated array.
    pub loc: Vec<u64>,
    /// Start of sublist B_j in the relocated array (length s).
    pub bucket_start: Vec<u64>,
    /// |B_j| = Σ_i a_ij (length s).
    pub bucket_size: Vec<u64>,
}

impl BucketLayout {
    /// Total keys covered (Σ_j |B_j|).
    pub fn total(&self) -> u64 {
        self.bucket_size.iter().sum()
    }

    /// Largest bucket — the paper's guarantee is `max ≤ 2n/s` [15].
    pub fn max_bucket(&self) -> u64 {
        self.bucket_size.iter().copied().max().unwrap_or(0)
    }
}

/// Compute the column-major prefix layout from the row-major m×s bucket
/// size matrix `counts`.
pub fn column_prefix(counts: &[u32], m: usize, s: usize, ledger: &mut Ledger) -> BucketLayout {
    assert_eq!(counts.len(), m * s, "counts must be an m×s matrix");

    // Launch 1: column sums (parallel over columns on the GPU).
    let mut bucket_size = vec![0u64; s];
    for i in 0..m {
        for j in 0..s {
            bucket_size[j] += counts[i * s + j] as u64;
        }
    }

    // Launch 2: exclusive prefix over the s column sums (one SM).
    let mut bucket_start = vec![0u64; s];
    let mut acc = 0u64;
    for j in 0..s {
        bucket_start[j] = acc;
        acc += bucket_size[j];
    }

    // Launch 3: per-column update — within-column exclusive prefix plus
    // the column start.
    let mut loc = vec![0u64; m * s];
    for j in 0..s {
        let mut run = bucket_start[j];
        for i in 0..m {
            loc[i * s + j] = run;
            run += counts[i * s + j] as u64;
        }
    }

    record(m, s, ledger);
    BucketLayout {
        loc,
        bucket_start,
        bucket_size,
    }
}

/// Ledger-only twin of [`column_prefix`].
pub fn analytic(m: usize, s: usize, ledger: &mut Ledger) {
    record(m, s, ledger);
}

fn record(m: usize, s: usize, ledger: &mut Ledger) {
    let matrix_bytes = (m * s * KEY_BYTES) as u64;
    let col_bytes = (s * KEY_BYTES) as u64;
    let col_blocks = (s as u64).max(1);
    let threads = MAX_BLOCK_THREADS.min(m.max(1) as u32);

    // Launch 1: column sums — read matrix, write s sums.
    ledger.begin_kernel(KernelClass::PrefixSum, col_blocks, threads);
    ledger.tag_step(7);
    ledger.add_coalesced(matrix_bytes + col_bytes);
    ledger.add_compute((m * s) as u64);
    ledger.end_kernel();

    // Launch 2: prefix over column sums — one block in shared memory.
    ledger.begin_kernel(KernelClass::SingleBlock, 1, MAX_BLOCK_THREADS.min(s.max(1) as u32));
    ledger.tag_step(7);
    ledger.add_coalesced(2 * col_bytes);
    ledger.add_smem(2 * s as u64);
    ledger.add_compute(s as u64);
    ledger.end_kernel();

    // Launch 3: per-column update — read matrix + starts, write matrix.
    ledger.begin_kernel(KernelClass::PrefixSum, col_blocks, threads);
    ledger.tag_step(7);
    ledger.add_coalesced(2 * matrix_bytes + col_bytes);
    ledger.add_compute((m * s) as u64);
    ledger.end_kernel();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_layout_by_hand() {
        // m=2, s=3; rows: [1,2,3], [4,0,2].
        let counts = vec![1, 2, 3, 4, 0, 2];
        let l = column_prefix(&counts, 2, 3, &mut Ledger::default());
        assert_eq!(l.bucket_size, vec![5, 2, 5]);
        assert_eq!(l.bucket_start, vec![0, 5, 7]);
        // Column-major order: A_11 A_21 | A_12 A_22 | A_13 A_23.
        // Col 0 starts 0: A_11@0 (len 1), A_21@1 (len 4).
        // Col 1 starts 5: A_12@5 (len 2), A_22@7 (len 0).
        // Col 2 starts 7: A_13@7 (len 3), A_23@10 (len 2).
        assert_eq!(l.loc, vec![0, 5, 7, 1, 7, 10]);
        assert_eq!(l.total(), 12);
        assert_eq!(l.max_bucket(), 5);
    }

    #[test]
    fn locations_are_disjoint_and_cover() {
        // Property: sorting all (loc, count) pairs tiles [0, total).
        let m = 7;
        let s = 5;
        let counts: Vec<u32> = (0..m * s).map(|x| ((x * 13 + 5) % 9) as u32).collect();
        let l = column_prefix(&counts, m, s, &mut Ledger::default());
        let mut segs: Vec<(u64, u32)> = (0..m * s).map(|k| (l.loc[k], counts[k])).collect();
        segs.sort_unstable();
        let mut expect = 0u64;
        for (start, len) in segs {
            assert_eq!(start, expect);
            expect += len as u64;
        }
        assert_eq!(expect, counts.iter().map(|&c| c as u64).sum::<u64>());
    }

    #[test]
    fn column_major_ordering() {
        // All of bucket j comes before any of bucket j+1.
        let m = 4;
        let s = 3;
        let counts: Vec<u32> = vec![2; m * s];
        let l = column_prefix(&counts, m, s, &mut Ledger::default());
        for j in 0..s - 1 {
            let max_j = (0..m).map(|i| l.loc[i * s + j]).max().unwrap();
            let min_j1 = (0..m).map(|i| l.loc[i * s + j + 1]).min().unwrap();
            assert!(max_j < min_j1);
        }
    }

    #[test]
    fn three_launches_recorded() {
        let mut led = Ledger::default();
        analytic(16, 8, &mut led);
        assert_eq!(led.kernel_count(), 3);
        assert!(led.kernels().iter().all(|k| k.step == 7));
        assert_eq!(led.kernels()[1].blocks, 1); // the single-SM prefix
    }

    #[test]
    fn ledger_matches_analytic() {
        let counts = vec![1u32; 12];
        let mut a = Ledger::default();
        column_prefix(&counts, 4, 3, &mut a);
        let mut b = Ledger::default();
        analytic(4, 3, &mut b);
        assert_eq!(a, b);
    }
}

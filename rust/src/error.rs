//! Crate-wide error type.
//!
//! The library keeps a concrete enum (rather than `eyre::Report`) so that
//! callers — the coordinator in particular — can match on failure classes:
//! a simulated out-of-memory must be routed differently (reject the
//! request) than an artifact-loading failure (fall back to the native
//! engine).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure classes of the library.
#[derive(Debug)]
pub enum Error {
    /// The simulated device ran out of global memory — mirrors the memory
    /// ceilings of the paper's Figures 6 & 7 (e.g. Thrust Merge failing
    /// beyond 16M items).
    DeviceOom {
        /// Bytes requested by the failing allocation.
        requested: usize,
        /// Bytes still available on the device.
        available: usize,
        /// Human-readable device name (e.g. "GTX 285 (2 GB)").
        device: String,
    },
    /// Invalid algorithm parameters (e.g. sample count exceeding the tile
    /// size, non-power-of-two tile).
    InvalidParams(String),
    /// An input failed validation (e.g. the fixed-shape pipeline received
    /// a key equal to the padding sentinel).
    InvalidInput(String),
    /// PJRT / XLA runtime failure (artifact missing, compile error,
    /// execution error).
    Runtime(String),
    /// Artifact manifest problems (missing file, shape mismatch, bad
    /// JSON).
    Manifest(String),
    /// Coordinator-level failure (queue closed, request cancelled).
    Coordinator(String),
    /// Load-shed rejection: the bounded admission queue is full. Carried
    /// over the wire as a typed `Busy` error frame so remote clients can
    /// back off exactly like in-process ones (the message always names
    /// the backpressure cause).
    Busy(String),
    /// The request exceeds a hard size limit (protocol `max_request_keys`
    /// or a device memory ceiling surfaced at admission).
    TooLarge(String),
    /// A failure reported by a remote sort server over the wire, in a
    /// class that has no richer local representation (`code` is the wire
    /// error-code name).
    Remote {
        /// Stable wire error-code name (e.g. `"internal"`).
        code: String,
        /// Human-readable server-side message.
        message: String,
    },
    /// Configuration file problems.
    Config(String),
    /// Wrapped I/O error.
    Io(std::io::Error),
    /// A simulated device died mid-step (fault injection, or a real
    /// accelerator dropping off the bus). The sharded engine treats this
    /// as retryable: mark the device unhealthy and re-plan over the
    /// survivors.
    DeviceLost {
        /// Pool-local index of the lost device.
        device: usize,
        /// Human-readable device name (e.g. "GTX 285 (2 GB)").
        name: String,
    },
    /// A per-request deadline expired before the job ran to completion.
    /// Deadlines are attempt-counted at the scheduler, never inside
    /// kernels (the R4 lint keeps wall-clock out of `src/algos/`).
    Timeout(String),
    /// An internal invariant broke — most prominently a kernel job that
    /// panicked and was contained at the worker boundary. The request
    /// fails; the worker and every other in-flight request survive.
    Internal(String),
    /// The TCP connection died with requests still in flight. Carries the
    /// request ids that were pending so callers (and the auto-resubmit
    /// path) know exactly what was lost.
    ConnectionLost {
        /// Wire ids of the requests that were in flight on the dead
        /// connection.
        request_ids: Vec<u64>,
    },
}

/// Coarse failure taxonomy the scheduler's retry loop switches on.
///
/// `Retryable` failures are transient — a lost device, a contained panic,
/// a dropped socket — and re-executing the request is both safe (sorting
/// is deterministic, so a retry is byte-identical) and likely to succeed.
/// `Fatal` failures are properties of the request itself (invalid input,
/// too large, deadline already blown): retrying burns capacity without
/// changing the outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureClass {
    /// Transient: bounded retry with deterministic backoff is warranted.
    Retryable,
    /// Permanent for this request: fail fast with the typed error.
    Fatal,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::DeviceOom {
                requested,
                available,
                device,
            } => write!(
                f,
                "device OOM on {device}: requested {requested} B, {available} B available"
            ),
            Error::InvalidParams(m) => write!(f, "invalid parameters: {m}"),
            Error::InvalidInput(m) => write!(f, "invalid input: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Busy(m) => write!(f, "service busy: {m}"),
            Error::TooLarge(m) => write!(f, "request too large: {m}"),
            Error::Remote { code, message } => write!(f, "remote error [{code}]: {message}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::DeviceLost { device, name } => {
                write!(f, "device lost: {name} (device {device})")
            }
            Error::Timeout(m) => write!(f, "deadline exceeded: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
            Error::ConnectionLost { request_ids } => write!(
                f,
                "connection lost with {} request(s) in flight: {request_ids:?}",
                request_ids.len()
            ),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True when the failure is a (simulated or real) memory-capacity
    /// rejection — the coordinator uses this to classify request failures.
    pub fn is_oom(&self) -> bool {
        matches!(self, Error::DeviceOom { .. })
    }

    /// True when the failure is a backpressure load-shed — callers (and
    /// remote clients) should back off and retry rather than treat the
    /// request as permanently failed.
    pub fn is_busy(&self) -> bool {
        matches!(self, Error::Busy(_))
    }

    /// Classify this failure for the scheduler's retry loop.
    ///
    /// Retryable: transient infrastructure faults where re-executing the
    /// deterministic sort is safe and useful (`DeviceLost`, contained
    /// `Internal` panics, `Io`/`ConnectionLost` transport drops, `Busy`
    /// backpressure). Everything else — bad input, capacity ceilings,
    /// expired deadlines, config errors — is a property of the request or
    /// the deployment and stays `Fatal`.
    pub fn failure_class(&self) -> FailureClass {
        match self {
            Error::DeviceLost { .. }
            | Error::Internal(_)
            | Error::Busy(_)
            | Error::Io(_)
            | Error::ConnectionLost { .. } => FailureClass::Retryable,
            _ => FailureClass::Fatal,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::DeviceOom {
            requested: 100,
            available: 10,
            device: "GTX 260".into(),
        };
        let s = e.to_string();
        assert!(s.contains("GTX 260"));
        assert!(s.contains("100"));
        assert!(e.is_oom());
        assert!(!Error::InvalidParams("x".into()).is_oom());
    }

    #[test]
    fn busy_and_remote_classes() {
        let busy = Error::Busy("queue full (8 requests) — backpressure".into());
        assert!(busy.is_busy());
        assert!(busy.to_string().contains("backpressure"));
        assert!(!Error::Coordinator("x".into()).is_busy());
        let big = Error::TooLarge("10 > 5 keys".into());
        assert!(big.to_string().contains("too large"));
        let remote = Error::Remote {
            code: "internal".into(),
            message: "engine exploded".into(),
        };
        assert!(remote.to_string().contains("internal"));
        assert!(remote.to_string().contains("engine exploded"));
    }

    #[test]
    fn failure_classes_partition_the_enum() {
        let lost = Error::DeviceLost {
            device: 2,
            name: "GTX 285 (2 GB)".into(),
        };
        assert_eq!(lost.failure_class(), FailureClass::Retryable);
        assert!(lost.to_string().contains("GTX 285"));
        assert!(lost.to_string().contains("device 2"));

        let conn = Error::ConnectionLost {
            request_ids: vec![7, 9],
        };
        assert_eq!(conn.failure_class(), FailureClass::Retryable);
        assert!(conn.to_string().contains("2 request(s)"));
        assert!(conn.to_string().contains('7'));

        assert_eq!(
            Error::Internal("kernel job panicked".into()).failure_class(),
            FailureClass::Retryable
        );
        assert_eq!(
            Error::Busy("queue full".into()).failure_class(),
            FailureClass::Retryable
        );

        // Fatal: request-shaped failures where a retry changes nothing.
        for fatal in [
            Error::Timeout("2 ms deadline".into()),
            Error::InvalidInput("sentinel".into()),
            Error::TooLarge("10 > 5".into()),
            Error::DeviceOom {
                requested: 1,
                available: 0,
                device: "GTX 260".into(),
            },
            Error::Config("bad".into()),
        ] {
            assert_eq!(fatal.failure_class(), FailureClass::Fatal, "{fatal}");
        }
        assert!(Error::Timeout("2 ms".into())
            .to_string()
            .contains("deadline exceeded"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("nope"));
    }
}

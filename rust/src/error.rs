//! Crate-wide error type.
//!
//! The library keeps a concrete enum (rather than `eyre::Report`) so that
//! callers — the coordinator in particular — can match on failure classes:
//! a simulated out-of-memory must be routed differently (reject the
//! request) than an artifact-loading failure (fall back to the native
//! engine).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure classes of the library.
#[derive(Debug)]
pub enum Error {
    /// The simulated device ran out of global memory — mirrors the memory
    /// ceilings of the paper's Figures 6 & 7 (e.g. Thrust Merge failing
    /// beyond 16M items).
    DeviceOom {
        /// Bytes requested by the failing allocation.
        requested: usize,
        /// Bytes still available on the device.
        available: usize,
        /// Human-readable device name (e.g. "GTX 285 (2 GB)").
        device: String,
    },
    /// Invalid algorithm parameters (e.g. sample count exceeding the tile
    /// size, non-power-of-two tile).
    InvalidParams(String),
    /// An input failed validation (e.g. the fixed-shape pipeline received
    /// a key equal to the padding sentinel).
    InvalidInput(String),
    /// PJRT / XLA runtime failure (artifact missing, compile error,
    /// execution error).
    Runtime(String),
    /// Artifact manifest problems (missing file, shape mismatch, bad
    /// JSON).
    Manifest(String),
    /// Coordinator-level failure (queue closed, request cancelled,
    /// backpressure rejection).
    Coordinator(String),
    /// Configuration file problems.
    Config(String),
    /// Wrapped I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::DeviceOom {
                requested,
                available,
                device,
            } => write!(
                f,
                "device OOM on {device}: requested {requested} B, {available} B available"
            ),
            Error::InvalidParams(m) => write!(f, "invalid parameters: {m}"),
            Error::InvalidInput(m) => write!(f, "invalid input: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True when the failure is a (simulated or real) memory-capacity
    /// rejection — the coordinator uses this to classify request failures.
    pub fn is_oom(&self) -> bool {
        matches!(self, Error::DeviceOom { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::DeviceOom {
            requested: 100,
            available: 10,
            device: "GTX 260".into(),
        };
        let s = e.to_string();
        assert!(s.contains("GTX 260"));
        assert!(s.contains("100"));
        assert!(e.is_oom());
        assert!(!Error::InvalidParams("x".into()).is_oom());
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("nope"));
    }
}

//! Typed sort keys — the comparison-based surface of the paper, made
//! explicit.
//!
//! Deterministic sample sort is *comparison-based*: unlike the radix
//! baseline, nothing in Algorithm 1 depends on keys being 32-bit
//! unsigned integers. This module carries that property into the API:
//!
//! * [`SortKey`] — an order-preserving bijection between a key type and
//!   its unsigned bit pattern, with per-type width and padding sentinel.
//!   Implemented for `u32`, `u64`, `i32`, `i64` and `f32` (IEEE-754
//!   total order, NaN-safe).
//! * [`Record`] — a key plus a 32-bit payload slot index. `Record<K>`
//!   itself implements [`SortKey`], which is how the key–value path
//!   works: Steps 2–9 of Algorithm 1 run unchanged over records, the
//!   rank/relocation machinery (Steps 6–8) carries the payload index
//!   alongside the key, and the caller permutes the payload array by
//!   the surviving indices afterwards.
//! * [`KeyType`] / [`KeyData`] — the runtime (request-level) twins of
//!   the compile-time trait, used by the service request path and the
//!   CLI where the key type is chosen by the client, not the program.
//!
//! Every sorting routine in this crate orders keys by
//! [`SortKey::to_bits`]. Because the bijection is order-preserving,
//! sorting bit patterns *is* sorting keys — and the bit domain gives a
//! total order even where the source type has none (`f32`: `-NaN <
//! -inf < … < -0.0 < +0.0 < … < +inf < +NaN`).

use std::cmp::Ordering;

/// An order-preserving bijection between a key type and unsigned bits.
///
/// Laws (checked by `rust/tests/prop_sortkey.rs`):
/// * **round-trip**: `from_bits(to_bits(k))` is bit-identical to `k`
///   (for `f32`, NaN payloads and `-0.0` survive);
/// * **order preservation**: `a` sorts before `b` iff
///   `a.to_bits() < b.to_bits()`;
/// * **sentinel maximality**: `PAD.to_bits()` is the maximum of the bit
///   domain, so padding always sorts last.
///
/// # The padding sentinel and the fixed-shape (XLA) pipeline
///
/// [`SortKey::PAD`] is the key whose bit pattern is the domain maximum.
/// The native and simulated pipelines use it only for *internal*
/// padding (tile alignment, power-of-two bitonic networks), where real
/// keys equal to `PAD` are harmless — padding is sliced off by position,
/// not by value.
///
/// The **fixed-shape AOT (XLA/PJRT) pipeline is stricter**: it pads
/// inputs up to a compiled capacity with `PAD` and truncates after the
/// sort, so an *input* containing `PAD` is indistinguishable from
/// padding and is rejected up front (`u32::MAX` for the classic `u32`
/// artifacts). This restriction is a property of the fixed-shape
/// execution model, not of the algorithm; it lives here, at the trait,
/// so every key type documents its own reserved value
/// (`<K as SortKey>::PAD`).
pub trait SortKey: Copy + Send + Sync + PartialEq + std::fmt::Debug + 'static {
    /// The unsigned bit-pattern type the key maps onto. Only `Ord` is
    /// required — tuples work, which is what lets [`Record`] reuse the
    /// whole machinery.
    type Bits: Copy + Ord + Send + Sync + std::fmt::Debug;

    /// Bytes one key occupies on the (simulated) device. The ledger's
    /// traffic and memory accounting scales with this — a `u64` sort
    /// moves twice the bytes of a `u32` sort of the same length.
    const WIDTH_BYTES: usize;

    /// The padding sentinel: the key whose bits are the domain maximum
    /// (sorts after every other key). See the trait docs for the
    /// fixed-shape pipeline's reservation of this value.
    const PAD: Self;

    /// The order-preserving map to bits.
    fn to_bits(self) -> Self::Bits;

    /// Inverse of [`SortKey::to_bits`].
    fn from_bits(bits: Self::Bits) -> Self;

    /// Build a key from a raw `u64` draw: the low `WIDTH_BYTES · 8`
    /// bits are taken as a position in the total order (workload
    /// generators use this so one distribution definition covers every
    /// key type).
    fn from_raw_bits(raw: u64) -> Self;

    /// The `i`-th least-significant byte of the element's ordered bit
    /// pattern (`0 ≤ i < WIDTH_BYTES`) — the digit stream of the
    /// executed LSD counting kernel
    /// ([`crate::algos::radix::radix_tile_sort`]). Stable LSD passes
    /// over these bytes reproduce exactly the [`SortKey::to_bits`]
    /// total order: byte `WIDTH_BYTES - 1` is the most significant
    /// comparison position (for [`Record`], the payload index occupies
    /// the low four bytes, so records order by key first, index
    /// second).
    fn radix_byte(self, i: usize) -> u8;

    /// The digit of `bits` width at `bit_offset` within the ordered bit
    /// pattern — the wide-digit generalization of [`SortKey::radix_byte`]
    /// used by the execution planner's LSD passes
    /// ([`crate::algos::plan`]). `bit_offset + bits` may extend past the
    /// key's width; the missing high bits read as zero. The default
    /// assembles the digit from at most three `radix_byte` calls, which
    /// is what lets composite keys ([`Record`], [`Segmented`]) join the
    /// planned passes without their own bit plumbing; the primitive
    /// impls override it with a single shift.
    #[inline]
    fn radix_digit(self, bit_offset: u32, bits: u32) -> usize {
        debug_assert!(bits >= 1 && bits <= 16);
        let first = bit_offset as usize / 8;
        let mut v: u64 = 0;
        let mut byte = first;
        while byte < Self::WIDTH_BYTES && 8 * byte < (bit_offset + bits) as usize {
            v |= (self.radix_byte(byte) as u64) << (8 * (byte - first));
            byte += 1;
        }
        ((v >> (bit_offset % 8)) & ((1u64 << bits) - 1)) as usize
    }

    /// Total-order comparison (by bits).
    #[inline]
    fn key_cmp(&self, other: &Self) -> Ordering {
        self.to_bits().cmp(&other.to_bits())
    }

    /// `self <= other` under the total order.
    #[inline]
    fn key_le(&self, other: &Self) -> bool {
        self.to_bits() <= other.to_bits()
    }

    /// `self < other` under the total order.
    #[inline]
    fn key_lt(&self, other: &Self) -> bool {
        self.to_bits() < other.to_bits()
    }
}

impl SortKey for u32 {
    type Bits = u32;
    const WIDTH_BYTES: usize = 4;
    const PAD: Self = u32::MAX;

    #[inline]
    fn to_bits(self) -> u32 {
        self
    }

    #[inline]
    fn from_bits(bits: u32) -> Self {
        bits
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        raw as u32
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        (self >> (8 * i)) as u8
    }

    #[inline]
    fn radix_digit(self, bit_offset: u32, bits: u32) -> usize {
        ((self as u64 >> bit_offset) & ((1u64 << bits) - 1)) as usize
    }
}

impl SortKey for u64 {
    type Bits = u64;
    const WIDTH_BYTES: usize = 8;
    const PAD: Self = u64::MAX;

    #[inline]
    fn to_bits(self) -> u64 {
        self
    }

    #[inline]
    fn from_bits(bits: u64) -> Self {
        bits
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        raw
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        (self >> (8 * i)) as u8
    }

    #[inline]
    fn radix_digit(self, bit_offset: u32, bits: u32) -> usize {
        ((self >> bit_offset) & ((1u64 << bits) - 1)) as usize
    }
}

impl SortKey for i32 {
    type Bits = u32;
    const WIDTH_BYTES: usize = 4;
    const PAD: Self = i32::MAX;

    // Flipping the sign bit shifts the two's-complement number line so
    // i32::MIN ↦ 0 and i32::MAX ↦ u32::MAX.
    #[inline]
    fn to_bits(self) -> u32 {
        (self as u32) ^ 0x8000_0000
    }

    #[inline]
    fn from_bits(bits: u32) -> Self {
        (bits ^ 0x8000_0000) as i32
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        Self::from_bits(raw as u32)
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        (SortKey::to_bits(self) >> (8 * i)) as u8
    }

    #[inline]
    fn radix_digit(self, bit_offset: u32, bits: u32) -> usize {
        ((SortKey::to_bits(self) as u64 >> bit_offset) & ((1u64 << bits) - 1)) as usize
    }
}

impl SortKey for i64 {
    type Bits = u64;
    const WIDTH_BYTES: usize = 8;
    const PAD: Self = i64::MAX;

    #[inline]
    fn to_bits(self) -> u64 {
        (self as u64) ^ (1u64 << 63)
    }

    #[inline]
    fn from_bits(bits: u64) -> Self {
        (bits ^ (1u64 << 63)) as i64
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        Self::from_bits(raw)
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        (SortKey::to_bits(self) >> (8 * i)) as u8
    }

    #[inline]
    fn radix_digit(self, bit_offset: u32, bits: u32) -> usize {
        ((SortKey::to_bits(self) >> bit_offset) & ((1u64 << bits) - 1)) as usize
    }
}

impl SortKey for f32 {
    type Bits = u32;
    const WIDTH_BYTES: usize = 4;
    // from_bits(u32::MAX): the NaN with all-ones payload — the maximum
    // of the IEEE-754 total order. (`f32::from_bits` is not a const fn
    // on the MSRV, hence the transmute; the two are defined to agree.)
    #[allow(clippy::transmute_int_to_float)]
    // SAFETY: `u32` and `f32` have identical size and alignment, and
    // every u32 bit pattern is a valid f32 (0x7FFF_FFFF is a quiet
    // NaN); this is exactly `f32::from_bits`, just usable in `const`.
    const PAD: Self = unsafe { std::mem::transmute::<u32, f32>(0x7FFF_FFFF) };

    // The classic IEEE-754 total-order trick: non-negative floats get
    // the sign bit set (shifting them above all negatives), negative
    // floats are bitwise complemented (reversing their magnitude
    // order). NaNs land at both extremes by sign, beyond the
    // infinities.
    #[inline]
    fn to_bits(self) -> u32 {
        let b = f32::to_bits(self);
        if b & 0x8000_0000 != 0 {
            !b
        } else {
            b | 0x8000_0000
        }
    }

    #[inline]
    fn from_bits(bits: u32) -> Self {
        f32::from_bits(if bits & 0x8000_0000 != 0 {
            bits ^ 0x8000_0000
        } else {
            !bits
        })
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        // NB: must name the trait — a bare `Self::from_bits` would
        // resolve to the *inherent* `f32::from_bits` (raw IEEE
        // reinterpret), which is not the order-preserving decode.
        <Self as SortKey>::from_bits(raw as u32)
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        // Same trait-vs-inherent shadowing as above: the digits must
        // come from the order-preserving bits.
        (SortKey::to_bits(self) >> (8 * i)) as u8
    }

    #[inline]
    fn radix_digit(self, bit_offset: u32, bits: u32) -> usize {
        ((SortKey::to_bits(self) as u64 >> bit_offset) & ((1u64 << bits) - 1)) as usize
    }
}

/// A key paired with a 32-bit payload slot index — the key–value record
/// of the rank/relocation path.
///
/// `Record<K>` implements [`SortKey`] with bits `(key bits, index)`, so
/// the full Algorithm-1 pipeline (and the native PSRS engine) runs over
/// records unchanged: every comparison, splitter search and relocation
/// carries the index along, and ties between equal keys break by
/// original position. Two consequences:
///
/// * the record order is **total** (no ties at all), so key–value sorts
///   are effectively *stable* and byte-deterministic for any worker
///   count and any engine;
/// * the index acts as the tie-breaking discipline that keeps the
///   deterministic bucket-size bound meaningful even for
///   duplicate-heavy inputs.
///
/// The 32-bit index bounds one key–value job at `u32::MAX` records —
/// far above any simulated device's ceiling (512M keys).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record<K> {
    /// The sort key.
    pub key: K,
    /// Index of this record's payload slot in the caller's value array.
    pub idx: u32,
}

impl<K: SortKey> SortKey for Record<K> {
    type Bits = (K::Bits, u32);
    const WIDTH_BYTES: usize = K::WIDTH_BYTES + 4;
    const PAD: Self = Record {
        key: K::PAD,
        idx: u32::MAX,
    };

    #[inline]
    fn to_bits(self) -> Self::Bits {
        (self.key.to_bits(), self.idx)
    }

    #[inline]
    fn from_bits(bits: Self::Bits) -> Self {
        Record {
            key: K::from_bits(bits.0),
            idx: bits.1,
        }
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        Record {
            key: K::from_raw_bits(raw),
            idx: 0,
        }
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        // Low four bytes: the tie-breaking payload index; above them,
        // the key's own digits — so LSD passes order by key first.
        if i < 4 {
            (self.idx >> (8 * i)) as u8
        } else {
            self.key.radix_byte(i - 4)
        }
    }
}

/// A key tagged with the request segment it belongs to — the carrier of
/// **coalesced dispatch** ([`crate::coordinator::coalesce`]).
///
/// `Segmented<K>` orders by `(segment, key bits)`: the segment id is the
/// *most* significant comparison position, so sorting the concatenation
/// of many small requests yields every request's keys sorted and
/// contiguous, in submission order — one kernel invocation over the
/// whole batch, split back into per-request responses that are
/// byte-identical to sorting each request alone (the sorted sequence of
/// a request's key multiset is unique).
///
/// It composes with [`Record`] the obvious way:
/// `Record<Segmented<K>>` orders by `(segment, key, index)`, which is
/// exactly the per-request stable key–value order, so coalesced
/// key–value batches stay stable per request too.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segmented<K> {
    /// Index of the request this key belongs to within its batch group.
    pub seg: u32,
    /// The request's own sort key.
    pub key: K,
}

impl<K: SortKey> SortKey for Segmented<K> {
    type Bits = (u32, K::Bits);
    const WIDTH_BYTES: usize = K::WIDTH_BYTES + 4;
    const PAD: Self = Segmented {
        seg: u32::MAX,
        key: K::PAD,
    };

    #[inline]
    fn to_bits(self) -> Self::Bits {
        (self.seg, self.key.to_bits())
    }

    #[inline]
    fn from_bits(bits: Self::Bits) -> Self {
        Segmented {
            seg: bits.0,
            key: K::from_bits(bits.1),
        }
    }

    #[inline]
    fn from_raw_bits(raw: u64) -> Self {
        Segmented {
            seg: 0,
            key: K::from_raw_bits(raw),
        }
    }

    #[inline]
    fn radix_byte(self, i: usize) -> u8 {
        // Low bytes: the key's own digits; above them, the segment id —
        // so LSD passes order within segments first, then by segment.
        if i < K::WIDTH_BYTES {
            self.key.radix_byte(i)
        } else {
            (self.seg >> (8 * (i - K::WIDTH_BYTES))) as u8
        }
    }
}

/// Compile-time ↔ runtime bridge for the [`KeyData`] variants: lets
/// generic code take a typed vector out of (and wrap one back into) the
/// request-level carrier. Implemented exactly for the [`KeyType`] set;
/// the coalescer uses it to run one generic composition over whichever
/// key type a request group holds.
pub trait TypedKeys: SortKey + Sized {
    /// The runtime tag of this key type.
    const KEY_TYPE: KeyType;

    /// Take the typed vector out of `data`, if it holds this type.
    fn from_key_data(data: KeyData) -> Option<Vec<Self>>;

    /// Wrap a typed vector back into the runtime carrier.
    fn into_key_data(v: Vec<Self>) -> KeyData;
}

macro_rules! impl_typed_keys {
    ($($ty:ty => $variant:ident),* $(,)?) => {
        $(impl TypedKeys for $ty {
            const KEY_TYPE: KeyType = KeyType::$variant;

            fn from_key_data(data: KeyData) -> Option<Vec<Self>> {
                match data {
                    KeyData::$variant(v) => Some(v),
                    _ => None,
                }
            }

            fn into_key_data(v: Vec<Self>) -> KeyData {
                KeyData::$variant(v)
            }
        })*
    };
}

impl_typed_keys! {
    u32 => U32,
    u64 => U64,
    i32 => I32,
    i64 => I64,
    f32 => F32,
}

/// The 32-bit record-index cap shared by every key–value entry point.
fn check_record_cap(keys_len: usize) -> crate::error::Result<()> {
    if keys_len as u64 > u32::MAX as u64 {
        return Err(crate::error::Error::InvalidInput(format!(
            "key–value jobs are limited to {} records, got {keys_len}",
            u32::MAX,
        )));
    }
    Ok(())
}

/// Validate a key–value job's shape — the single definition every
/// entry point (request validation and the engines' `sort_pairs`)
/// shares: the payload pairs one-to-one with the keys, and the job
/// fits the 32-bit record index space (see [`Record`]).
pub fn validate_key_value(keys_len: usize, payload_len: usize) -> crate::error::Result<()> {
    if payload_len != keys_len {
        return Err(crate::error::Error::InvalidInput(format!(
            "payload length {payload_len} does not match key count {keys_len}"
        )));
    }
    check_record_cap(keys_len)
}

/// Attach payload slot indices `0..keys.len()` to a key slice.
///
/// Errors if the job exceeds the 32-bit index space (see [`Record`]).
pub fn tag_records<K: SortKey>(keys: &[K]) -> crate::error::Result<Vec<Record<K>>> {
    let mut out = Vec::new();
    tag_records_into(keys, &mut out)?;
    Ok(out)
}

/// [`tag_records`] into a caller-provided (typically arena-recycled)
/// buffer, so steady-state key–value jobs allocate nothing.
pub fn tag_records_into<K: SortKey>(
    keys: &[K],
    out: &mut Vec<Record<K>>,
) -> crate::error::Result<()> {
    check_record_cap(keys.len())?;
    out.clear();
    out.reserve(keys.len());
    out.extend(keys.iter().zip(0u32..).map(|(&key, idx)| Record { key, idx }));
    Ok(())
}

/// Write sorted records back: keys in record order, payload permuted by
/// the surviving indices.
pub fn untag_records<K: SortKey>(recs: &[Record<K>], keys: &mut [K], payload: &mut Vec<u64>) {
    untag_records_in(recs, keys, payload, &crate::util::ScratchArena::new());
}

/// [`untag_records`] with the permutation staged through an arena
/// buffer instead of a fresh allocation.
pub fn untag_records_in<K: SortKey>(
    recs: &[Record<K>],
    keys: &mut [K],
    payload: &mut Vec<u64>,
    arena: &crate::util::ScratchArena,
) {
    debug_assert_eq!(recs.len(), keys.len());
    debug_assert_eq!(recs.len(), payload.len());
    let mut permuted = arena.take_empty::<u64>();
    permuted.extend(recs.iter().map(|r| payload[r.idx as usize]));
    for (k, r) in keys.iter_mut().zip(recs) {
        *k = r.key;
    }
    payload.copy_from_slice(&permuted);
}

/// The key types a client can request — the runtime twin of the
/// [`SortKey`] impl set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KeyType {
    /// 32-bit unsigned — the paper's key type and the classic path.
    U32,
    /// 64-bit unsigned.
    U64,
    /// 32-bit signed.
    I32,
    /// 64-bit signed.
    I64,
    /// IEEE-754 single precision, sorted by total order (NaN-safe).
    F32,
}

impl KeyType {
    /// Every supported key type, classic `u32` first.
    pub const ALL: [KeyType; 5] = [
        KeyType::U32,
        KeyType::U64,
        KeyType::I32,
        KeyType::I64,
        KeyType::F32,
    ];

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<KeyType> {
        match s.to_ascii_lowercase().as_str() {
            "u32" | "uint32" => Some(KeyType::U32),
            "u64" | "uint64" => Some(KeyType::U64),
            "i32" | "int32" => Some(KeyType::I32),
            "i64" | "int64" => Some(KeyType::I64),
            "f32" | "float32" | "float" => Some(KeyType::F32),
            _ => None,
        }
    }

    /// Stable identifier (CLI/CSV/JSON).
    pub fn id(&self) -> &'static str {
        match self {
            KeyType::U32 => "u32",
            KeyType::U64 => "u64",
            KeyType::I32 => "i32",
            KeyType::I64 => "i64",
            KeyType::F32 => "f32",
        }
    }

    /// Bytes per key of this type.
    pub fn width_bytes(&self) -> usize {
        match self {
            KeyType::U32 => <u32 as SortKey>::WIDTH_BYTES,
            KeyType::U64 => <u64 as SortKey>::WIDTH_BYTES,
            KeyType::I32 => <i32 as SortKey>::WIDTH_BYTES,
            KeyType::I64 => <i64 as SortKey>::WIDTH_BYTES,
            KeyType::F32 => <f32 as SortKey>::WIDTH_BYTES,
        }
    }
}

impl std::fmt::Display for KeyType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// A typed key vector — the request-level carrier that erases the
/// [`SortKey`] type parameter at the service boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum KeyData {
    /// `u32` keys (the classic path; byte-identical to the pre-typed
    /// API).
    U32(Vec<u32>),
    /// `u64` keys.
    U64(Vec<u64>),
    /// `i32` keys.
    I32(Vec<i32>),
    /// `i64` keys.
    I64(Vec<i64>),
    /// `f32` keys (total order; may contain NaNs).
    F32(Vec<f32>),
}

impl Default for KeyData {
    fn default() -> Self {
        KeyData::U32(Vec::new())
    }
}

/// Dispatch a generic expression over the concrete vector inside a
/// [`KeyData`] (mutable borrow). Each arm monomorphizes `$body` at the
/// arm's key type, so `$body` may call functions generic over
/// [`SortKey`].
macro_rules! for_each_key_vec_mut {
    ($data:expr, $v:ident => $body:expr) => {
        match $data {
            $crate::key::KeyData::U32(ref mut $v) => $body,
            $crate::key::KeyData::U64(ref mut $v) => $body,
            $crate::key::KeyData::I32(ref mut $v) => $body,
            $crate::key::KeyData::I64(ref mut $v) => $body,
            $crate::key::KeyData::F32(ref mut $v) => $body,
        }
    };
}
pub(crate) use for_each_key_vec_mut;

/// Immutable twin of [`for_each_key_vec_mut`].
macro_rules! for_each_key_vec {
    ($data:expr, $v:ident => $body:expr) => {
        match $data {
            $crate::key::KeyData::U32(ref $v) => $body,
            $crate::key::KeyData::U64(ref $v) => $body,
            $crate::key::KeyData::I32(ref $v) => $body,
            $crate::key::KeyData::I64(ref $v) => $body,
            $crate::key::KeyData::F32(ref $v) => $body,
        }
    };
}
pub(crate) use for_each_key_vec;

impl KeyData {
    /// The runtime key type tag.
    pub fn key_type(&self) -> KeyType {
        match self {
            KeyData::U32(_) => KeyType::U32,
            KeyData::U64(_) => KeyType::U64,
            KeyData::I32(_) => KeyType::I32,
            KeyData::I64(_) => KeyType::I64,
            KeyData::F32(_) => KeyType::F32,
        }
    }

    /// Number of keys.
    pub fn len(&self) -> usize {
        for_each_key_vec!(self, v => v.len())
    }

    /// True when there are no keys.
    pub fn is_empty(&self) -> bool {
        for_each_key_vec!(self, v => v.is_empty())
    }

    /// Bytes per key.
    pub fn width_bytes(&self) -> usize {
        self.key_type().width_bytes()
    }

    /// Total key bytes (`len · width`).
    pub fn total_bytes(&self) -> usize {
        self.len() * self.width_bytes()
    }

    /// Reverse the keys in place (ascending ↔ descending).
    pub fn reverse(&mut self) {
        for_each_key_vec_mut!(self, v => v.reverse());
    }

    /// True when the keys are sorted under the total order, in the
    /// given direction.
    pub fn is_sorted(&self, descending: bool) -> bool {
        fn check<K: SortKey>(v: &[K], descending: bool) -> bool {
            if descending {
                v.windows(2).all(|w| w[1].key_le(&w[0]))
            } else {
                v.windows(2).all(|w| w[0].key_le(&w[1]))
            }
        }
        for_each_key_vec!(self, v => check(v, descending))
    }

    /// Borrow the classic `u32` key vector, if that is the type held.
    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            KeyData::U32(v) => Some(v),
            _ => None,
        }
    }

    /// Take ownership of the classic `u32` key vector, if held.
    pub fn into_u32(self) -> Option<Vec<u32>> {
        match self {
            KeyData::U32(v) => Some(v),
            _ => None,
        }
    }
}

impl From<Vec<u32>> for KeyData {
    fn from(v: Vec<u32>) -> Self {
        KeyData::U32(v)
    }
}

impl From<Vec<u64>> for KeyData {
    fn from(v: Vec<u64>) -> Self {
        KeyData::U64(v)
    }
}

impl From<Vec<i32>> for KeyData {
    fn from(v: Vec<i32>) -> Self {
        KeyData::I32(v)
    }
}

impl From<Vec<i64>> for KeyData {
    fn from(v: Vec<i64>) -> Self {
        KeyData::I64(v)
    }
}

impl From<Vec<f32>> for KeyData {
    fn from(v: Vec<f32>) -> Self {
        KeyData::F32(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u32_bits_are_identity() {
        for x in [0u32, 1, 0xDEAD_BEEF, u32::MAX] {
            assert_eq!(x.to_bits(), x);
            assert_eq!(u32::from_bits(x), x);
        }
        assert_eq!(<u32 as SortKey>::PAD, u32::MAX);
    }

    #[test]
    fn signed_bits_preserve_order() {
        let seq = [i32::MIN, -7, -1, 0, 1, 42, i32::MAX];
        for w in seq.windows(2) {
            assert!(w[0].to_bits() < w[1].to_bits(), "{w:?}");
            assert_eq!(i32::from_bits(w[0].to_bits()), w[0]);
        }
        let seq64 = [i64::MIN, -(1i64 << 40), -1, 0, 1i64 << 40, i64::MAX];
        for w in seq64.windows(2) {
            assert!(w[0].to_bits() < w[1].to_bits(), "{w:?}");
            assert_eq!(i64::from_bits(w[0].to_bits()), w[0]);
        }
    }

    #[test]
    fn f32_total_order() {
        // NB: `f32` has *inherent* `to_bits`/`from_bits` (raw IEEE
        // bits) that shadow the trait methods on the concrete type —
        // qualify the trait explicitly here. Generic `K: SortKey` code
        // has no such ambiguity.
        let seq = [
            f32::NEG_INFINITY,
            -1.0e30f32,
            -1.0,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            1.0,
            f32::INFINITY,
            f32::NAN,
        ];
        for w in seq.windows(2) {
            assert!(
                SortKey::to_bits(w[0]) < SortKey::to_bits(w[1]),
                "{w:?}"
            );
            assert!(w[0].key_lt(&w[1]), "{w:?}");
        }
        // PAD is the domain maximum and round-trips bit-identically.
        assert_eq!(SortKey::to_bits(<f32 as SortKey>::PAD), u32::MAX);
        let nan = f32::NAN;
        let roundtrip = <f32 as SortKey>::from_bits(SortKey::to_bits(nan));
        assert_eq!(f32::to_bits(roundtrip), f32::to_bits(nan));
    }

    #[test]
    fn record_orders_by_key_then_index() {
        let a = Record { key: 5u32, idx: 0 };
        let b = Record { key: 5u32, idx: 1 };
        let c = Record { key: 6u32, idx: 0 };
        assert!(a.key_lt(&b) && b.key_lt(&c));
        assert_eq!(<Record<u32> as SortKey>::WIDTH_BYTES, 8);
        let pad = <Record<u32> as SortKey>::PAD;
        assert!(b.key_lt(&pad));
    }

    #[test]
    fn tag_untag_roundtrip() {
        let keys = vec![30u32, 10, 20];
        let mut recs = tag_records(&keys).unwrap();
        recs.sort_unstable_by(<Record<u32>>::key_cmp);
        let mut out = keys.clone();
        let mut payload = vec![300u64, 100, 200];
        untag_records(&recs, &mut out, &mut payload);
        assert_eq!(out, vec![10, 20, 30]);
        assert_eq!(payload, vec![100, 200, 300]);
    }

    #[test]
    fn key_type_parse_roundtrip() {
        for kt in KeyType::ALL {
            assert_eq!(KeyType::parse(kt.id()), Some(kt));
        }
        assert_eq!(KeyType::parse("float"), Some(KeyType::F32));
        assert_eq!(KeyType::parse("u8"), None);
    }

    #[test]
    fn key_data_accessors() {
        let mut d = KeyData::from(vec![3u32, 1, 2]);
        assert_eq!(d.key_type(), KeyType::U32);
        assert_eq!(d.len(), 3);
        assert_eq!(d.width_bytes(), 4);
        assert_eq!(d.total_bytes(), 12);
        assert!(!d.is_sorted(false));
        d = KeyData::from(vec![1u32, 2, 3]);
        assert!(d.is_sorted(false));
        d.reverse();
        assert!(d.is_sorted(true));
        assert_eq!(d.as_u32(), Some(&[3u32, 2, 1][..]));
        assert_eq!(d.into_u32(), Some(vec![3, 2, 1]));
        let wide = KeyData::from(vec![1u64, 2]);
        assert_eq!(wide.width_bytes(), 8);
        assert!(wide.as_u32().is_none());
        assert!(KeyData::default().is_empty());
    }

    #[test]
    fn radix_digit_agrees_with_radix_bytes() {
        // The wide digit at any (offset, width) must equal the value
        // assembled from the byte stream — for the primitive overrides
        // and for the composite default impls alike.
        fn check<K: SortKey>(k: K) {
            for bits in [1u32, 5, 8, 11, 16] {
                let width_bits = 8 * K::WIDTH_BYTES as u32;
                let mut offset = 0;
                while offset < width_bits {
                    let b = bits.min(width_bits - offset);
                    let got = k.radix_digit(offset, b);
                    let mut expect: u64 = 0;
                    for i in 0..b {
                        let bit = offset + i;
                        let byte = k.radix_byte(bit as usize / 8);
                        expect |= (((byte >> (bit % 8)) & 1) as u64) << i;
                    }
                    assert_eq!(got, expect as usize, "offset={offset} bits={b}");
                    offset += b;
                }
            }
        }
        check(0xDEAD_BEEFu32);
        check(0x0123_4567_89AB_CDEFu64);
        check(-123_456_789i32);
        check(-(1i64 << 40) - 7);
        check(-1.5e-20f32);
        check(Record {
            key: 0xCAFE_F00Du32,
            idx: 0x1234_5678,
        });
        check(Segmented {
            seg: 42,
            key: 0xFFFF_0001u32,
        });
    }

    #[test]
    fn segmented_orders_by_segment_then_key() {
        let a = Segmented { seg: 0, key: 9u32 };
        let b = Segmented { seg: 1, key: 0u32 };
        let c = Segmented { seg: 1, key: 5u32 };
        assert!(a.key_lt(&b), "segment dominates the key");
        assert!(b.key_lt(&c));
        assert_eq!(<Segmented<u32> as SortKey>::WIDTH_BYTES, 8);
        let pad = <Segmented<u32> as SortKey>::PAD;
        assert!(c.key_lt(&pad));
        // Round-trip through bits.
        let back = Segmented::<u32>::from_bits(c.to_bits());
        assert_eq!(back, c);
        // The key occupies the low digits, the segment the high ones —
        // the property that makes stable LSD passes segment-major.
        assert_eq!(c.radix_byte(0), 5);
        assert_eq!(c.radix_byte(4), 1);
    }

    #[test]
    fn typed_keys_bridge_round_trips() {
        fn check<K: TypedKeys>(v: Vec<K>) {
            let data = K::into_key_data(v.clone());
            assert_eq!(data.key_type(), K::KEY_TYPE);
            let back = K::from_key_data(data).unwrap();
            assert_eq!(back.len(), v.len());
        }
        check(vec![1u32, 2]);
        check(vec![1u64, 2]);
        check(vec![-1i32, 2]);
        check(vec![-1i64, 2]);
        check(vec![0.5f32, -2.0]);
        // Wrong-type extraction refuses.
        assert!(u32::from_key_data(KeyData::U64(vec![1])).is_none());
    }

    #[test]
    fn from_raw_bits_is_order_preserving() {
        // Raw draws in increasing order map to keys in increasing
        // total order, for every type (low 32 bits for 4-byte keys).
        let raws = [0u64, 1, 0x7FFF_FFFF, 0x8000_0000, 0xFFFF_FFFE];
        fn check<K: SortKey>(raws: &[u64]) {
            for w in raws.windows(2) {
                let (a, b) = (K::from_raw_bits(w[0]), K::from_raw_bits(w[1]));
                assert!(a.key_lt(&b), "{a:?} !< {b:?}");
            }
        }
        check::<u32>(&raws);
        check::<u64>(&raws);
        check::<i32>(&raws);
        check::<i64>(&raws);
        check::<f32>(&raws);
    }
}

//! Native execution engine: the "virtual SM" pool.
//!
//! While [`crate::sim`] reproduces the paper's *GPU* performance
//! figures, this module is the *real* high-performance path of the
//! library: Algorithm 1 executed on host cores, one resident pool
//! worker ([`crate::util::pool`]) standing in for one SM with a
//! scratchpad-sized chunk, scratch buffers recycled through the
//! engine's [`crate::ExecContext`] arena, and the tile/bucket kernel
//! selected by [`crate::KernelKind`]. This is what the coordinator's
//! `native` engine serves requests with, and the subject of the §Perf
//! optimization pass.

pub mod native;

pub use native::{NativeEngine, NativeParams, NativeReport, PhaseTimes};

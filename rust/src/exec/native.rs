//! Algorithm 1 on host cores — the deterministic sample sort as a real
//! multicore parallel sort (the PSRS heritage of the method, Shi &
//! Schaeffer [15], brought back to the CPU).
//!
//! Mapping from the paper's GPU phases:
//!
//! | paper | here |
//! |---|---|
//! | Step 2: tile per SM in shared memory | chunk per worker, cache-resident sort |
//! | Steps 3–5: regular sampling | identical (s per chunk → s−1 splitters) |
//! | Step 6: parallel binary search | `partition_point` per chunk, in parallel |
//! | Step 7: column-major prefix | identical (small, sequential) |
//! | Step 8: coalesced relocation | per-bucket parallel gather into disjoint output slices |
//! | Step 9: sublist sort | per-bucket parallel sort |
//!
//! The determinism property carries over: bucket sizes are guaranteed
//! (≤ 2n/s + chunking slack), so the critical path is balanced without
//! work stealing.

use crate::algos::{adaptive, plan, ExecContext, KernelKind};
use crate::error::Result;
use crate::key::Record;
use crate::util::pool;
use crate::SortKey;
use std::time::Instant;

/// Parameters of the native engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NativeParams {
    /// Worker ("virtual SM") count; 0 = logical cores.
    pub workers: usize,
    /// Samples per chunk (the paper's s); the splitter count is
    /// `buckets − 1` with `buckets = max(workers·bucket_factor, 2)`.
    pub samples_per_chunk: usize,
    /// Buckets per worker — >1 gives the tail of the bucket-sort phase
    /// slack to balance.
    pub bucket_factor: usize,
    /// Below this size, fall back to a single-threaded sort (parallel
    /// setup costs more than it saves).
    pub sequential_cutoff: usize,
}

impl Default for NativeParams {
    fn default() -> Self {
        NativeParams {
            workers: 0,
            samples_per_chunk: 64,
            bucket_factor: 4,
            sequential_cutoff: 1 << 15,
        }
    }
}

/// Wall-clock phase breakdown of one native sort (the CPU analogue of
/// Figure 5).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    /// Steps 1–2: chunk local sorts.
    pub local_sort_ms: f64,
    /// Steps 3–5: sampling + splitter selection.
    pub sampling_ms: f64,
    /// Steps 6–7: boundaries + prefix layout.
    pub indexing_ms: f64,
    /// Step 8: relocation.
    pub relocation_ms: f64,
    /// Step 9: bucket sorts.
    pub bucket_sort_ms: f64,
}

impl PhaseTimes {
    /// Sum of all phases.
    pub fn total_ms(&self) -> f64 {
        self.local_sort_ms
            + self.sampling_ms
            + self.indexing_ms
            + self.relocation_ms
            + self.bucket_sort_ms
    }
}

/// Report of one native sort.
#[derive(Debug, Clone)]
pub struct NativeReport {
    /// Keys sorted.
    pub n: usize,
    /// Chunks (virtual SMs) used.
    pub chunks: usize,
    /// Buckets formed.
    pub buckets: usize,
    /// Phase breakdown.
    pub phases: PhaseTimes,
    /// End-to-end wall time (≥ phase sum; includes glue).
    pub wall_ms: f64,
    /// Largest bucket (balance check).
    pub max_bucket: usize,
}

impl NativeReport {
    /// Throughput in million keys per second.
    pub fn rate_mkeys_s(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return 0.0;
        }
        self.n as f64 / self.wall_ms / 1e3
    }
}

/// The native multicore engine.
#[derive(Debug)]
pub struct NativeEngine {
    params: NativeParams,
    workers: usize,
    /// Persistent execution resources: the scratch arena (Step-8 output
    /// buffer, record vectors, radix scratch) and the kernel selection.
    /// Held for the engine's lifetime, so repeated sorts of similar
    /// shapes allocate nothing.
    ctx: ExecContext,
    /// Adaptive decisions taken ([`KernelKind::Adaptive`] only):
    /// lifetime totals for metrics plus the latest choice for response
    /// tagging.
    choices: adaptive::ChoiceLog,
}

impl NativeEngine {
    /// Build an engine with a default [`ExecContext`] (radix kernel,
    /// fresh arena).
    pub fn new(params: NativeParams) -> Result<Self> {
        Self::with_context(params, ExecContext::default())
    }

    /// Build an engine around explicit execution resources (kernel
    /// selection, shared arena).
    pub fn with_context(params: NativeParams, mut ctx: ExecContext) -> Result<Self> {
        let workers = if params.workers == 0 {
            pool::default_workers()
        } else {
            params.workers
        };
        ctx.workers = workers;
        Ok(NativeEngine {
            params,
            workers,
            ctx,
            choices: adaptive::ChoiceLog::default(),
        })
    }

    /// The parameters in use.
    pub fn params(&self) -> &NativeParams {
        &self.params
    }

    /// The execution context (kernel, arena) in use.
    pub fn context(&self) -> &ExecContext {
        &self.ctx
    }

    /// Worker (virtual SM) count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Lifetime totals of adaptive decisions (all zero unless the
    /// engine runs [`KernelKind::Adaptive`]).
    pub fn plan_totals(&self) -> adaptive::PlanTotals {
        self.choices.totals()
    }

    /// The most recent adaptive decision, if any.
    pub fn last_plan_choice(&self) -> Option<adaptive::PlanChoice> {
        self.choices.last()
    }

    /// Sort `keys` in place (any [`SortKey`]; ordering by key bits).
    ///
    /// Under [`KernelKind::Adaptive`] the request first passes the
    /// adaptive front-end: a verified-sorted input returns untouched, a
    /// verified-reverse input is reversed in place, and everything else
    /// runs whichever concrete kernel the context's cost model predicts
    /// cheaper. Every candidate path produces identical bytes.
    pub fn sort<K: SortKey>(&self, keys: &mut [K]) -> NativeReport {
        if self.ctx.kernel != KernelKind::Adaptive {
            return self.sort_with(keys, &self.ctx);
        }
        let start = Instant::now();
        let (resolved, mut choice) =
            adaptive::resolve(keys, &self.ctx.cost, self.ctx.digit_bits);
        let mut report = match resolved {
            adaptive::Resolved::Done => NativeReport {
                n: keys.len(),
                chunks: 1,
                buckets: 1,
                phases: PhaseTimes {
                    local_sort_ms: start.elapsed().as_secs_f64() * 1e3,
                    ..Default::default()
                },
                wall_ms: 0.0,
                max_bucket: keys.len(),
            },
            adaptive::Resolved::Run(kernel) => {
                // The clone shares the arena (it is a handle): only the
                // kernel selection changes for this request.
                let mut ctx = self.ctx.clone();
                ctx.kernel = kernel;
                self.sort_with(keys, &ctx)
            }
        };
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;
        report.wall_ms = wall_ms;
        choice.actual_ms = wall_ms;
        self.choices.record(&choice);
        report
    }

    /// Sort with an explicit (concrete-kernel) context.
    fn sort_with<K: SortKey>(&self, keys: &mut [K], ctx: &ExecContext) -> NativeReport {
        let n = keys.len();
        let start = Instant::now();
        // With one worker the PSRS machinery is pure overhead (an extra
        // full copy + partition passes) — go straight to the sequential
        // kernel (§Perf).
        if n <= self.params.sequential_cutoff || self.workers <= 1 {
            let t0 = Instant::now();
            sort_run(keys, ctx);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            return NativeReport {
                n,
                chunks: 1,
                buckets: 1,
                phases: PhaseTimes {
                    local_sort_ms: ms,
                    ..Default::default()
                },
                wall_ms: start.elapsed().as_secs_f64() * 1e3,
                max_bucket: n,
            };
        }
        let report = self.sort_parallel(keys, ctx);
        NativeReport {
            wall_ms: start.elapsed().as_secs_f64() * 1e3,
            ..report
        }
    }

    /// Sort a key–value job: `keys` in place, `payload` permuted so
    /// `payload[i]` still belongs to `keys[i]`. Runs the PSRS engine
    /// over [`Record`]s — stable (ties break by original position) and
    /// byte-deterministic for any worker count.
    pub fn sort_pairs<K: SortKey>(
        &self,
        keys: &mut [K],
        payload: &mut Vec<u64>,
    ) -> Result<NativeReport> {
        crate::key::validate_key_value(keys.len(), payload.len())?;
        let mut recs = self.ctx.arena.take_empty::<Record<K>>();
        crate::key::tag_records_into(keys, &mut recs)?;
        let report = self.sort(recs.as_mut_slice());
        crate::key::untag_records_in(recs.as_slice(), keys, payload, &self.ctx.arena);
        Ok(report)
    }

    fn sort_parallel<K: SortKey>(&self, keys: &mut [K], ctx: &ExecContext) -> NativeReport {
        let n = keys.len();
        let workers = self.workers;
        let chunks = workers;
        let chunk_len = n.div_ceil(chunks);
        let s = self.params.samples_per_chunk.max(2);
        let buckets = (workers * self.params.bucket_factor).max(2);
        let mut phases = PhaseTimes::default();

        // Steps 1–2: parallel chunk sorts with the selected kernel
        // (scratch per worker from the arena).
        let t0 = Instant::now();
        pool::parallel_chunks_mut(keys, chunk_len, workers, |_, c| sort_run(c, ctx));
        phases.local_sort_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Steps 3–5: s regular samples per chunk → buckets−1 splitters.
        // (Sampling touches only s·m keys — sequential is cheapest.)
        let t0 = Instant::now();
        let mut samples: Vec<K> = keys
            .chunks(chunk_len)
            .flat_map(|c| {
                let stride = (c.len() / s).max(1);
                (0..s).filter_map(move |p| c.get(((p + 1) * stride).saturating_sub(1)).copied())
            })
            .collect();
        samples.sort_unstable_by(K::key_cmp);
        let splitters: Vec<K> = (1..buckets)
            .map(|j| samples[(j * samples.len() / buckets).min(samples.len() - 1)])
            .collect();
        phases.sampling_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Steps 6–7: per-chunk boundaries, then the column-major prefix.
        let t0 = Instant::now();
        let read_keys: &[K] = keys;
        let chunk_refs: Vec<&[K]> = read_keys.chunks(chunk_len).collect();
        let chunk_bounds: Vec<Vec<usize>> = pool::parallel_map(chunk_refs, workers, |c| {
            let mut b = Vec::with_capacity(buckets + 1);
            b.push(0);
            for sp in &splitters {
                b.push(c.partition_point(|x| x.key_lt(sp)));
            }
            b.push(c.len());
            b
        });
        let m = chunk_bounds.len();
        // loc[i][j] = destination of chunk i's bucket-j segment.
        let mut bucket_start = vec![0usize; buckets + 1];
        for j in 0..buckets {
            let mut total = 0usize;
            for cb in &chunk_bounds {
                total += cb[j + 1] - cb[j];
            }
            bucket_start[j + 1] = bucket_start[j] + total;
        }
        let mut loc = vec![0usize; m * buckets];
        for j in 0..buckets {
            let mut run = bucket_start[j];
            for i in 0..m {
                loc[i * buckets + j] = run;
                run += chunk_bounds[i][j + 1] - chunk_bounds[i][j];
            }
        }
        phases.indexing_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Step 8: relocation — parallel per *bucket*, each bucket
        // gathering its segments from every chunk into a disjoint
        // output slice (the output buffer is arena-recycled, so the
        // steady state performs no allocation here).
        let t0 = Instant::now();
        let mut out = ctx.arena.take(n, K::PAD);
        {
            let mut slices: Vec<&mut [K]> = Vec::with_capacity(buckets);
            let mut rest: &mut [K] = out.as_mut_slice();
            for j in 0..buckets {
                let len = bucket_start[j + 1] - bucket_start[j];
                let (head, tail) = rest.split_at_mut(len);
                slices.push(head);
                rest = tail;
            }
            let src: &[K] = keys;
            pool::parallel_slices_mut(slices, workers, |j, dst| {
                let mut off = 0usize;
                for (i, cb) in chunk_bounds.iter().enumerate() {
                    let (lo, hi) = (cb[j], cb[j + 1]);
                    let c_start = i * chunk_len;
                    let c_end = (c_start + chunk_len).min(n);
                    let seg = &src[c_start..c_end][lo..hi];
                    dst[off..off + seg.len()].copy_from_slice(seg);
                    off += seg.len();
                }
                debug_assert_eq!(off, dst.len());
            });
        }
        phases.relocation_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Step 9: parallel bucket sorts over disjoint output slices,
        // same kernel as Step 2.
        let t0 = Instant::now();
        {
            let mut slices: Vec<&mut [K]> = Vec::with_capacity(buckets);
            let mut rest: &mut [K] = out.as_mut_slice();
            for j in 0..buckets {
                let len = bucket_start[j + 1] - bucket_start[j];
                let (head, tail) = rest.split_at_mut(len);
                slices.push(head);
                rest = tail;
            }
            pool::parallel_slices_mut(slices, workers, |_, b| sort_run(b, ctx));
        }
        phases.bucket_sort_ms = t0.elapsed().as_secs_f64() * 1e3;

        let max_bucket = (0..buckets)
            .map(|j| bucket_start[j + 1] - bucket_start[j])
            .max()
            .unwrap_or(0);
        keys.copy_from_slice(out.as_slice());

        NativeReport {
            n,
            chunks: m,
            buckets,
            phases,
            wall_ms: 0.0, // filled by caller
            max_bucket,
        }
    }
}

/// Sort one contiguous run with the selected kernel: the
/// planner-scheduled wide-digit LSD kernel (pass schedule from the
/// context's digit width, constant digits elided), or the comparison
/// path — `slice::sort_unstable_by` on key bits, the host-optimal
/// equivalent of the GPU engines' bitonic network (the network itself
/// would waste the CPU's branch predictor on O(n log² n) work).
fn sort_run<K: SortKey>(keys: &mut [K], ctx: &ExecContext) {
    match ctx.kernel {
        // Adaptive resolves to a concrete kernel at the request
        // boundary (NativeEngine::sort); a run-level Adaptive context
        // executes the radix default.
        KernelKind::Radix | KernelKind::Adaptive => {
            let mut scratch = ctx.arena.take_empty::<K>();
            let mut counts = ctx.arena.take_empty::<usize>();
            plan::planned_sort(keys, &mut scratch, &mut counts, ctx.digit_bits, None);
        }
        KernelKind::Bitonic => keys.sort_unstable_by(K::key_cmp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{is_sorted, is_sorted_permutation, Key};

    fn engine() -> NativeEngine {
        NativeEngine::new(NativeParams {
            workers: 4,
            sequential_cutoff: 1 << 10,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn sorts_various_sizes() {
        let e = engine();
        for n in [0usize, 1, 100, 1 << 10, (1 << 10) + 1, 100_000, 1_000_003] {
            let input: Vec<Key> = (0..n as u32).map(|x| x.wrapping_mul(2654435761)).collect();
            let mut keys = input.clone();
            let r = e.sort(&mut keys);
            assert!(is_sorted_permutation(&input, &keys), "n={n}");
            assert_eq!(r.n, n);
        }
    }

    #[test]
    fn sorts_adversarial_inputs() {
        let e = engine();
        for input in [
            vec![7u32; 200_000],
            (0..200_000u32).collect(),
            (0..200_000u32).rev().collect(),
            (0..200_000u32).map(|x| x % 3).collect(),
        ] {
            let mut keys = input.clone();
            e.sort(&mut keys);
            assert!(is_sorted_permutation(&input, &keys));
        }
    }

    #[test]
    fn small_inputs_use_sequential_path() {
        let e = engine();
        let mut keys: Vec<Key> = (0..512u32).rev().collect();
        let r = e.sort(&mut keys);
        assert_eq!(r.chunks, 1);
        assert!(is_sorted(&keys));
    }

    #[test]
    fn phase_times_populated() {
        let e = engine();
        let mut keys: Vec<Key> = (0..500_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
        let r = e.sort(&mut keys);
        assert!(r.phases.local_sort_ms > 0.0);
        assert!(r.phases.bucket_sort_ms > 0.0);
        assert!(r.wall_ms >= r.phases.total_ms() * 0.5);
        assert!(r.rate_mkeys_s() > 0.0);
        assert!(r.buckets >= 2);
    }

    #[test]
    fn sorts_typed_keys_and_pairs() {
        let e = engine();
        // i64 negatives through the parallel PSRS path.
        let input: Vec<i64> = (0..300_000i64).map(|x| (x * 2654435761) - (1i64 << 40)).collect();
        let mut keys = input.clone();
        e.sort(&mut keys);
        assert!(is_sorted_permutation(&input, &keys));

        // f32 with NaNs: total order, NaNs sort last.
        let mut finput: Vec<f32> = (0..200_000u32)
            .map(|x| x.wrapping_mul(2654435761) as f32 - 2e9)
            .collect();
        finput[3] = f32::NAN;
        finput[100_001] = f32::NAN;
        let mut fkeys = finput.clone();
        e.sort(&mut fkeys);
        assert!(is_sorted_permutation(&finput, &fkeys));

        // Key–value: payload tracks its key, stably, through the
        // parallel path.
        let kin: Vec<u32> = (0..150_000u32).map(|x| x.wrapping_mul(2654435761) % 1024).collect();
        let pin: Vec<u64> = (0..kin.len() as u64).collect();
        let mut kout = kin.clone();
        let mut pout = pin.clone();
        e.sort_pairs(&mut kout, &mut pout).unwrap();
        assert!(is_sorted_permutation(&kin, &kout));
        for (k, p) in kout.iter().zip(&pout) {
            assert_eq!(kin[*p as usize], *k, "payload divorced from key");
        }
        for (w, pw) in kout.windows(2).zip(pout.windows(2)) {
            if w[0] == w[1] {
                assert!(pw[0] < pw[1], "unstable at key {}", w[0]);
            }
        }
        // Mismatched payload length is rejected.
        let mut bad = vec![0u64; 3];
        assert!(e.sort_pairs(&mut kout, &mut bad).is_err());
    }

    #[test]
    fn kernels_and_worker_counts_agree_byte_for_byte() {
        let input: Vec<Key> = (0..300_000u32).map(|x| x.wrapping_mul(2654435761) % 4096).collect();
        let payload: Vec<u64> = (0..input.len() as u64).collect();
        let mut reference: Option<(Vec<Key>, Vec<u64>)> = None;
        for kernel in [KernelKind::Bitonic, KernelKind::Radix, KernelKind::Adaptive] {
            for workers in [1usize, 2, 4] {
                let e = NativeEngine::with_context(
                    NativeParams {
                        workers,
                        sequential_cutoff: 1 << 10,
                        ..Default::default()
                    },
                    ExecContext::new(kernel, 0),
                )
                .unwrap();
                // Two rounds through the same engine: the second must be
                // served from the warm arena and still be identical.
                for _ in 0..2 {
                    let mut k = input.clone();
                    let mut p = payload.clone();
                    e.sort_pairs(&mut k, &mut p).unwrap();
                    match &reference {
                        None => reference = Some((k, p)),
                        Some((rk, rp)) => {
                            assert_eq!(&k, rk, "{kernel} × {workers} workers");
                            assert_eq!(&p, rp, "{kernel} × {workers} workers");
                        }
                    }
                }
                assert!(e.context().arena.stats().hits > 0, "arena never reused");
            }
        }
    }

    #[test]
    fn adaptive_engine_takes_early_exits_and_records_choices() {
        use crate::algos::adaptive::Choice;
        let e = engine(); // default context → adaptive kernel
        assert_eq!(e.context().kernel, KernelKind::Adaptive);
        assert_eq!(e.plan_totals(), Default::default());

        let mut sorted: Vec<Key> = (0..100_000).collect();
        let r = e.sort(&mut sorted);
        assert_eq!(r.chunks, 1, "early exit must not launch the PSRS path");
        assert!(is_sorted(&sorted));
        let last = e.last_plan_choice().unwrap();
        assert_eq!(last.chosen, Choice::EarlyExitSorted);
        assert!(last.actual_ms >= 0.0 && last.predicted_ms > 0.0);

        let mut reversed: Vec<Key> = (0..100_000).rev().collect();
        e.sort(&mut reversed);
        assert!(is_sorted(&reversed));
        assert_eq!(
            e.last_plan_choice().unwrap().chosen,
            Choice::EarlyExitReverse
        );

        let mut random: Vec<Key> =
            (0..100_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
        e.sort(&mut random);
        assert!(is_sorted(&random));
        assert_eq!(e.last_plan_choice().unwrap().chosen, Choice::Radix);

        let mut tiny: Vec<Key> = (0..300u32).map(|x| x.wrapping_mul(2654435761)).collect();
        e.sort(&mut tiny);
        assert!(is_sorted(&tiny));
        assert_eq!(e.last_plan_choice().unwrap().chosen, Choice::Comparison);

        let t = e.plan_totals();
        assert_eq!(t.requests, 4);
        assert_eq!(t.early_exit_sorted, 1);
        assert_eq!(t.early_exit_reverse, 1);
        assert_eq!(t.chose_radix, 1);
        assert_eq!(t.chose_comparison, 1);
    }

    #[test]
    fn adaptive_early_exit_preserves_pair_stability() {
        let e = engine();
        // Sorted keys with heavy duplicates: the early exit must return
        // the payload untouched — exactly the stable order.
        let kin: Vec<u32> = (0..50_000u32).map(|x| x / 16).collect();
        let pin: Vec<u64> = (0..kin.len() as u64).collect();
        let (mut k, mut p) = (kin.clone(), pin.clone());
        e.sort_pairs(&mut k, &mut p).unwrap();
        assert_eq!(k, kin);
        assert_eq!(p, pin, "sorted early exit must preserve payload order");

        // Reverse-sorted keys with duplicates: a blind reversal would
        // flip tie order; the record front-end must take the full sort
        // and keep ties in input order.
        let kin: Vec<u32> = (0..50_000u32).rev().map(|x| x / 16).collect();
        let (mut k, mut p) = (kin.clone(), pin.clone());
        e.sort_pairs(&mut k, &mut p).unwrap();
        assert!(is_sorted(&k));
        for (w, pw) in k.windows(2).zip(p.windows(2)) {
            if w[0] == w[1] {
                assert!(pw[0] < pw[1], "unstable at key {}", w[0]);
            }
        }
    }

    #[test]
    fn buckets_reasonably_balanced_on_uniform() {
        let e = engine();
        let input = crate::workload::Distribution::Uniform.generate(1 << 20, 11);
        let mut keys = input.clone();
        let r = e.sort(&mut keys);
        // Deterministic guarantee (plus chunk slack): max ≤ ~2·n/buckets.
        let bound = 2 * (1 << 20) / r.buckets + (1 << 20) / r.chunks / 8;
        assert!(
            r.max_bucket <= bound,
            "max bucket {} exceeds bound {bound}",
            r.max_bucket
        );
    }
}

//! The reproduction harness: one function per table/figure of the
//! paper's evaluation (§5), shared by the CLI (`gbs experiment …`), the
//! bench targets (`benches/fig*.rs`) and `examples/paper_figures.rs`.
//!
//! | paper artifact | function |
//! |---|---|
//! | Table 1 | [`table1`] |
//! | Figure 3 (runtime vs sample size s) | [`fig3_sample_size`] |
//! | Figure 4 (runtime vs n, three GPUs) | [`fig4_devices`] |
//! | Figure 5 (per-step breakdown, GTX 285) | [`fig5_step_breakdown`] |
//! | Figure 6 (vs randomized & Thrust Merge, GTX 285) | [`fig6_gtx285`] |
//! | Figure 7 (same on Tesla C1060) | [`fig7_tesla`] |
//! | §5 robustness narrative (determinism vs fluctuation) | [`robustness`] |
//!
//! Paper-scale points (up to 512M keys) use the analytic ledgers — the
//! property tests in `rust/tests/prop_algorithms.rs` pin them to the
//! executed ledgers at feasible sizes — and the cost model of
//! [`crate::sim::cost`] prices them per device. Missing cells are
//! capacity failures, reproduced deliberately (the paper's OOM
//! ceilings).

use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
use crate::algos::randomized::{RandomizedParams, RandomizedSampleSort};
use crate::algos::sharded::{ShardedSort, ShardedSortParams};
use crate::algos::thrust_merge::{ThrustMergeParams, ThrustMergeSort};
use crate::sim::{CostModel, DevicePool, GpuModel, GpuSim};
use crate::workload::Distribution;

/// A simple labelled table: one row label + one optional value per
/// column (None = the configuration failed, e.g. OOM — rendered as the
/// paper's missing data points).
#[derive(Debug, Clone)]
pub struct ExpTable {
    /// Table id, e.g. "fig4".
    pub name: String,
    /// Caption shown above the rendered table.
    pub caption: String,
    /// First (label) column header.
    pub row_header: String,
    /// Value column headers.
    pub columns: Vec<String>,
    /// Rows: (label, one value per column).
    pub rows: Vec<(String, Vec<Option<f64>>)>,
}

impl ExpTable {
    /// Render as CSV (empty cell = missing point).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.row_header);
        for c in &self.columns {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(label);
            for v in vals {
                out.push(',');
                if let Some(v) = v {
                    out.push_str(&format!("{v:.3}"));
                }
            }
            out.push('\n');
        }
        out
    }

    /// Render as an aligned console/markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("### {} — {}\n\n", self.name, self.caption);
        out.push_str(&format!("| {} |", self.row_header));
        for c in &self.columns {
            out.push_str(&format!(" {c} |"));
        }
        out.push('\n');
        out.push_str(&"|---".repeat(self.columns.len() + 1));
        out.push_str("|\n");
        for (label, vals) in &self.rows {
            out.push_str(&format!("| {label} |"));
            for v in vals {
                match v {
                    Some(v) => out.push_str(&format!(" {v:.1} |")),
                    None => out.push_str(" — |"),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Write the CSV under `dir/<name>.csv`.
    pub fn write_csv(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a key count the way the paper labels its axes (e.g. "32M").
pub fn fmt_n(n: usize) -> String {
    if n >= (1 << 20) && n % (1 << 20) == 0 {
        format!("{}M", n >> 20)
    } else if n >= 1024 && n % 1024 == 0 {
        format!("{}K", n >> 10)
    } else {
        n.to_string()
    }
}

/// GPU Bucket Sort's estimated total ms for `n` keys on `gpu` (analytic
/// path; None on OOM).
pub fn gbs_ms(n: usize, s: usize, gpu: GpuModel) -> Option<f64> {
    let params = BucketSortParams {
        s,
        ..BucketSortParams::default()
    };
    let sorter = BucketSort::try_new(params).ok()?;
    let mut sim = GpuSim::new(gpu.spec());
    let spec = gpu.spec();
    sorter
        .sort_analytic(n, &mut sim)
        .ok()
        .map(|r| r.total_estimated_ms(&spec))
}

/// Randomized sample sort's estimated ms (balanced/uniform assumption;
/// None on OOM — [9]'s reported ceilings).
pub fn rss_ms(n: usize, gpu: GpuModel) -> Option<f64> {
    let sorter = RandomizedSampleSort::new(RandomizedParams::default());
    let mut sim = GpuSim::new(gpu.spec());
    let spec = gpu.spec();
    sorter
        .sort_analytic(n, &mut sim)
        .ok()
        .map(|r| r.total_estimated_ms(&spec))
}

/// Thrust Merge's estimated ms (None beyond its 16M operational
/// ceiling [5]).
pub fn thrust_ms(n: usize, gpu: GpuModel) -> Option<f64> {
    let sorter = ThrustMergeSort::new(ThrustMergeParams::default());
    let mut sim = GpuSim::new(gpu.spec());
    let spec = gpu.spec();
    sorter
        .sort_analytic(n, &mut sim)
        .ok()
        .map(|r| r.total_estimated_ms(&spec))
}

/// Table 1: hardware characteristics of the four devices.
pub fn table1() -> ExpTable {
    let mut rows = vec![
        ("Number Of Cores".to_string(), Vec::new()),
        ("Core Clock Rate (MHz)".to_string(), Vec::new()),
        ("Global Memory Size (MB)".to_string(), Vec::new()),
        ("Memory Clock Rate (MHz)".to_string(), Vec::new()),
        ("Memory Bandwidth (GB/s)".to_string(), Vec::new()),
        ("Streaming Multiprocessors".to_string(), Vec::new()),
    ];
    for gpu in GpuModel::ALL {
        let s = gpu.spec();
        rows[0].1.push(Some(s.cores as f64));
        rows[1].1.push(Some(s.core_clock_mhz as f64));
        rows[2].1.push(Some((s.global_memory_bytes >> 20) as f64));
        rows[3].1.push(Some(s.memory_clock_mhz as f64));
        rows[4].1.push(Some(s.memory_bandwidth_gbs));
        rows[5].1.push(Some(s.sm_count as f64));
    }
    ExpTable {
        name: "table1".into(),
        caption: "Performance characteristics (paper Table 1)".into(),
        row_header: "characteristic".into(),
        columns: GpuModel::ALL.iter().map(|g| g.spec().name).collect(),
        rows,
    }
}

/// Figure 3: total runtime as a function of sample size s, for fixed
/// n ∈ {32M, 64M, 128M} on the GTX 285 — the s=64 trade-off.
pub fn fig3_sample_size(ns: &[usize], s_values: &[usize]) -> ExpTable {
    let gpu = GpuModel::Gtx285_2G;
    let mut rows = Vec::new();
    for &s in s_values {
        let vals = ns.iter().map(|&n| gbs_ms(n, s, gpu)).collect();
        rows.push((s.to_string(), vals));
    }
    ExpTable {
        name: "fig3".into(),
        caption: "GPU Bucket Sort runtime (ms) vs sample size s, GTX 285 (paper Fig. 3)"
            .into(),
        row_header: "s".into(),
        columns: ns.iter().map(|&n| format!("n={}", fmt_n(n))).collect(),
        rows,
    }
}

/// The sample sizes Figure 3 sweeps.
pub const FIG3_S_VALUES: [usize; 6] = [16, 32, 64, 128, 256, 512];

/// The data sizes Figure 3 fixes.
pub const FIG3_NS: [usize; 3] = [32 << 20, 64 << 20, 128 << 20];

/// Figure 4: GPU Bucket Sort runtime vs n on the three GPUs (missing
/// cells = over the device's memory ceiling).
pub fn fig4_devices(ns: &[usize]) -> ExpTable {
    let devices = [GpuModel::TeslaC1060, GpuModel::Gtx260, GpuModel::Gtx285_2G];
    let mut rows = Vec::new();
    for &n in ns {
        let vals = devices.iter().map(|&g| gbs_ms(n, 64, g)).collect();
        rows.push((fmt_n(n), vals));
    }
    ExpTable {
        name: "fig4".into(),
        caption: "GPU Bucket Sort runtime (ms) on Tesla C1060 / GTX 260 / GTX 285 (paper Fig. 4)"
            .into(),
        row_header: "n".into(),
        columns: devices.iter().map(|g| g.spec().name).collect(),
        rows,
    }
}

/// The n ladder used for Figures 4, 6 and 7 (powers of two, 1M–512M).
pub fn paper_n_ladder(max: usize) -> Vec<usize> {
    let mut ns = Vec::new();
    let mut n = 1usize << 20;
    while n <= max {
        ns.push(n);
        n *= 2;
    }
    ns
}

/// Figure 5: per-step time breakdown on the GTX 285.
pub fn fig5_step_breakdown(ns: &[usize]) -> ExpTable {
    let gpu = GpuModel::Gtx285_2G;
    let spec = gpu.spec();
    let sorter = BucketSort::new(BucketSortParams::default());
    let step_names = [
        (2u8, "Step 2 local sort"),
        (3, "Step 3 local sampling"),
        (4, "Step 4 sorting samples"),
        (5, "Step 5 global sampling"),
        (6, "Step 6 sample indexing"),
        (7, "Step 7 prefix sum"),
        (8, "Step 8 relocation"),
        (9, "Step 9 sublist sort"),
    ];
    let mut rows: Vec<(String, Vec<Option<f64>>)> = step_names
        .iter()
        .map(|(_, name)| (name.to_string(), Vec::new()))
        .collect();
    rows.push(("Total".to_string(), Vec::new()));
    for &n in ns {
        let mut sim = GpuSim::new(gpu.spec());
        match sorter.sort_analytic(n, &mut sim) {
            Ok(report) => {
                let steps = report.step_ms(&spec);
                let mut total = 0.0;
                for (idx, (step, _)) in step_names.iter().enumerate() {
                    let v = steps.get(step).copied().unwrap_or(0.0);
                    rows[idx].1.push(Some(v));
                    total += v;
                }
                let last = rows.len() - 1;
                rows[last].1.push(Some(total));
            }
            Err(_) => {
                for row in rows.iter_mut() {
                    row.1.push(None);
                }
            }
        }
    }
    ExpTable {
        name: "fig5".into(),
        caption: "Per-step runtime (ms) of Algorithm 1 on GTX 285 (paper Fig. 5)".into(),
        row_header: "step".into(),
        columns: ns.iter().map(|&n| fmt_n(n)).collect(),
        rows,
    }
}

/// Figure 6: GTX 285 comparison — GPU Bucket Sort (2 GB card) vs
/// Randomized Sample Sort ([9]'s 1 GB card, uniform best case) vs
/// Thrust Merge. Missing cells reproduce each method's ceiling.
pub fn fig6_gtx285(ns: &[usize]) -> ExpTable {
    comparison_table(
        "fig6",
        "GTX 285: GBS vs Randomized Sample Sort [9] vs Thrust Merge [14] (paper Fig. 6)",
        ns,
        GpuModel::Gtx285_2G,
        GpuModel::Gtx285_1G, // the card [9] actually measured on
    )
}

/// Figure 7: the same comparison on the Tesla C1060.
pub fn fig7_tesla(ns: &[usize]) -> ExpTable {
    comparison_table(
        "fig7",
        "Tesla C1060: GBS vs Randomized Sample Sort [9] vs Thrust Merge [14] (paper Fig. 7)",
        ns,
        GpuModel::TeslaC1060,
        GpuModel::TeslaC1060,
    )
}

fn comparison_table(
    name: &str,
    caption: &str,
    ns: &[usize],
    gbs_gpu: GpuModel,
    rss_gpu: GpuModel,
) -> ExpTable {
    let mut rows = Vec::new();
    for &n in ns {
        rows.push((
            fmt_n(n),
            vec![
                gbs_ms(n, 64, gbs_gpu),
                rss_ms(n, rss_gpu),
                thrust_ms(n, gbs_gpu),
            ],
        ));
    }
    ExpTable {
        name: name.into(),
        caption: caption.into(),
        row_header: "n".into(),
        columns: vec![
            "GPU Bucket Sort".into(),
            "Randomized Sample Sort [9]".into(),
            "Thrust Merge [14]".into(),
        ],
        rows,
    }
}

/// §5 robustness: executed (not analytic) runs of both sample sorts
/// across the distribution suite at a host-feasible n. Returns the
/// table plus the relative spread (max/min − 1) of each algorithm — the
/// deterministic method's spread must be ~0.
pub fn robustness(n: usize, seed: u64) -> (ExpTable, f64, f64) {
    let gpu = GpuModel::Gtx285_2G;
    let spec = gpu.spec();
    let gbs = BucketSort::new(BucketSortParams::default());
    let rss = RandomizedSampleSort::new(RandomizedParams {
        base_case: 1 << 14,
        ..RandomizedParams::default()
    });
    let mut rows = Vec::new();
    let mut gbs_all = Vec::new();
    let mut rss_all = Vec::new();
    for dist in Distribution::ROBUSTNESS_SUITE {
        let keys = dist.generate(n, seed);
        let mut sim = GpuSim::new(gpu.spec());
        let g = gbs
            .sort(&mut keys.clone(), &mut sim)
            .map(|r| r.total_estimated_ms(&spec))
            .ok();
        let mut sim2 = GpuSim::new(gpu.spec());
        let r = rss
            .sort(&mut keys.clone(), &mut sim2)
            .map(|r| r.total_estimated_ms(&spec))
            .ok();
        if let Some(v) = g {
            gbs_all.push(v);
        }
        if let Some(v) = r {
            rss_all.push(v);
        }
        rows.push((dist.id().to_string(), vec![g, r]));
    }
    let spread = |v: &[f64]| {
        if v.is_empty() {
            return 0.0;
        }
        let max = v.iter().copied().fold(0.0f64, f64::max);
        let min = v.iter().copied().fold(f64::INFINITY, f64::min);
        max / min - 1.0
    };
    let table = ExpTable {
        name: "robustness".into(),
        caption: format!(
            "Estimated ms across input distributions at n={} (§5 determinism claim)",
            fmt_n(n)
        ),
        row_header: "distribution".into(),
        columns: vec!["GPU Bucket Sort".into(), "Randomized Sample Sort".into()],
        rows,
    };
    (table, spread(&gbs_all), spread(&rss_all))
}

/// Sharded-engine makespan for `n` keys over `count` replicas of
/// `model` (analytic path; None on OOM — the pool's aggregate ceiling).
pub fn sharded_ms(n: usize, count: usize, model: GpuModel) -> Option<f64> {
    let models = vec![model; count];
    let mut pool = DevicePool::new(&models).ok()?;
    let sorter = ShardedSort::try_new(ShardedSortParams::default()).ok()?;
    let report = sorter.sort_analytic(n, &mut pool).ok()?;
    Some(report.makespan_ms(&pool))
}

/// Sharded scaling study (beyond the paper): estimated makespan vs
/// device count for homogeneous pools of `model`. Missing cells are
/// pool-level OOMs — the table shows the single-device ceiling moving
/// out as devices are added, and the speedup at fixed n.
pub fn sharded_scaling(ns: &[usize], device_counts: &[usize], model: GpuModel) -> ExpTable {
    let mut rows = Vec::new();
    for &n in ns {
        let vals = device_counts
            .iter()
            .map(|&c| sharded_ms(n, c, model))
            .collect();
        rows.push((fmt_n(n), vals));
    }
    ExpTable {
        name: "sharded".into(),
        caption: format!(
            "Sharded sort makespan (ms) vs device count, {} pool (beyond the paper)",
            model.spec().name
        ),
        row_header: "n".into(),
        columns: device_counts
            .iter()
            .map(|&c| format!("{c} device{}", if c == 1 { "" } else { "s" }))
            .collect(),
        rows,
    }
}

/// Sorting-rate series (Mkeys/s vs n) — the paper's "fixed sorting
/// rate" observation in §5 (flat for GBS over the whole range).
pub fn sort_rate_series(ns: &[usize], gpu: GpuModel) -> ExpTable {
    let mut rows = Vec::new();
    for &n in ns {
        let rate = gbs_ms(n, 64, gpu).map(|ms| CostModel::sort_rate_mkeys_s(n, ms));
        rows.push((fmt_n(n), vec![rate]));
    }
    ExpTable {
        name: "sort_rate".into(),
        caption: format!("GPU Bucket Sort sorting rate on {} (§5)", gpu.spec().name),
        row_header: "n".into(),
        columns: vec!["Mkeys/s".into()],
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_values() {
        let t = table1();
        assert_eq!(t.columns.len(), 4);
        // Cores row: 240, 240, 240, 216.
        assert_eq!(t.rows[0].1, vec![Some(240.0), Some(240.0), Some(240.0), Some(216.0)]);
        // Bandwidths: 102, 149, 159, 112.
        assert_eq!(
            t.rows[4].1,
            vec![Some(102.0), Some(149.0), Some(159.0), Some(112.0)]
        );
    }

    #[test]
    fn fig3_has_interior_minimum_shape() {
        // The s-tradeoff: runtime at the extremes exceeds the minimum,
        // and the minimum sits at a moderate s (paper: s = 64).
        let t = fig3_sample_size(&[32 << 20], &FIG3_S_VALUES);
        let series: Vec<f64> = t.rows.iter().map(|r| r.1[0].unwrap()).collect();
        let min_idx = series
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(min_idx > 0, "minimum must not sit at s=16: {series:?}");
        assert!(
            min_idx < series.len() - 1,
            "minimum must not sit at s=512: {series:?}"
        );
        assert!(series[0] > series[min_idx] * 1.05);
        assert!(series[series.len() - 1] > series[min_idx] * 1.02);
    }

    #[test]
    fn fig4_device_ordering_and_ceilings() {
        let ns = paper_n_ladder(512 << 20);
        let t = fig4_devices(&ns);
        // Columns: Tesla, GTX260, GTX285. The GTX 285 (highest
        // bandwidth) is fastest everywhere; the bandwidth ordering
        // GTX 260 < Tesla emerges once the run is memory-bound (the
        // paper's §5 observation) — we assert it from 64M up, where the
        // Tesla's small compute-clock edge has washed out.
        for (label, vals) in &t.rows {
            if let (Some(tesla), Some(g260), Some(g285)) = (vals[0], vals[1], vals[2]) {
                assert!(g285 < g260, "{label}: 285 {g285} < 260 {g260}");
                assert!(g285 < tesla, "{label}: 285 {g285} < tesla {tesla}");
                let big = label.ends_with('M')
                    && label.trim_end_matches('M').parse::<u32>().unwrap_or(0) >= 64;
                if big {
                    assert!(g260 < tesla, "{label}: 260 {g260} < tesla {tesla}");
                }
            }
        }
        // Ceilings: 64M is the last GTX 260 row; 256M the last GTX 285;
        // 512M present on Tesla.
        let row = |l: &str| {
            t.rows
                .iter()
                .find(|(label, _)| label == l)
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        assert!(row("64M")[1].is_some());
        assert!(row("128M")[1].is_none());
        assert!(row("256M")[2].is_some());
        assert!(row("512M")[2].is_none());
        assert!(row("512M")[0].is_some());
    }

    #[test]
    fn fig6_ordering_and_ceilings() {
        let ns = paper_n_ladder(256 << 20);
        let t = fig6_gtx285(&ns);
        for (label, vals) in &t.rows {
            let meg = label.trim_end_matches('M').parse::<u32>().unwrap_or(0);
            // Thrust Merge is clearly slower from the paper's mid-range
            // up (its merge rounds grow with log n, so the gap widens).
            if let (Some(gbs), Some(tm)) = (vals[0], vals[2]) {
                if meg >= 8 {
                    assert!(tm > 1.5 * gbs, "{label}: thrust {tm} vs gbs {gbs}");
                }
            }
            // The two sample sorts are comparable (within 2× either way)
            // — the paper's "nearly identical performance".
            if let (Some(gbs), Some(rss)) = (vals[0], vals[1]) {
                let ratio = rss / gbs;
                assert!((0.5..2.0).contains(&ratio), "{label}: ratio {ratio}");
            }
        }
        let row = |l: &str| {
            t.rows
                .iter()
                .find(|(label, _)| label == l)
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        // Thrust stops after 16M; RSS (1 GB card) after 32M; GBS reaches 256M.
        assert!(row("16M")[2].is_some() && row("32M")[2].is_none());
        assert!(row("32M")[1].is_some() && row("64M")[1].is_none());
        assert!(row("256M")[0].is_some());
    }

    #[test]
    fn fig7_ceilings() {
        let ns = paper_n_ladder(512 << 20);
        let t = fig7_tesla(&ns);
        let row = |l: &str| {
            t.rows
                .iter()
                .find(|(label, _)| label == l)
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        // Paper: RSS sorts up to 128M on the Tesla; GBS up to 512M.
        assert!(row("128M")[1].is_some() && row("256M")[1].is_none());
        assert!(row("512M")[0].is_some());
    }

    #[test]
    fn fig5_structure() {
        let t = fig5_step_breakdown(&[32 << 20]);
        assert_eq!(t.rows.len(), 9); // 8 steps + total
        let total = t.rows.last().unwrap().1[0].unwrap();
        let sum: f64 = t.rows[..8].iter().map(|r| r.1[0].unwrap()).sum();
        assert!((total - sum).abs() < 1e-9);
        // Steps 2 and 9 dominate (Figure 5's visual).
        let s2 = t.rows[0].1[0].unwrap();
        let s9 = t.rows[7].1[0].unwrap();
        assert!(s2 + s9 > 0.6 * total);
    }

    #[test]
    fn rate_is_roughly_flat() {
        // §5: fixed sorting rate over the whole range (mild log² drift
        // allowed: within 2.5× across 1M→512M).
        let t = sort_rate_series(&paper_n_ladder(512 << 20), GpuModel::TeslaC1060);
        let rates: Vec<f64> = t.rows.iter().filter_map(|r| r.1[0]).collect();
        let max = rates.iter().copied().fold(0.0f64, f64::max);
        let min = rates.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(max / min < 2.5, "rates {rates:?}");
    }

    #[test]
    fn sharded_scaling_moves_the_ceiling_and_speeds_up() {
        let t = sharded_scaling(
            &[64 << 20, 512 << 20],
            &[1, 2, 4],
            GpuModel::Gtx285_2G,
        );
        let row = |l: &str| {
            t.rows
                .iter()
                .find(|(label, _)| label == l)
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        // 512M exceeds one GTX 285's 256M ceiling but fits pools of 2+.
        let big = row("512M");
        assert!(big[0].is_none());
        assert!(big[1].is_some() && big[2].is_some());
        // At a fixed feasible n, more devices = shorter makespan, and
        // four devices beat one by a clear margin (combine overhead is
        // small next to the local-sort speedup).
        let mid = row("64M");
        let (one, two, four) = (mid[0].unwrap(), mid[1].unwrap(), mid[2].unwrap());
        assert!(two < one, "2 devices {two} vs 1 device {one}");
        assert!(four < two, "4 devices {four} vs 2 devices {two}");
        assert!(four < 0.5 * one, "4-device speedup too small: {four} vs {one}");
    }

    #[test]
    fn csv_and_markdown_render() {
        let t = fig4_devices(&[1 << 20, 128 << 20]);
        let csv = t.to_csv();
        assert!(csv.starts_with("n,"));
        assert!(csv.contains("1M,"));
        // The GTX 260's missing 128M cell renders empty.
        let line: &str = csv.lines().find(|l| l.starts_with("128M")).unwrap();
        assert!(line.contains(",,"), "{line}");
        let md = t.to_markdown();
        assert!(md.contains("| 1M |"));
        assert!(md.contains("—"));
    }

    #[test]
    fn robustness_contrast() {
        let (t, gbs_spread, rss_spread) = robustness(1 << 17, 7);
        assert_eq!(t.rows.len(), 6);
        // Randomized: visibly input-dependent.
        assert!(rss_spread > 0.01, "rss spread {rss_spread}");
        // Deterministic: flat across every tie-bounded distribution.
        // (zipf's unbounded duplicates can overflow the 2n/s bucket
        // guarantee — the documented tie-breaking limitation — so it is
        // excluded from the flatness check but still sorted correctly.)
        let gbs_non_zipf: Vec<f64> = t
            .rows
            .iter()
            .filter(|(label, _)| label != "zipf")
            .filter_map(|(_, v)| v[0])
            .collect();
        let max = gbs_non_zipf.iter().copied().fold(0.0f64, f64::max);
        let min = gbs_non_zipf.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(max / min - 1.0 < 1e-9, "gbs must be exactly flat off-zipf");
        assert!(gbs_spread < 0.1, "even with zipf the spread stays small: {gbs_spread}");
    }
}

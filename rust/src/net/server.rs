//! The TCP sort server: a framed-protocol front end over a running
//! [`SortClient`].
//!
//! Topology (per process):
//!
//! ```text
//!  accept thread ──▶ connection thread (reader)  ──┐ submit
//!                     │ credit window, partials    ▼
//!                     │                     coordinator::Service
//!                     │ pump thread ◀── per-request oneshot ┘
//!                     └─▶ shared write half (frame-granular mutex)
//! ```
//!
//! Each connection runs **two** threads: the *reader* owns the socket's
//! read half (handshake, frame decode, chunk reassembly, admission) and
//! the *pump* delivers responses back **in submission order** (HTTP-
//! pipelining style — the per-connection FIFO keeps responses matched
//! to the client's pipelined requests even though batches complete out
//! of order across workers). Both serialize writes through one mutex,
//! so frames never interleave mid-frame.
//!
//! Flow control is credit-based: the handshake grants
//! [`crate::config::NetConfig::credits`] admission slots; each
//! completed (or shed) request returns one via a `Credit` frame. The
//! scheduler's bounded queue surfaces as typed `Busy` error frames,
//! oversized submissions as `TooLarge` — a malformed frame closes that
//! connection with a typed error but never takes down the listener.
//!
//! [`NetServer::shutdown`] drains gracefully: stop accepting, reject
//! new submissions with `shutdown` error frames, wait for in-flight
//! sorts to complete and flush, then drain the inner service.

use super::credit::ServerWindow;
use super::wire::{
    chunk_frames, classify_error, encode_frame, error_frame, key_data_from_bytes,
    key_data_to_bytes, payload_from_bytes, payload_to_bytes, read_frame, CreditMsg, ErrorCode,
    Frame, HelloAckMsg, HelloMsg, Opcode, SortBeginMsg, SortHeaderMsg, WireError,
};
use crate::config::NetConfig;
use crate::coordinator::{SortClient, SortRequest, SortResponse};
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::util::sync::{
    self as sync, lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned, Arc, AtomicBool,
    Condvar, Mutex, Ordering,
};
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use sync::thread::JoinHandle;

/// Responses larger than this many keys are not cached (bounds the
/// window's memory). An uncached resubmission simply re-executes —
/// sorting is deterministic, so the replay is byte-identical anyway;
/// the window is an optimization, not a correctness requirement.
const DEDUP_MAX_KEYS: u64 = 1 << 16;

/// The idempotency window: FIFO-evicted map of completed responses,
/// capacity-bounded by [`crate::config::NetConfig::dedup_window`].
/// Session id `0` (a client that never reconnects) disables it.
struct Dedup {
    window: usize,
    order: VecDeque<(u64, u64)>,
    map: HashMap<(u64, u64), SortResponse>,
}

impl Dedup {
    fn new(window: usize) -> Dedup {
        Dedup {
            window,
            order: VecDeque::new(),
            map: HashMap::new(),
        }
    }

    /// Cache a completed response; returns how many older entries were
    /// evicted to make room (surfaced as `net_dedup_evictions` — a
    /// nonzero rate means reconnecting clients may miss replays and
    /// re-execute instead).
    fn insert(&mut self, session: u64, id: u64, resp: SortResponse) -> u64 {
        if self.window == 0 {
            return 0;
        }
        let mut evicted = 0;
        if self.map.insert((session, id), resp).is_none() {
            self.order.push_back((session, id));
            while self.order.len() > self.window {
                if let Some(k) = self.order.pop_front() {
                    self.map.remove(&k);
                    evicted += 1;
                }
            }
        }
        evicted
    }

    fn get(&self, session: u64, id: u64) -> Option<SortResponse> {
        self.map.get(&(session, id)).cloned()
    }
}

/// A zero-counting gauge: incremented per submitted request, waited on
/// at drain time.
#[derive(Default)]
struct Gauge {
    n: Mutex<usize>,
    cv: Condvar,
}

impl Gauge {
    fn incr(&self) {
        *lock_unpoisoned(&self.n) += 1;
    }

    fn get(&self) -> usize {
        *lock_unpoisoned(&self.n)
    }

    fn decr(&self) {
        let mut g = lock_unpoisoned(&self.n);
        *g = g.saturating_sub(1);
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait_zero(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = lock_unpoisoned(&self.n);
        while *g != 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = wait_timeout_unpoisoned(&self.cv, g, deadline - now);
            g = guard;
        }
        true
    }
}

/// Latched "a client asked us to drain" signal.
#[derive(Default)]
struct DrainSignal {
    requested: Mutex<bool>,
    cv: Condvar,
}

struct Shared {
    client: SortClient,
    net: NetConfig,
    metrics: Metrics,
    draining: AtomicBool,
    inflight: Gauge,
    drain: DrainSignal,
    conns: Mutex<Vec<TcpStream>>,
    /// Idempotency window for reconnecting clients (see [`Dedup`]).
    dedup: Mutex<Dedup>,
    /// The service's fault injector (when a plan is armed), probed for
    /// the `node_down` point at request admission.
    faults: Option<Arc<crate::sim::FaultInjector>>,
}

/// A running TCP sort server. Dropping (or calling
/// [`NetServer::shutdown`]) drains gracefully.
pub struct NetServer {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<Vec<JoinHandle<()>>>>,
    finished: bool,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve the given service handle. The server owns only its clone
    /// of the handle — other clones stay usable, and shutdown drains
    /// through the transport-agnostic [`SortClient::drain`].
    pub fn bind(addr: &str, client: SortClient, net: NetConfig) -> Result<NetServer> {
        net.validate()?;
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let faults = client.fault_injector();
        let shared = Arc::new(Shared {
            client,
            net,
            metrics: Metrics::new(),
            draining: AtomicBool::new(false),
            inflight: Gauge::default(),
            drain: DrainSignal::default(),
            conns: Mutex::new(Vec::new()),
            dedup: Mutex::new(Dedup::new(net.dedup_window)),
            faults,
        });
        let accept_shared = shared.clone();
        let accept = sync::thread::spawn_named("gbs-net-accept".into(), move || {
            accept_loop(listener, accept_shared)
        });
        Ok(NetServer {
            local_addr,
            shared,
            accept: Some(accept),
            finished: false,
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Live snapshot of the network-tier counters (`net_*`). The full
    /// merged picture (service + net) is returned by
    /// [`NetServer::shutdown`].
    pub fn net_metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// True once some client has sent a `Drain` frame.
    pub fn drain_requested(&self) -> bool {
        *lock_unpoisoned(&self.shared.drain.requested)
    }

    /// A cheap, clonable probe of this server's advertised load:
    /// `(inflight, credit_headroom)`. The cluster heartbeat thread
    /// calls it each beat; both numbers are instantaneous reads (the
    /// registry smooths nothing — routing only needs relative order).
    pub fn load_probe(&self) -> Arc<dyn Fn() -> (u32, u32) + Send + Sync> {
        let shared = Arc::clone(&self.shared);
        Arc::new(move || {
            let inflight = shared.inflight.get() as u32;
            let conns = lock_unpoisoned(&shared.conns).len() as u32;
            let total = conns.saturating_mul(shared.net.credits as u32);
            (inflight, total.saturating_sub(inflight))
        })
    }

    /// Block until a client requests a drain (or the timeout passes);
    /// returns whether a drain was requested. `gbs serve --listen` sits
    /// here, then calls [`NetServer::shutdown`].
    pub fn wait_for_drain_request(&self, timeout: Option<Duration>) -> bool {
        let mut g = lock_unpoisoned(&self.shared.drain.requested);
        match timeout {
            None => {
                while !*g {
                    g = wait_unpoisoned(&self.shared.drain.cv, g);
                }
                true
            }
            Some(t) => {
                let deadline = Instant::now() + t;
                while !*g {
                    let now = Instant::now();
                    if now >= deadline {
                        return false;
                    }
                    let (guard, _) =
                        wait_timeout_unpoisoned(&self.shared.drain.cv, g, deadline - now);
                    g = guard;
                }
                true
            }
        }
    }

    /// Graceful drain: stop accepting, complete in-flight sorts, flush
    /// their responses, close connections, then drain the inner
    /// service. Returns the merged service + network metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown_impl()
    }

    fn shutdown_impl(&mut self) -> MetricsSnapshot {
        self.finished = true;
        self.shared.draining.store(true, Ordering::SeqCst);
        // Poke the accept loop out of its blocking accept; it sees the
        // draining flag and exits, dropping the listener.
        let _ = TcpStream::connect(self.local_addr);
        let conn_handles = self
            .accept
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default();
        // Complete and flush in-flight sorts before touching sockets.
        let drain_timeout = Duration::from_millis(self.shared.net.drain_timeout_ms);
        if !self.shared.inflight.wait_zero(drain_timeout) {
            self.shared.metrics.incr("net_drain_timeout", 1);
        }
        // Unblock idle readers; their threads exit on the closed socket.
        for s in lock_unpoisoned(&self.shared.conns).iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in conn_handles {
            let _ = h.join();
        }
        // Transport-agnostic service drain: works while other clones of
        // the handle (e.g. the CLI's) are still alive.
        let mut snap = self.shared.client.drain();
        let net = self.shared.metrics.snapshot();
        for (k, v) in net.counters {
            *snap.counters.entry(k).or_insert(0) += v;
        }
        for (k, h) in net.timers {
            snap.timers.entry(k).or_insert(h);
        }
        snap
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if !self.finished {
            let _ = self.shutdown_impl();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<JoinHandle<()>> {
    let mut handles = Vec::new();
    for stream in listener.incoming() {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        shared.metrics.incr("net_connections", 1);
        if let Ok(clone) = stream.try_clone() {
            lock_unpoisoned(&shared.conns).push(clone);
        }
        let conn_shared = shared.clone();
        handles.push(sync::thread::spawn_named("gbs-net-conn".into(), move || {
            handle_connection(stream, conn_shared)
        }));
    }
    handles
}

/// One queued response: the wire request id and its oneshot channel.
type PumpItem = (u64, mpsc::Receiver<Result<SortResponse>>);

/// Write one frame under the shared write mutex. Returns false when the
/// peer is gone — callers just stop sending; cleanup happens when the
/// reader notices.
fn send(writer: &Mutex<TcpStream>, shared: &Shared, frame: &Frame) -> bool {
    let bytes = encode_frame(frame);
    let mut w = lock_unpoisoned(writer);
    match w.write_all(&bytes) {
        Ok(()) => {
            shared.metrics.incr("net_frames_tx", 1);
            true
        }
        Err(_) => false,
    }
}

fn handle_connection(stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let writer = Arc::new(Mutex::new(write_half));
    let mut reader = BufReader::new(stream);

    // Handshake: exactly one Hello, answered with the credit window.
    let hello = match read_frame(&mut reader, shared.net.max_frame_len) {
        Ok(Some(f)) if f.opcode == Opcode::Hello => match HelloMsg::decode(&f.payload) {
            Ok(h) => h,
            Err(e) => {
                shared.metrics.incr("net_malformed", 1);
                send(&writer, &shared, &error_frame(0, ErrorCode::Malformed, &e.to_string()));
                return;
            }
        },
        Ok(_) => {
            shared.metrics.incr("net_malformed", 1);
            send(
                &writer,
                &shared,
                &error_frame(0, ErrorCode::Malformed, "expected Hello handshake"),
            );
            return;
        }
        Err(e) => {
            shared.metrics.incr("net_malformed", 1);
            send(&writer, &shared, &error_frame(0, ErrorCode::Malformed, &e.to_string()));
            return;
        }
    };
    let ack = HelloAckMsg {
        credits: shared.net.credits as u32,
        max_frame_len: shared.net.max_frame_len as u32,
        max_request_keys: shared.net.max_request_keys as u64,
    };
    if !send(
        &writer,
        &shared,
        &Frame::message(Opcode::HelloAck, 0, ack.encode()),
    ) {
        return;
    }
    // Response chunks must fit what the client will accept.
    let chunk = shared
        .net
        .chunk_bytes
        .min((hello.max_frame_len as usize).max(64));

    // In-order completion pump; shares the connection's credit window.
    let window = Arc::new(ServerWindow::new(shared.net.credits));
    let (pump_tx, pump_rx) = mpsc::channel::<PumpItem>();
    let pump_writer = writer.clone();
    let pump_shared = shared.clone();
    let pump_window = window.clone();
    let session = hello.session;
    let pump = sync::thread::spawn_named("gbs-net-pump".into(), move || {
        pump_loop(pump_rx, pump_writer, pump_shared, pump_window, chunk, session)
    });

    read_loop(&mut reader, &writer, &shared, &window, pump_tx, session, chunk);

    let _ = pump.join();
}

fn pump_loop(
    rx: mpsc::Receiver<PumpItem>,
    writer: Arc<Mutex<TcpStream>>,
    shared: Arc<Shared>,
    window: Arc<ServerWindow>,
    chunk: usize,
    session: u64,
) {
    while let Ok((id, resp_rx)) = rx.recv() {
        let outcome = resp_rx
            .recv()
            .unwrap_or_else(|_| Err(Error::Coordinator("request dropped during shutdown".into())));
        match outcome {
            Ok(resp) => {
                send_response(&writer, &shared, id, &resp, chunk);
                // Remember the completed response for the idempotency
                // window — errors are not cached (they may be
                // transient; a resubmission deserves a fresh attempt).
                if session != 0 && resp.keys.len() as u64 <= DEDUP_MAX_KEYS {
                    let evicted = lock_unpoisoned(&shared.dedup).insert(session, id, resp);
                    if evicted > 0 {
                        shared.metrics.incr("net_dedup_evictions", evicted);
                    }
                }
            }
            Err(e) => {
                let code = classify_error(&e);
                if code == ErrorCode::Busy {
                    shared.metrics.incr("net_shed_busy", 1);
                }
                shared.metrics.incr("net_requests_failed", 1);
                send(&writer, &shared, &error_frame(id, code, &e.to_string()));
            }
        }
        // Free the window slot *before* returning the credit: once the
        // client sees the Credit frame it may immediately spend it, and
        // the next SortBegin must not trip the defensive window check.
        // (`rust/tests/loom_models.rs` checks this ordering.)
        window.release();
        send(
            &writer,
            &shared,
            &Frame::message(Opcode::Credit, id, CreditMsg { credits: 1 }.encode()),
        );
        shared.inflight.decr();
    }
}

fn send_response(
    writer: &Mutex<TcpStream>,
    shared: &Shared,
    id: u64,
    resp: &SortResponse,
    chunk: usize,
) {
    let header = SortHeaderMsg {
        key_type: resp.keys.key_type(),
        total_keys: resp.keys.len() as u64,
        has_payload: resp.payload.is_some(),
        engine: resp.engine,
        worker: resp.worker as u32,
        batch_size: resp.batch_size as u32,
        queue_ms: resp.queue_ms,
        service_ms: resp.service_ms,
        tag: resp.tag.clone(),
    };
    if !send(
        writer,
        shared,
        &Frame::message(Opcode::SortHeader, id, header.encode()),
    ) {
        return;
    }
    for f in chunk_frames(
        Opcode::ResultKeyChunk,
        id,
        &key_data_to_bytes(&resp.keys),
        chunk,
    ) {
        if !send(writer, shared, &f) {
            return;
        }
    }
    if let Some(p) = &resp.payload {
        for f in chunk_frames(Opcode::ResultPayloadChunk, id, &payload_to_bytes(p), chunk) {
            if !send(writer, shared, &f) {
                return;
            }
        }
    }
    if send(writer, shared, &Frame::control(Opcode::ResultEnd, id)) {
        shared.metrics.incr("net_responses", 1);
    }
}

/// Replay path: stream a cached response, then return the credit the
/// client spent on the resubmission. (Replays bypass the pump thread,
/// which normally owns the credit return.)
fn send_response_with_credit(
    writer: &Mutex<TcpStream>,
    shared: &Shared,
    id: u64,
    resp: &SortResponse,
    chunk: usize,
) {
    send_response(writer, shared, id, resp, chunk);
    send(
        writer,
        shared,
        &Frame::message(Opcode::Credit, id, CreditMsg { credits: 1 }.encode()),
    );
}

/// A request mid-stream: `SortBegin` seen, `Commit` pending.
struct PartialRequest {
    begin: SortBeginMsg,
    key_bytes: Vec<u8>,
    payload_bytes: Vec<u8>,
    /// Set when the idempotency window already holds this request's
    /// response: the submission frames are consumed as usual (the
    /// client has already pipelined them), but `Commit` replays the
    /// cached response instead of re-executing. Replay partials never
    /// took a window slot, so they release none.
    replay: Option<SortResponse>,
}

fn read_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &Arc<Mutex<TcpStream>>,
    shared: &Arc<Shared>,
    window: &Arc<ServerWindow>,
    pump_tx: mpsc::Sender<PumpItem>,
    session: u64,
    chunk: usize,
) {
    let mut partials: HashMap<u64, PartialRequest> = HashMap::new();
    loop {
        let frame = match read_frame(reader, shared.net.max_frame_len) {
            Ok(Some(f)) => f,
            Ok(None) => break, // clean close
            Err(WireError::Truncated) | Err(WireError::Io(_)) => {
                // Abrupt disconnect (possibly mid-frame): drop partials,
                // keep the listener untouched.
                shared.metrics.incr("net_disconnects", 1);
                break;
            }
            Err(e) => {
                // Corrupt or hostile frame: typed error, close this
                // connection only.
                shared.metrics.incr("net_malformed", 1);
                send(writer, shared, &error_frame(0, ErrorCode::Malformed, &e.to_string()));
                break;
            }
        };
        shared.metrics.incr("net_frames_rx", 1);
        match frame.opcode {
            Opcode::SortBegin => {
                let begin = match SortBeginMsg::decode(&frame.payload) {
                    Ok(b) => b,
                    Err(e) => {
                        shared.metrics.incr("net_malformed", 1);
                        send(writer, shared, &error_frame(0, ErrorCode::Malformed, &e.to_string()));
                        break;
                    }
                };
                if frame.id == 0 || partials.contains_key(&frame.id) {
                    shared.metrics.incr("net_malformed", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(0, ErrorCode::Malformed, "duplicate or zero request id"),
                    );
                    break;
                }
                // Deterministic whole-node crash (chaos plans only):
                // the `node_down` point fires at admission and the
                // process dies abruptly — no drain, no goodbye, no
                // deregister — modelling a kill -9. Cluster failover
                // (registry eviction + client resubmission to a
                // surviving node) is what recovers the request. Each
                // node process owns its plan file, so the probe index
                // is always 0.
                if let Some(inj) = &shared.faults {
                    if inj.node_down(0) {
                        std::process::exit(113);
                    }
                }
                // Defensive credit enforcement: a conforming client
                // never trips this, so no credit is returned.
                if window.is_exhausted() {
                    shared.metrics.incr("net_shed_busy", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(
                            frame.id,
                            ErrorCode::Busy,
                            "credit window exhausted — backpressure",
                        ),
                    );
                    continue;
                }
                if shared.draining.load(Ordering::SeqCst) {
                    shared.metrics.incr("net_shed_shutdown", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(frame.id, ErrorCode::Shutdown, "server draining"),
                    );
                    send(
                        writer,
                        shared,
                        &Frame::message(Opcode::Credit, frame.id, CreditMsg { credits: 1 }.encode()),
                    );
                    continue;
                }
                if begin.total_keys > shared.net.max_request_keys as u64 {
                    shared.metrics.incr("net_shed_too_large", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(
                            frame.id,
                            ErrorCode::TooLarge,
                            &format!(
                                "{} keys exceed the per-request ceiling {}",
                                begin.total_keys, shared.net.max_request_keys
                            ),
                        ),
                    );
                    send(
                        writer,
                        shared,
                        &Frame::message(Opcode::Credit, frame.id, CreditMsg { credits: 1 }.encode()),
                    );
                    continue;
                }
                // Idempotency window: a resubmission of a request this
                // server already completed (the client reconnected
                // before its response arrived) replays the cached
                // response at Commit time. The submission frames are
                // still consumed normally — the client has already
                // pipelined its chunks, and rejecting them here would
                // trip the unknown-id check below.
                if session != 0 {
                    let cached = lock_unpoisoned(&shared.dedup).get(session, frame.id);
                    if let Some(resp) = cached {
                        shared.metrics.incr("net_dedup_replays", 1);
                        partials.insert(
                            frame.id,
                            PartialRequest {
                                begin,
                                key_bytes: Vec::new(),
                                payload_bytes: Vec::new(),
                                replay: Some(resp),
                            },
                        );
                        continue;
                    }
                }
                shared.metrics.incr("net_requests", 1);
                window.begin();
                partials.insert(
                    frame.id,
                    PartialRequest {
                        begin,
                        key_bytes: Vec::new(),
                        payload_bytes: Vec::new(),
                        replay: None,
                    },
                );
            }
            Opcode::KeyChunk | Opcode::PayloadChunk => {
                let Some(partial) = partials.get_mut(&frame.id) else {
                    shared.metrics.incr("net_malformed", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(0, ErrorCode::Malformed, "chunk for unknown request id"),
                    );
                    break;
                };
                let width = partial.begin.key_type.width_bytes();
                let (buf, cap) = if frame.opcode == Opcode::KeyChunk {
                    (
                        &mut partial.key_bytes,
                        partial.begin.total_keys as usize * width,
                    )
                } else {
                    (
                        &mut partial.payload_bytes,
                        partial.begin.total_keys as usize * 8,
                    )
                };
                // Chunk accounting bound: a peer can never make us
                // buffer more than it declared at SortBegin.
                if buf.len() + frame.payload.len() > cap {
                    shared.metrics.incr("net_malformed", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(0, ErrorCode::Malformed, "chunk bytes exceed declared total"),
                    );
                    if let Some(p) = partials.remove(&frame.id) {
                        if p.replay.is_none() {
                            window.release();
                        }
                    }
                    break;
                }
                buf.extend_from_slice(&frame.payload);
            }
            Opcode::Commit => {
                let Some(partial) = partials.remove(&frame.id) else {
                    shared.metrics.incr("net_malformed", 1);
                    send(
                        writer,
                        shared,
                        &error_frame(0, ErrorCode::Malformed, "commit for unknown request id"),
                    );
                    break;
                };
                if let Some(resp) = partial.replay {
                    // Replay from the idempotency window: the cached
                    // response, byte-identical to the original. No
                    // window slot was taken, but the client spent a
                    // credit on the resubmission — return it.
                    send_response_with_credit(writer, shared, frame.id, &resp, chunk);
                    continue;
                }
                match assemble_request(&partial) {
                    Ok(request) => match shared.client.submit(request) {
                        Ok(rx) => {
                            shared.inflight.incr();
                            // The pump owns the credit/window release.
                            if pump_tx.send((frame.id, rx)).is_err() {
                                shared.inflight.decr();
                                window.release();
                            }
                        }
                        Err(e) => {
                            shared.metrics.incr("net_requests_failed", 1);
                            window.release();
                            send(
                                writer,
                                shared,
                                &error_frame(frame.id, classify_error(&e), &e.to_string()),
                            );
                            send(
                                writer,
                                shared,
                                &Frame::message(
                                    Opcode::Credit,
                                    frame.id,
                                    CreditMsg { credits: 1 }.encode(),
                                ),
                            );
                        }
                    },
                    Err(e) => {
                        shared.metrics.incr("net_malformed", 1);
                        window.release();
                        send(
                            writer,
                            shared,
                            &error_frame(frame.id, ErrorCode::Malformed, &e.to_string()),
                        );
                        send(
                            writer,
                            shared,
                            &Frame::message(
                                Opcode::Credit,
                                frame.id,
                                CreditMsg { credits: 1 }.encode(),
                            ),
                        );
                    }
                }
            }
            Opcode::Ping => {
                shared.metrics.incr("net_pings", 1);
                send(writer, shared, &Frame::control(Opcode::Pong, frame.id));
            }
            Opcode::Drain => {
                send(writer, shared, &Frame::control(Opcode::DrainAck, frame.id));
                let mut g = lock_unpoisoned(&shared.drain.requested);
                *g = true;
                shared.drain.cv.notify_all();
            }
            Opcode::Goodbye => break,
            // Anything else (including a second Hello or a
            // server→client opcode) is a protocol violation.
            _ => {
                shared.metrics.incr("net_malformed", 1);
                send(
                    writer,
                    shared,
                    &error_frame(0, ErrorCode::Malformed, "unexpected opcode"),
                );
                break;
            }
        }
    }
    // Abandoned partials release their credit-window slots; they never
    // reached the service, so there is nothing to leak there. Replay
    // partials never took a slot.
    for (_, p) in partials.drain() {
        if p.replay.is_none() {
            window.release();
        }
    }
}

fn assemble_request(partial: &PartialRequest) -> std::result::Result<SortRequest, WireError> {
    let begin = &partial.begin;
    let width = begin.key_type.width_bytes();
    let expected = begin.total_keys as usize * width;
    if partial.key_bytes.len() != expected {
        return Err(WireError::Malformed(format!(
            "commit with {} of {expected} declared key bytes",
            partial.key_bytes.len()
        )));
    }
    let keys = key_data_from_bytes(begin.key_type, &partial.key_bytes)?;
    let payload = if begin.has_payload {
        let expected = begin.total_keys as usize * 8;
        if partial.payload_bytes.len() != expected {
            return Err(WireError::Malformed(format!(
                "commit with {} of {expected} declared payload bytes",
                partial.payload_bytes.len()
            )));
        }
        Some(payload_from_bytes(&partial.payload_bytes)?)
    } else if partial.payload_bytes.is_empty() {
        None
    } else {
        return Err(WireError::Malformed(
            "payload chunks without has_payload".into(),
        ));
    };
    Ok(SortRequest {
        keys,
        payload,
        descending: begin.descending,
        self_check: begin.self_check,
        tag: begin.tag.clone(),
        // Deadlines are client-local: a remote caller's clock should
        // not start a server-side timer it cannot observe.
        deadline_ms: None,
    })
}

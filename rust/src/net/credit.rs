//! Credit-window flow control, extracted from the TCP client/server so
//! the loom models (`rust/tests/loom_models.rs`) can exhaustively check
//! its orderings without sockets.
//!
//! Two halves of the same protocol:
//!
//! * [`CreditGate`] — the **client's** admission gate. The handshake
//!   seeds it with the server's credit grant; [`CreditGate::acquire`]
//!   blocks a submitter until a credit is free, each `Credit` frame
//!   [`CreditGate::grant`]s one back, and connection death
//!   ([`CreditGate::kill`]) wakes every waiter with a refusal so no
//!   submitter blocks on a dead socket forever.
//! * [`ServerWindow`] — the **server's** defensive mirror: a counter of
//!   admission slots in use on one connection. Only the connection's
//!   reader thread calls [`ServerWindow::begin`] (after checking
//!   [`ServerWindow::is_exhausted`]), so check-then-begin is
//!   single-writer and race-free; the pump thread and the reader's
//!   error paths call [`ServerWindow::release`].
//!
//! The load-bearing ordering invariant (modeled under loom): the pump
//! must `release()` the window **before** writing the `Credit` frame.
//! Once the client sees the frame it may immediately spend the credit,
//! and the resulting `SortBegin` must not trip the server's defensive
//! exhaustion check.

use crate::util::sync::{
    lock_unpoisoned, wait_unpoisoned, AtomicUsize, Condvar, Mutex, Ordering,
};

struct GateState {
    credits: u32,
    dead: bool,
}

/// Client-side admission gate: a counted semaphore with a kill switch.
/// See the module docs.
pub struct CreditGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

impl CreditGate {
    /// Gate seeded with the server's handshake credit grant.
    pub fn new(credits: u32) -> Self {
        CreditGate {
            state: Mutex::new(GateState {
                credits,
                dead: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Take one credit, blocking while none are free. Returns `false`
    /// when the gate has been killed (the connection died) — then and
    /// only then no credit was consumed.
    pub fn acquire(&self) -> bool {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if st.dead {
                return false;
            }
            if st.credits > 0 {
                st.credits -= 1;
                return true;
            }
            st = wait_unpoisoned(&self.cv, st);
        }
    }

    /// Return `n` credits (a `Credit` frame arrived) and wake waiters.
    pub fn grant(&self, n: u32) {
        {
            let mut st = lock_unpoisoned(&self.state);
            st.credits = st.credits.saturating_add(n);
        }
        self.cv.notify_all();
    }

    /// Kill the gate: every current and future [`CreditGate::acquire`]
    /// returns `false`. Idempotent.
    pub fn kill(&self) {
        {
            let mut st = lock_unpoisoned(&self.state);
            st.dead = true;
        }
        self.cv.notify_all();
    }

    /// Credits currently free (diagnostics/tests; racy by nature).
    pub fn available(&self) -> u32 {
        lock_unpoisoned(&self.state).credits
    }
}

/// Server-side in-use counter for one connection's credit window. See
/// the module docs for the threading contract.
pub struct ServerWindow {
    in_use: AtomicUsize,
    limit: usize,
}

impl ServerWindow {
    /// Window of `limit` admission slots.
    pub fn new(limit: usize) -> Self {
        ServerWindow {
            in_use: AtomicUsize::new(0),
            limit,
        }
    }

    /// True when every slot is in use — a conforming client never
    /// submits past its credits, so a `true` here means the peer is
    /// broken or hostile and the request is shed without a credit.
    pub fn is_exhausted(&self) -> bool {
        self.in_use.load(Ordering::SeqCst) >= self.limit
    }

    /// Occupy one slot. Reader-thread only (single writer); callers
    /// check [`ServerWindow::is_exhausted`] first.
    pub fn begin(&self) {
        self.in_use.fetch_add(1, Ordering::SeqCst);
    }

    /// Free one slot. Must happen **before** the matching `Credit`
    /// frame is written — see the module docs.
    pub fn release(&self) {
        self.in_use.fetch_sub(1, Ordering::SeqCst);
    }

    /// Slots currently in use.
    pub fn in_use(&self) -> usize {
        self.in_use.load(Ordering::SeqCst)
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn gate_counts_and_blocks() {
        let gate = CreditGate::new(2);
        assert!(gate.acquire());
        assert!(gate.acquire());
        assert_eq!(gate.available(), 0);
        gate.grant(1);
        assert!(gate.acquire());
    }

    #[test]
    fn kill_wakes_blocked_acquirers() {
        let gate = Arc::new(CreditGate::new(0));
        let g2 = Arc::clone(&gate);
        let waiter = std::thread::spawn(move || g2.acquire());
        // The waiter blocks on zero credits until the kill lands.
        gate.kill();
        assert!(!waiter.join().expect("waiter thread"));
        // Killed gates refuse immediately, even with credits granted.
        gate.grant(5);
        assert!(!gate.acquire());
    }

    #[test]
    fn grant_hands_off_to_a_waiter() {
        let gate = Arc::new(CreditGate::new(1));
        assert!(gate.acquire());
        let g2 = Arc::clone(&gate);
        let waiter = std::thread::spawn(move || g2.acquire());
        gate.grant(1);
        assert!(waiter.join().expect("waiter thread"));
    }

    #[test]
    fn window_tracks_slots() {
        let w = ServerWindow::new(2);
        assert!(!w.is_exhausted());
        w.begin();
        w.begin();
        assert!(w.is_exhausted());
        assert_eq!(w.in_use(), 2);
        w.release();
        assert!(!w.is_exhausted());
    }
}

//! Multi-node sort client: registry-resolved routing with
//! health-checked failover.
//!
//! A [`ClusterClient`] never takes node addresses directly — it asks
//! the registry ([`super::registry::node_list`]) for the alive set and
//! keeps a pooled [`NetClient`] per node. Each request is routed to
//! the node with the lowest apparent load:
//!
//! * **advertised in-flight** — from the node's last heartbeat, via the
//!   registry (refreshed every [`ClusterOptions::refresh_every`]
//!   requests);
//! * **local in-flight** — requests this client currently has
//!   outstanding on the node (fresher than any heartbeat);
//! * **advertised credit headroom** — the tiebreak: more spare
//!   admission credits wins.
//!
//! # Failover
//!
//! Per-node clients run with reconnection *off* — when a node dies,
//! same-node retry is exactly wrong. The cluster client instead marks
//! the node dead, refreshes the node list, and resubmits the request
//! on a surviving node, paced by [`Backoff::RECONNECT`]. Blind
//! resubmission is safe for the same reason PR 9's single-node
//! recovery is: sorting is deterministic, so a request that secretly
//! completed on the dying node and is re-executed elsewhere produces a
//! byte-identical response. Only *loss-class* errors fail over
//! ([`Error::ConnectionLost`], [`Error::Io`], pool-exhaustion
//! [`Error::Coordinator`]); a typed rejection such as
//! [`Error::InvalidInput`] or [`Error::TooLarge`] would fail
//! identically everywhere and is returned as-is.

use super::client::{ClientOptions, NetClient};
use super::registry::node_list;
use crate::config::NetConfig;
use crate::coordinator::{SortRequest, SortResponse};
use crate::error::{Error, Result};
use crate::sim::fault::FaultInjector;
use crate::util::backoff::{sleep_backoff, Backoff};
use crate::util::sync::{lock_unpoisoned, Arc, AtomicBool, AtomicU64, Mutex, Ordering};

/// Routing/failover knobs for [`ClusterClient::connect`].
#[derive(Clone)]
pub struct ClusterOptions {
    /// Pooled connections per node (the per-node
    /// [`NetClient::connect`] pool size).
    pub connections_per_node: usize,
    /// How many times one request may fail over to another node before
    /// its loss-class error is returned to the caller.
    pub max_failovers: u32,
    /// Refresh the node list from the registry every this many
    /// requests (failover refreshes immediately regardless). 0 keeps
    /// the resolve-time list until a failover forces a refresh.
    pub refresh_every: u64,
    /// Optional fault injector forwarded to every per-node client
    /// (`socket_cut`, `frame_corrupt` points).
    pub faults: Option<Arc<FaultInjector>>,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            connections_per_node: 1,
            max_failovers: 4,
            refresh_every: 32,
            faults: None,
        }
    }
}

/// One resolved node: its pooled client plus the load inputs routing
/// reads. Advertised load comes from the registry; local in-flight is
/// maintained by this client around each submission.
struct NodeSlot {
    addr: String,
    client: NetClient,
    /// `(inflight, credit_headroom)` from the node's last heartbeat.
    advertised: Mutex<(u32, u32)>,
    /// Requests this cluster client currently has outstanding here.
    local_inflight: AtomicU64,
    /// Set on a loss-class failure; dead slots are never routed to and
    /// are dropped at the next refresh.
    dead: AtomicBool,
}

/// Decrement-on-drop guard so a panicking response path cannot leak a
/// node's local in-flight count.
struct InflightGuard<'a>(&'a NodeSlot);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.local_inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// A sorting client for a registry-coordinated cluster of sort
/// servers. See the module docs for routing and failover semantics.
pub struct ClusterClient {
    registry_addr: String,
    net: NetConfig,
    opts: ClusterOptions,
    nodes: Mutex<Vec<Arc<NodeSlot>>>,
    requests: AtomicU64,
    failovers: AtomicU64,
}

impl ClusterClient {
    /// Resolve the alive node set from the registry at `registry_addr`
    /// and connect to every node. Fails if the registry lists no alive
    /// nodes or none of them accepts a connection.
    pub fn connect(
        registry_addr: &str,
        net: NetConfig,
        opts: ClusterOptions,
    ) -> Result<ClusterClient> {
        net.validate()?;
        let cluster = ClusterClient {
            registry_addr: registry_addr.to_string(),
            net,
            opts,
            nodes: Mutex::new(Vec::new()),
            requests: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
        };
        cluster.refresh()?;
        if cluster.alive_count() == 0 {
            return Err(Error::Coordinator(format!(
                "registry {} lists no connectable nodes",
                cluster.registry_addr
            )));
        }
        Ok(cluster)
    }

    /// Addresses of the nodes currently considered routable, in
    /// routing-table order.
    pub fn nodes(&self) -> Vec<String> {
        lock_unpoisoned(&self.nodes)
            .iter()
            .filter(|n| !n.dead.load(Ordering::Relaxed))
            .map(|n| n.addr.clone())
            .collect()
    }

    /// How many requests failed over to another node so far.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    /// Total requests submitted through this client.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    fn alive_count(&self) -> usize {
        lock_unpoisoned(&self.nodes)
            .iter()
            .filter(|n| !n.dead.load(Ordering::Relaxed))
            .count()
    }

    fn connect_node(&self, addr: &str) -> Result<Arc<NodeSlot>> {
        let client = NetClient::connect_with(
            addr,
            self.opts.connections_per_node,
            self.net.clone(),
            ClientOptions {
                // Cluster failover replaces same-node reconnection: a
                // dead node's requests move to a survivor instead.
                reconnect: false,
                faults: self.opts.faults.clone(),
            },
        )?;
        Ok(Arc::new(NodeSlot {
            addr: addr.to_string(),
            client,
            advertised: Mutex::new((0, 0)),
            local_inflight: AtomicU64::new(0),
            dead: AtomicBool::new(false),
        }))
    }

    /// Re-resolve from the registry: update advertised load for known
    /// nodes, connect to newly listed ones, drop dead slots. Slots
    /// missing from the reply but still healthy are kept — the
    /// registry may merely suspect them, and a working connection
    /// beats an empty routing table.
    fn refresh(&self) -> Result<()> {
        let entries = node_list(&self.registry_addr)?;
        let mut nodes = lock_unpoisoned(&self.nodes);
        nodes.retain(|n| !n.dead.load(Ordering::Relaxed));
        for entry in entries {
            if let Some(slot) = nodes.iter().find(|n| n.addr == entry.addr) {
                *lock_unpoisoned(&slot.advertised) = (entry.inflight, entry.credit_headroom);
                continue;
            }
            // A node this client has never connected to (or one it
            // declared dead and dropped — re-listed means recovered).
            match self.connect_node(&entry.addr) {
                Ok(slot) => {
                    *lock_unpoisoned(&slot.advertised) = (entry.inflight, entry.credit_headroom);
                    nodes.push(slot);
                }
                Err(_) => continue,
            }
        }
        Ok(())
    }

    /// Pick the routable node with the lowest apparent load:
    /// advertised in-flight plus local in-flight, tiebreak on larger
    /// advertised credit headroom, then address order (determinism).
    fn pick(&self) -> Result<Arc<NodeSlot>> {
        let nodes = lock_unpoisoned(&self.nodes);
        let mut best: Option<(&Arc<NodeSlot>, u64, u32)> = None;
        for slot in nodes.iter() {
            if slot.dead.load(Ordering::Relaxed) {
                continue;
            }
            let (adv_inflight, headroom) = *lock_unpoisoned(&slot.advertised);
            let load =
                u64::from(adv_inflight) + slot.local_inflight.load(Ordering::Relaxed);
            let better = match best {
                None => true,
                Some((_, best_load, best_headroom)) => {
                    load < best_load || (load == best_load && headroom > best_headroom)
                }
            };
            if better {
                best = Some((slot, load, headroom));
            }
        }
        match best {
            Some((slot, _, _)) => Ok(slot.clone()),
            None => Err(Error::Coordinator(
                "no routable cluster node (all dead or deregistered)".into(),
            )),
        }
    }

    /// Sort on the least-loaded node, failing over to survivors on
    /// node death (up to [`ClusterOptions::max_failovers`] times).
    pub fn sort(&self, request: SortRequest) -> Result<SortResponse> {
        let seq = self.requests.fetch_add(1, Ordering::Relaxed);
        if self.opts.refresh_every > 0 && seq > 0 && seq % self.opts.refresh_every == 0 {
            // Periodic load refresh is best effort: a briefly
            // unreachable registry must not fail sorts on healthy,
            // already-connected nodes.
            let _ = self.refresh();
        }
        let mut attempt: u32 = 0;
        loop {
            let slot = self.pick()?;
            slot.local_inflight.fetch_add(1, Ordering::Relaxed);
            let outcome = {
                let _guard = InflightGuard(&slot);
                slot.client.sort(request.clone())
            };
            match outcome {
                Ok(resp) => return Ok(resp),
                Err(e) if is_loss(&e) => {
                    slot.dead.store(true, Ordering::Relaxed);
                    if attempt >= self.opts.max_failovers {
                        return Err(e);
                    }
                    self.failovers.fetch_add(1, Ordering::Relaxed);
                    sleep_backoff(&Backoff::RECONNECT, attempt);
                    attempt = attempt.saturating_add(1);
                    // Learn the survivors (and drop the corpse) before
                    // resubmitting. Deterministic sorting makes the
                    // resubmission idempotent even if the dead node
                    // already executed it.
                    let _ = self.refresh();
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// True for failures that mean "this node (or the path to it) is
/// gone", where the same request on another node can still succeed.
fn is_loss(e: &Error) -> bool {
    matches!(
        e,
        Error::ConnectionLost { .. } | Error::Io(_) | Error::Coordinator(_)
    )
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn loss_classification() {
        assert!(is_loss(&Error::ConnectionLost {
            request_ids: vec![1]
        }));
        assert!(is_loss(&Error::Io(std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "gone"
        ))));
        assert!(is_loss(&Error::Coordinator(
            "every pooled connection closed".into()
        )));
        assert!(!is_loss(&Error::InvalidInput("bad key width".into())));
        assert!(!is_loss(&Error::TooLarge("2 keys > limit 1".into())));
    }

    #[test]
    fn connect_refuses_empty_cluster() {
        // A registry with no nodes must be rejected at connect time.
        let reg = crate::net::registry::Registry::bind(
            "127.0.0.1:0",
            crate::net::registry::RegistryConfig::default(),
        )
        .expect("bind registry");
        let err = ClusterClient::connect(
            &reg.local_addr().to_string(),
            NetConfig::default(),
            ClusterOptions::default(),
        );
        assert!(err.is_err(), "empty cluster must not connect");
        reg.shutdown();
    }
}

//! The cluster registry: lease-based membership for a fleet of sort
//! servers, plus the node-side registration/heartbeat lifecycle.
//!
//! Topology:
//!
//! ```text
//!   node A ──Register/Heartbeat──▶ ┌──────────┐ ◀──NodeList── client
//!   node B ──Register/Heartbeat──▶ │ registry │ ◀──NodeList── client
//!   node C ──Deregister─────────▶  └──────────┘
//! ```
//!
//! Membership is a **lease**: a registered node renews by heartbeating
//! every `heartbeat_ms`; the registry never pings anybody. Lease state
//! is swept *lazily* — there is no sweeper thread and no registry-side
//! sleep; staleness is computed from the last heartbeat's timestamp at
//! the moment somebody asks:
//!
//! * `misses < suspect_misses` — **alive**: listed to routing clients.
//! * `suspect_misses ≤ misses < evict_misses` — **suspect**: withheld
//!   from `NodeList` replies (clients stop routing there) but kept in
//!   the table, so a late heartbeat reinstates it without a
//!   re-registration round trip.
//! * `misses ≥ evict_misses` — **evicted**: removed from the table; the
//!   node must `Register` again to rejoin.
//!
//! Shutdown ordering matters: a draining node first `Deregister`s (the
//! registry acks after removing it — from that ack on, no `NodeList`
//! reply routes new work to the node) and only then starts shedding
//! in-flight work. The ack read is bounded by the node's
//! [`crate::config::NetConfig::drain_timeout_ms`] so a dead registry
//! cannot wedge a node's shutdown.
//!
//! The registry speaks the same framed wire protocol as the sort
//! servers (`Register`/`Heartbeat`/`Deregister`/`NodeList` plus
//! `Ping`/`Drain`/`Goodbye`), but skips the `Hello` handshake — its
//! messages are tiny and carry no credits.

use super::wire::{
    error_frame, read_frame, write_frame, ErrorCode, Frame, HeartbeatMsg, NodeEntry, NodeListMsg,
    Opcode, RegisterAckMsg, RegisterMsg, WireError,
};
use crate::error::{Error, Result};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::util::backoff::{sleep_backoff, Backoff};
use crate::util::sync::{
    self as sync, lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned, Arc, AtomicBool,
    Condvar, Mutex, Ordering,
};
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use sync::thread::JoinHandle;

/// Frame ceiling on registry connections. Registry payloads are node
/// tables and addresses — a few KB at most; anything larger is hostile.
pub const REGISTRY_MAX_FRAME: usize = 1 << 16;

/// Lease parameters for a [`Registry`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryConfig {
    /// Expected heartbeat interval, in milliseconds. Echoed to nodes in
    /// the `RegisterAck` so the registry's clock is the one source of
    /// pacing truth.
    pub heartbeat_ms: u64,
    /// Consecutive missed heartbeats before a node turns **suspect**
    /// (withheld from `NodeList` replies).
    pub suspect_misses: u64,
    /// Consecutive missed heartbeats before a suspect node is
    /// **evicted** from the membership table.
    pub evict_misses: u64,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            heartbeat_ms: 100,
            suspect_misses: 3,
            evict_misses: 6,
        }
    }
}

impl RegistryConfig {
    /// Sanity-check the combination.
    pub fn validate(&self) -> Result<()> {
        if self.heartbeat_ms == 0 {
            return Err(Error::Config("registry.heartbeat_ms must be >= 1".into()));
        }
        if self.suspect_misses == 0 {
            return Err(Error::Config("registry.suspect_misses must be >= 1".into()));
        }
        if self.evict_misses < self.suspect_misses {
            return Err(Error::Config(format!(
                "registry.evict_misses ({}) must be >= suspect_misses ({})",
                self.evict_misses, self.suspect_misses
            )));
        }
        Ok(())
    }

    /// The lease a registration grants: silence for this long gets the
    /// node evicted.
    pub fn lease_ms(&self) -> u64 {
        self.heartbeat_ms.saturating_mul(self.evict_misses)
    }
}

/// Lease phase of one membership entry, as reported by
/// [`Registry::snapshot`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeaseState {
    /// Heartbeating on schedule — listed to routing clients.
    Alive,
    /// Missed `suspect_misses` beats — withheld from routing, not yet
    /// forgotten.
    Suspect,
}

/// One row of [`Registry::snapshot`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeStatus {
    /// Advertised sort address.
    pub addr: String,
    /// Last-advertised in-flight count.
    pub inflight: u32,
    /// Last-advertised credit headroom.
    pub credit_headroom: u32,
    /// Lease phase at snapshot time.
    pub state: LeaseState,
}

struct NodeState {
    last: Instant,
    inflight: u32,
    credit_headroom: u32,
}

/// Latched "a client asked us to drain" signal (same shape as the sort
/// server's).
#[derive(Default)]
struct DrainSignal {
    requested: Mutex<bool>,
    cv: Condvar,
}

struct Shared {
    cfg: RegistryConfig,
    metrics: Metrics,
    nodes: Mutex<HashMap<String, NodeState>>,
    draining: AtomicBool,
    drain: DrainSignal,
    conns: Mutex<Vec<TcpStream>>,
}

impl Shared {
    fn misses(&self, st: &NodeState) -> u64 {
        (st.last.elapsed().as_millis() as u64) / self.cfg.heartbeat_ms.max(1)
    }

    /// Lazy lease sweep: drop evicted entries, return the alive set.
    /// Called under no other lock; the membership mutex is the only one
    /// taken.
    fn sweep_and_list(&self) -> Vec<NodeEntry> {
        let mut nodes = lock_unpoisoned(&self.nodes);
        let before = nodes.len();
        let evict = self.cfg.evict_misses;
        nodes.retain(|_, st| self.misses(st) < evict);
        let evicted = before - nodes.len();
        if evicted > 0 {
            self.metrics.incr("registry_evictions", evicted as u64);
        }
        let mut alive: Vec<NodeEntry> = nodes
            .iter()
            .filter(|(_, st)| self.misses(st) < self.cfg.suspect_misses)
            .map(|(addr, st)| NodeEntry {
                addr: addr.clone(),
                inflight: st.inflight,
                credit_headroom: st.credit_headroom,
            })
            .collect();
        // Deterministic reply order (HashMap iteration is not).
        alive.sort_by(|a, b| a.addr.cmp(&b.addr));
        alive
    }

    fn upsert(&self, addr: String, inflight: u32, credit_headroom: u32) {
        let mut nodes = lock_unpoisoned(&self.nodes);
        nodes.insert(
            addr,
            NodeState {
                last: Instant::now(),
                inflight,
                credit_headroom,
            },
        );
    }
}

/// A running registry process. Dropping (or calling
/// [`Registry::shutdown`]) stops the listener and closes every node and
/// client connection.
pub struct Registry {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<Vec<JoinHandle<()>>>>,
    finished: bool,
}

impl Registry {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start serving membership.
    pub fn bind(addr: &str, cfg: RegistryConfig) -> Result<Registry> {
        cfg.validate()?;
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            cfg,
            metrics: Metrics::new(),
            nodes: Mutex::new(HashMap::new()),
            draining: AtomicBool::new(false),
            drain: DrainSignal::default(),
            conns: Mutex::new(Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept = sync::thread::spawn_named("gbs-registry-accept".into(), move || {
            accept_loop(listener, accept_shared)
        });
        Ok(Registry {
            local_addr,
            shared,
            accept: Some(accept),
            finished: false,
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The lease configuration this registry runs.
    pub fn config(&self) -> RegistryConfig {
        self.shared.cfg
    }

    /// Registry counters (`registry_*`).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Current membership, with lease phases computed now (evicted
    /// entries are swept as a side effect). Sorted by address.
    pub fn snapshot(&self) -> Vec<NodeStatus> {
        let shared = &*self.shared;
        let mut nodes = lock_unpoisoned(&shared.nodes);
        let evict = shared.cfg.evict_misses;
        nodes.retain(|_, st| shared.misses(st) < evict);
        let mut out: Vec<NodeStatus> = nodes
            .iter()
            .map(|(addr, st)| NodeStatus {
                addr: addr.clone(),
                inflight: st.inflight,
                credit_headroom: st.credit_headroom,
                state: if shared.misses(st) < shared.cfg.suspect_misses {
                    LeaseState::Alive
                } else {
                    LeaseState::Suspect
                },
            })
            .collect();
        out.sort_by(|a, b| a.addr.cmp(&b.addr));
        out
    }

    /// True once some client has sent a `Drain` frame.
    pub fn drain_requested(&self) -> bool {
        *lock_unpoisoned(&self.shared.drain.requested)
    }

    /// Block until a client requests a drain (or the timeout passes);
    /// returns whether a drain was requested. `gbs registry` sits here,
    /// then calls [`Registry::shutdown`].
    pub fn wait_for_drain_request(&self, timeout: Option<Duration>) -> bool {
        let mut g = lock_unpoisoned(&self.shared.drain.requested);
        match timeout {
            None => {
                while !*g {
                    g = wait_unpoisoned(&self.shared.drain.cv, g);
                }
                true
            }
            Some(t) => {
                let deadline = Instant::now() + t;
                while !*g {
                    let now = Instant::now();
                    if now >= deadline {
                        return false;
                    }
                    let (guard, _) =
                        wait_timeout_unpoisoned(&self.shared.drain.cv, g, deadline - now);
                    g = guard;
                }
                true
            }
        }
    }

    /// Stop accepting, close every connection, return final counters.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown_impl()
    }

    fn shutdown_impl(&mut self) -> MetricsSnapshot {
        self.finished = true;
        self.shared.draining.store(true, Ordering::SeqCst);
        // Poke the accept loop out of its blocking accept.
        let _ = TcpStream::connect(self.local_addr);
        let conn_handles = self
            .accept
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default();
        for s in lock_unpoisoned(&self.shared.conns).iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in conn_handles {
            let _ = h.join();
        }
        self.shared.metrics.snapshot()
    }
}

impl Drop for Registry {
    fn drop(&mut self) {
        if !self.finished {
            let _ = self.shutdown_impl();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<JoinHandle<()>> {
    let mut handles = Vec::new();
    for stream in listener.incoming() {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        shared.metrics.incr("registry_connections", 1);
        if let Ok(clone) = stream.try_clone() {
            lock_unpoisoned(&shared.conns).push(clone);
        }
        let conn_shared = shared.clone();
        handles.push(sync::thread::spawn_named(
            "gbs-registry-conn".into(),
            move || handle_connection(stream, conn_shared),
        ));
    }
    handles
}

fn send(writer: &mut TcpStream, frame: &Frame) -> bool {
    write_frame(writer, frame).is_ok()
}

fn handle_connection(stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let Ok(mut writer) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(stream);
    loop {
        let frame = match read_frame(&mut reader, REGISTRY_MAX_FRAME) {
            Ok(Some(f)) => f,
            // Clean close or abrupt drop: the lease machinery (not the
            // connection) decides liveness, so just stop reading.
            Ok(None) | Err(WireError::Truncated) | Err(WireError::Io(_)) => return,
            Err(e) => {
                shared.metrics.incr("registry_malformed", 1);
                send(
                    &mut writer,
                    &error_frame(0, ErrorCode::Malformed, &e.to_string()),
                );
                return;
            }
        };
        match frame.opcode {
            Opcode::Register => {
                let msg = match RegisterMsg::decode(&frame.payload) {
                    Ok(m) => m,
                    Err(e) => {
                        shared.metrics.incr("registry_malformed", 1);
                        send(
                            &mut writer,
                            &error_frame(0, ErrorCode::Malformed, &e.to_string()),
                        );
                        return;
                    }
                };
                shared.metrics.incr("registry_registers", 1);
                shared.upsert(msg.addr, 0, 0);
                let ack = RegisterAckMsg {
                    heartbeat_ms: shared.cfg.heartbeat_ms,
                    lease_ms: shared.cfg.lease_ms(),
                };
                send(
                    &mut writer,
                    &Frame::message(Opcode::RegisterAck, frame.id, ack.encode()),
                );
            }
            Opcode::Heartbeat => {
                let msg = match HeartbeatMsg::decode(&frame.payload) {
                    Ok(m) => m,
                    Err(e) => {
                        shared.metrics.incr("registry_malformed", 1);
                        send(
                            &mut writer,
                            &error_frame(0, ErrorCode::Malformed, &e.to_string()),
                        );
                        return;
                    }
                };
                shared.metrics.incr("registry_heartbeats", 1);
                // A heartbeat is an implicit re-registration: if the
                // node was suspect (or evicted and the registry
                // restarted), this reinstates it.
                shared.upsert(msg.addr, msg.inflight, msg.credit_headroom);
            }
            Opcode::Deregister => {
                let msg = match RegisterMsg::decode(&frame.payload) {
                    Ok(m) => m,
                    Err(e) => {
                        shared.metrics.incr("registry_malformed", 1);
                        send(
                            &mut writer,
                            &error_frame(0, ErrorCode::Malformed, &e.to_string()),
                        );
                        return;
                    }
                };
                shared.metrics.incr("registry_deregisters", 1);
                // Remove *before* acking: once the node sees the ack it
                // starts draining, and from that moment no NodeList
                // reply may route new work to it.
                lock_unpoisoned(&shared.nodes).remove(&msg.addr);
                let ack = RegisterAckMsg {
                    heartbeat_ms: shared.cfg.heartbeat_ms,
                    lease_ms: 0,
                };
                send(
                    &mut writer,
                    &Frame::message(Opcode::RegisterAck, frame.id, ack.encode()),
                );
            }
            Opcode::NodeList => {
                shared.metrics.incr("registry_node_lists", 1);
                let reply = NodeListMsg {
                    nodes: shared.sweep_and_list(),
                };
                send(
                    &mut writer,
                    &Frame::message(Opcode::NodeListReply, frame.id, reply.encode()),
                );
            }
            Opcode::Ping => {
                send(&mut writer, &Frame::control(Opcode::Pong, frame.id));
            }
            Opcode::Drain => {
                send(&mut writer, &Frame::control(Opcode::DrainAck, frame.id));
                let mut g = lock_unpoisoned(&shared.drain.requested);
                *g = true;
                shared.drain.cv.notify_all();
            }
            Opcode::Goodbye => return,
            _ => {
                shared.metrics.incr("registry_malformed", 1);
                send(
                    &mut writer,
                    &error_frame(0, ErrorCode::Malformed, "unexpected opcode"),
                );
                return;
            }
        }
    }
}

/// One registry round trip on a fresh connection: ask for the routable
/// node set. Used by the cluster client's resolve/refresh path, the
/// failover tests and the bench harness.
pub fn node_list(registry_addr: &str) -> Result<Vec<NodeEntry>> {
    let mut stream = TcpStream::connect(registry_addr)?;
    let _ = stream.set_nodelay(true);
    write_frame(&mut stream, &Frame::control(Opcode::NodeList, 1))?;
    match read_frame(&mut stream, REGISTRY_MAX_FRAME) {
        Ok(Some(f)) if f.opcode == Opcode::NodeListReply => {
            Ok(NodeListMsg::decode(&f.payload)?.nodes)
        }
        Ok(Some(f)) => Err(Error::Remote {
            code: "registry".into(),
            message: format!("expected NodeListReply, got {:?}", f.opcode),
        }),
        Ok(None) => Err(Error::Remote {
            code: "registry".into(),
            message: "registry closed the connection mid-query".into(),
        }),
        Err(e) => Err(e.into()),
    }
}

/// Ask a registry process to drain (the `gbs registry` exit path).
pub fn drain_registry(registry_addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(registry_addr)?;
    write_frame(&mut stream, &Frame::control(Opcode::Drain, 1))?;
    match read_frame(&mut stream, REGISTRY_MAX_FRAME) {
        Ok(Some(f)) if f.opcode == Opcode::DrainAck => Ok(()),
        Ok(Some(f)) => Err(Error::Remote {
            code: "registry".into(),
            message: format!("expected DrainAck, got {:?}", f.opcode),
        }),
        Ok(None) => Err(Error::Remote {
            code: "registry".into(),
            message: "registry closed the connection mid-drain".into(),
        }),
        Err(e) => Err(e.into()),
    }
}

// ---------------------------------------------------------------------------
// Node-side lifecycle
// ---------------------------------------------------------------------------

/// Load probe handed to [`NodeRegistration::start`]: returns
/// `(inflight, credit_headroom)` (see
/// [`crate::net::NetServer::load_probe`]).
pub type LoadProbe = Arc<dyn Fn() -> (u32, u32) + Send + Sync>;

struct RegShared {
    registry_addr: String,
    advertised: String,
    drain_timeout: Duration,
    load: LoadProbe,
    stop: Mutex<bool>,
    cv: Condvar,
    /// Whether the final `Deregister` was acked by the registry.
    deregistered: AtomicBool,
}

/// A node's live membership in a cluster: registers on start, renews
/// the lease from a background heartbeat thread, and deregisters
/// *before* the caller starts draining (call
/// [`NodeRegistration::deregister`] first, then drain the server).
pub struct NodeRegistration {
    shared: Arc<RegShared>,
    handle: Option<JoinHandle<()>>,
}

impl NodeRegistration {
    /// Register `advertised` with the registry at `registry_addr` and
    /// start heartbeating at the interval the registry's ack dictates.
    /// `load` is probed once per beat; `drain_timeout` bounds how long
    /// the final deregister waits for its ack.
    pub fn start(
        registry_addr: &str,
        advertised: &str,
        load: LoadProbe,
        drain_timeout: Duration,
    ) -> Result<NodeRegistration> {
        let (stream, ack) = dial_and_register(registry_addr, advertised)?;
        let shared = Arc::new(RegShared {
            registry_addr: registry_addr.to_string(),
            advertised: advertised.to_string(),
            drain_timeout,
            load,
            stop: Mutex::new(false),
            cv: Condvar::new(),
            deregistered: AtomicBool::new(false),
        });
        let hb_shared = shared.clone();
        let interval = Duration::from_millis(ack.heartbeat_ms.max(1));
        let handle = sync::thread::spawn_named("gbs-node-heartbeat".into(), move || {
            heartbeat_loop(hb_shared, stream, interval)
        });
        Ok(NodeRegistration {
            shared,
            handle: Some(handle),
        })
    }

    /// The lease this node registered under.
    pub fn advertised(&self) -> &str {
        &self.shared.advertised
    }

    /// Deregister-then-drain, step one: send the `Deregister`, wait
    /// (bounded by `drain_timeout`) for the registry's ack, stop the
    /// heartbeat thread. Returns whether the registry acked — after a
    /// `true`, the registry routes no new work here and the caller may
    /// start shedding. Safe to call once; Drop does the same best
    /// effort if the caller forgets.
    pub fn deregister(mut self) -> bool {
        self.stop_and_join();
        self.shared.deregistered.load(Ordering::SeqCst)
    }

    fn stop_and_join(&mut self) {
        {
            let mut g = lock_unpoisoned(&self.shared.stop);
            *g = true;
            self.shared.cv.notify_all();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeRegistration {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn dial_and_register(registry_addr: &str, advertised: &str) -> Result<(TcpStream, RegisterAckMsg)> {
    let mut stream = TcpStream::connect(registry_addr)?;
    let _ = stream.set_nodelay(true);
    let msg = RegisterMsg {
        addr: advertised.to_string(),
    };
    write_frame(
        &mut stream,
        &Frame::message(Opcode::Register, 1, msg.encode()),
    )?;
    match read_frame(&mut stream, REGISTRY_MAX_FRAME) {
        Ok(Some(f)) if f.opcode == Opcode::RegisterAck => {
            let ack = RegisterAckMsg::decode(&f.payload)?;
            Ok((stream, ack))
        }
        Ok(Some(f)) => Err(Error::Remote {
            code: "registry".into(),
            message: format!("expected RegisterAck, got {:?}", f.opcode),
        }),
        Ok(None) => Err(Error::Remote {
            code: "registry".into(),
            message: "registry closed the connection during registration".into(),
        }),
        Err(e) => Err(e.into()),
    }
}

fn heartbeat_loop(shared: Arc<RegShared>, mut stream: TcpStream, mut interval: Duration) {
    // Reconnect attempts since the last successful write; resets on
    // success so a long-lived node backs off afresh per outage.
    let mut attempt: u32 = 0;
    loop {
        let stopped = {
            let g = lock_unpoisoned(&shared.stop);
            if *g {
                true
            } else {
                let (g, _) = wait_timeout_unpoisoned(&shared.cv, g, interval);
                *g
            }
        };
        if stopped {
            // Deregister-then-drain: tell the registry to stop routing
            // here and wait (bounded) for the ack before the caller
            // sheds. A dead registry forfeits the ack — the lease
            // expires on its own.
            let msg = RegisterMsg {
                addr: shared.advertised.clone(),
            };
            if write_frame(
                &mut stream,
                &Frame::message(Opcode::Deregister, 1, msg.encode()),
            )
            .is_ok()
            {
                let _ = stream.set_read_timeout(Some(shared.drain_timeout));
                if let Ok(Some(f)) = read_frame(&mut stream, REGISTRY_MAX_FRAME) {
                    if f.opcode == Opcode::RegisterAck {
                        shared.deregistered.store(true, Ordering::SeqCst);
                    }
                }
            }
            return;
        }
        let (inflight, credit_headroom) = (shared.load)();
        let hb = HeartbeatMsg {
            addr: shared.advertised.clone(),
            inflight,
            credit_headroom,
        };
        if write_frame(
            &mut stream,
            &Frame::message(Opcode::Heartbeat, 0, hb.encode()),
        )
        .is_ok()
        {
            attempt = 0;
            continue;
        }
        // Registry connection lost: re-dial and re-register, paced by
        // the reconnect backoff — one attempt per loop turn so a stop
        // request stays responsive.
        sleep_backoff(&Backoff::RECONNECT, attempt);
        attempt = attempt.saturating_add(1).min(16);
        if let Ok((s, ack)) = dial_and_register(&shared.registry_addr, &shared.advertised) {
            stream = s;
            interval = Duration::from_millis(ack.heartbeat_ms.max(1));
            attempt = 0;
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn fast_cfg() -> RegistryConfig {
        RegistryConfig {
            heartbeat_ms: 20,
            suspect_misses: 2,
            evict_misses: 4,
        }
    }

    fn fixed_load(inflight: u32, headroom: u32) -> LoadProbe {
        Arc::new(move || (inflight, headroom))
    }

    #[test]
    fn config_validates() {
        assert!(RegistryConfig::default().validate().is_ok());
        assert!(RegistryConfig {
            heartbeat_ms: 0,
            ..RegistryConfig::default()
        }
        .validate()
        .is_err());
        assert!(RegistryConfig {
            suspect_misses: 0,
            ..RegistryConfig::default()
        }
        .validate()
        .is_err());
        assert!(RegistryConfig {
            suspect_misses: 5,
            evict_misses: 4,
            ..RegistryConfig::default()
        }
        .validate()
        .is_err());
        assert_eq!(RegistryConfig::default().lease_ms(), 600);
    }

    #[test]
    fn register_heartbeat_list_deregister_roundtrip() {
        let reg = Registry::bind("127.0.0.1:0", fast_cfg()).expect("bind registry");
        let addr = reg.local_addr().to_string();

        let a = NodeRegistration::start(
            &addr,
            "10.0.0.1:4750",
            fixed_load(2, 6),
            Duration::from_secs(5),
        )
        .expect("register a");
        let _b = NodeRegistration::start(
            &addr,
            "10.0.0.2:4750",
            fixed_load(0, 8),
            Duration::from_secs(5),
        )
        .expect("register b");

        let nodes = node_list(&addr).expect("node list");
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].addr, "10.0.0.1:4750");
        assert_eq!(nodes[1].addr, "10.0.0.2:4750");

        // Deregister-before-drain ordering: the ack means the node is
        // already unroutable.
        assert!(a.deregister(), "registry must ack the deregister");
        let nodes = node_list(&addr).expect("node list after deregister");
        assert_eq!(nodes.len(), 1);
        assert_eq!(nodes[0].addr, "10.0.0.2:4750");

        let snap = reg.shutdown();
        assert_eq!(snap.counters.get("registry_deregisters"), Some(&1));
        assert!(snap.counters.get("registry_registers").copied().unwrap_or(0) >= 2);
    }

    #[test]
    fn lease_expiry_suspects_then_evicts() {
        let cfg = fast_cfg();
        let reg = Registry::bind("127.0.0.1:0", cfg).expect("bind registry");
        let addr = reg.local_addr().to_string();

        // Register directly (no heartbeat thread) so the lease decays.
        let (_stream, ack) =
            dial_and_register(&addr, "10.0.0.9:4750").expect("manual registration");
        assert_eq!(ack.heartbeat_ms, cfg.heartbeat_ms);
        assert_eq!(ack.lease_ms, cfg.lease_ms());

        assert_eq!(node_list(&addr).expect("fresh list").len(), 1);

        // Past suspect_misses beats: withheld from routing, still known.
        std::thread::sleep(Duration::from_millis(
            cfg.heartbeat_ms * (cfg.suspect_misses + 1),
        ));
        assert!(
            node_list(&addr).expect("suspect list").is_empty(),
            "suspect node must not be routable"
        );
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].state, LeaseState::Suspect);

        // Past evict_misses beats: forgotten entirely.
        std::thread::sleep(Duration::from_millis(
            cfg.heartbeat_ms * (cfg.evict_misses - cfg.suspect_misses + 1),
        ));
        assert!(reg.snapshot().is_empty(), "expired lease must be evicted");
        let metrics = reg.shutdown();
        assert!(metrics.counters.get("registry_evictions").copied().unwrap_or(0) >= 1);
    }

    #[test]
    fn heartbeats_keep_the_lease_alive_and_update_load() {
        let cfg = fast_cfg();
        let reg = Registry::bind("127.0.0.1:0", cfg).expect("bind registry");
        let addr = reg.local_addr().to_string();
        let node = NodeRegistration::start(
            &addr,
            "10.0.0.3:4750",
            fixed_load(5, 11),
            Duration::from_secs(5),
        )
        .expect("register");

        // Well past the eviction horizon — heartbeats must renew.
        std::thread::sleep(Duration::from_millis(cfg.lease_ms() * 2));
        let nodes = node_list(&addr).expect("list");
        assert_eq!(nodes.len(), 1, "heartbeating node must stay routable");
        assert_eq!(nodes[0].inflight, 5);
        assert_eq!(nodes[0].credit_headroom, 11);
        drop(node);
        reg.shutdown();
    }

    #[test]
    fn drain_latch_and_helpers() {
        let reg = Registry::bind("127.0.0.1:0", RegistryConfig::default()).expect("bind");
        let addr = reg.local_addr().to_string();
        assert!(!reg.drain_requested());
        assert!(!reg.wait_for_drain_request(Some(Duration::from_millis(10))));
        drain_registry(&addr).expect("drain ack");
        assert!(reg.wait_for_drain_request(Some(Duration::from_secs(5))));
        assert!(reg.drain_requested());
        reg.shutdown();
    }
}

//! Sort-as-a-service over TCP.
//!
//! Three layers:
//!
//! * [`wire`] — the framed, CRC-checked, length-prefixed binary
//!   protocol (versioned header, typed opcodes, chunked streaming of
//!   large key arrays, typed error frames). Pure codec: no sockets.
//! * [`credit`] — the credit-window flow-control primitives shared by
//!   both ends ([`credit::CreditGate`], [`credit::ServerWindow`]),
//!   extracted so the loom models can check their orderings.
//! * [`server`] — [`NetServer`]: a listener in front of a running
//!   [`crate::coordinator::SortClient`], with credit-based admission,
//!   typed load-shedding (`busy` / `too_large` / `shutdown` error
//!   frames), per-connection fairness and graceful drain.
//! * [`client`] — [`NetClient`]: a pooled, pipelined client whose
//!   failures come back as the same typed [`crate::error::Error`]
//!   classes as in-process calls. With [`ClientOptions::reconnect`] it
//!   recovers from dead connections end to end: capped-backoff re-dial
//!   plus idempotent resubmission of in-flight requests under their
//!   original wire ids, matched by the server's per-session dedup
//!   window.
//!
//! `gbs serve --listen ADDR` and `gbs sort --connect ADDR` are the CLI
//! entry points; `docs/ARCHITECTURE.md` (§ Network tier) has the frame
//! layout and the flow-control state machine.

pub mod client;
pub mod credit;
pub mod server;
pub mod wire;

pub use client::{ClientOptions, NetClient};
pub use server::NetServer;

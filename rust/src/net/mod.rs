//! Sort-as-a-service over TCP.
//!
//! Three layers plus the cluster tier:
//!
//! * [`wire`] — the framed, CRC-checked, length-prefixed binary
//!   protocol (versioned header, typed opcodes, chunked streaming of
//!   large key arrays, typed error frames). Pure codec: no sockets.
//! * [`credit`] — the credit-window flow-control primitives shared by
//!   both ends ([`credit::CreditGate`], [`credit::ServerWindow`]),
//!   extracted so the loom models can check their orderings.
//! * [`server`] — [`NetServer`]: a listener in front of a running
//!   [`crate::coordinator::SortClient`], with credit-based admission,
//!   typed load-shedding (`busy` / `too_large` / `shutdown` error
//!   frames), per-connection fairness and graceful drain.
//! * [`client`] — [`NetClient`]: a pooled, pipelined client whose
//!   failures come back as the same typed [`crate::error::Error`]
//!   classes as in-process calls. With [`ClientOptions::reconnect`] it
//!   recovers from dead connections end to end: capped-backoff re-dial
//!   plus idempotent resubmission of in-flight requests under their
//!   original wire ids, matched by the server's per-session dedup
//!   window.
//! * [`registry`] — [`Registry`]: lease-based cluster membership
//!   (`Register`/`Heartbeat`/`NodeList` opcodes). Nodes self-register
//!   and heartbeat; silent nodes turn suspect (unroutable), then are
//!   evicted. [`NodeRegistration`] is the node-side lifecycle,
//!   including deregister-before-drain shutdown ordering.
//! * [`cluster`] — [`ClusterClient`]: resolves nodes from the
//!   registry, routes each request to the least-loaded node
//!   (advertised in-flight + local in-flight, credit-headroom
//!   tiebreak), and on node death fails in-flight requests over to a
//!   survivor — safe because sorting is deterministic.
//!
//! `gbs serve --listen ADDR` and `gbs sort --connect ADDR` are the CLI
//! entry points; `gbs registry`, `serve --registry` and
//! `sort --registry` form the multi-node path. `docs/ARCHITECTURE.md`
//! (§ Network tier, § Cluster tier) has the frame layout, the
//! flow-control state machine and the lease/failover state machines.

pub mod client;
pub mod cluster;
pub mod credit;
pub mod registry;
pub mod server;
pub mod wire;

pub use client::{ClientOptions, NetClient};
pub use cluster::{ClusterClient, ClusterOptions};
pub use registry::{NodeRegistration, Registry, RegistryConfig};
pub use server::NetServer;

//! The framed wire protocol spoken between `gbs serve --listen` and
//! `gbs sort --connect` (and by [`super::client`] / [`super::server`]).
//!
//! Every message is one **frame**:
//!
//! ```text
//!  offset  size  field
//!  ------  ----  -----------------------------------------------
//!       0     4  magic  "GBSW"
//!       4     1  protocol version (1)
//!       5     1  opcode                       (see [`Opcode`])
//!       6     2  flags, little-endian         (bit 0: last chunk)
//!       8     8  request id, little-endian    (0 = connection-level)
//!      16     4  payload length, little-endian
//!      20     4  CRC32 (IEEE) over bytes [0, 20) ++ payload
//!      24     …  payload
//! ```
//!
//! Large key arrays stream as a `SortBegin` header followed by
//! `KeyChunk`/`PayloadChunk` frames (arbitrary byte boundaries — chunks
//! need not align to key width) and a `Commit`; responses stream back
//! the same way. The decoder is hardened: the length prefix is checked
//! against a hard ceiling **before any allocation**, truncation and
//! corruption yield typed [`WireError`]s, and a CRC mismatch can never
//! surface as a valid frame. No decode path panics on hostile input.

use crate::error::Error;
use crate::key::{KeyData, KeyType};

/// Frame magic — first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"GBSW";
/// Protocol version carried (and checked) on every frame.
pub const VERSION: u8 = 1;
/// Fixed frame-header size in bytes.
pub const HEADER_LEN: usize = 24;
/// Flag bit 0: this is the final chunk of a streamed byte sequence.
pub const FLAG_LAST: u16 = 1;

/// Frame type. Client→server opcodes sit below `0x80`, server→client
/// at or above it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    /// Client handshake: payload is a [`HelloMsg`].
    Hello = 0x01,
    /// Start a sort submission: payload is a [`SortBeginMsg`].
    SortBegin = 0x02,
    /// A slice of the request's key bytes.
    KeyChunk = 0x03,
    /// A slice of the request's `u64` payload bytes.
    PayloadChunk = 0x04,
    /// All chunks sent — admit the request.
    Commit = 0x05,
    /// Orderly client goodbye (the socket closes after).
    Goodbye = 0x06,
    /// Liveness probe; the server echoes the id in a [`Opcode::Pong`].
    Ping = 0x07,
    /// Node → registry: join the cluster. Payload is a [`RegisterMsg`];
    /// acked with [`Opcode::RegisterAck`].
    Register = 0x08,
    /// Node → registry: lease renewal plus advertised load. Payload is
    /// a [`HeartbeatMsg`]; fire-and-forget (no reply frame).
    Heartbeat = 0x09,
    /// Client → registry: ask for the routable node set. Answered with
    /// a [`Opcode::NodeListReply`].
    NodeList = 0x0A,
    /// Node → registry: leave the cluster *before* draining, so the
    /// registry stops routing to the node while it still answers.
    /// Payload is a [`RegisterMsg`]; acked with [`Opcode::RegisterAck`].
    Deregister = 0x0B,
    /// Ask the server to drain gracefully (finish in-flight sorts, then
    /// stop). Acked with [`Opcode::DrainAck`] before the drain begins.
    Drain = 0x0F,
    /// Server handshake reply: payload is a [`HelloAckMsg`].
    HelloAck = 0x81,
    /// Response header: payload is a [`SortHeaderMsg`].
    SortHeader = 0x82,
    /// A slice of the response's key bytes.
    ResultKeyChunk = 0x83,
    /// A slice of the response's `u64` payload bytes.
    ResultPayloadChunk = 0x84,
    /// Response complete.
    ResultEnd = 0x85,
    /// Typed failure: payload is an [`ErrorMsg`]. With request id 0 the
    /// error is connection-level and the server closes the connection.
    ErrorFrame = 0x86,
    /// Flow control: payload is a [`CreditMsg`] returning admission
    /// credits to the client.
    Credit = 0x87,
    /// Acknowledges a [`Opcode::Drain`] request.
    DrainAck = 0x88,
    /// Liveness reply.
    Pong = 0x89,
    /// Registry → node: acknowledges a [`Opcode::Register`] or
    /// [`Opcode::Deregister`]. Payload is a [`RegisterAckMsg`].
    RegisterAck = 0x8A,
    /// Registry → client: the routable node set. Payload is a
    /// [`NodeListMsg`].
    NodeListReply = 0x8B,
}

impl Opcode {
    /// Every opcode (for exhaustive property tests).
    pub const ALL: [Opcode; 23] = [
        Opcode::Hello,
        Opcode::SortBegin,
        Opcode::KeyChunk,
        Opcode::PayloadChunk,
        Opcode::Commit,
        Opcode::Goodbye,
        Opcode::Ping,
        Opcode::Register,
        Opcode::Heartbeat,
        Opcode::NodeList,
        Opcode::Deregister,
        Opcode::Drain,
        Opcode::HelloAck,
        Opcode::SortHeader,
        Opcode::ResultKeyChunk,
        Opcode::ResultPayloadChunk,
        Opcode::ResultEnd,
        Opcode::ErrorFrame,
        Opcode::Credit,
        Opcode::DrainAck,
        Opcode::Pong,
        Opcode::RegisterAck,
        Opcode::NodeListReply,
    ];

    /// Parse a wire byte.
    pub fn from_u8(b: u8) -> Option<Opcode> {
        Opcode::ALL.into_iter().find(|op| *op as u8 == b)
    }
}

/// Typed decode failure. Hostile input maps here — never to a panic.
#[derive(Debug)]
pub enum WireError {
    /// The frame does not start with [`MAGIC`].
    BadMagic,
    /// Unsupported protocol version.
    BadVersion(u8),
    /// Authentic frame with an opcode this peer does not know.
    UnknownOpcode(u8),
    /// The length prefix exceeds the configured frame ceiling; detected
    /// before any payload allocation.
    Oversized {
        /// Declared payload length.
        len: usize,
        /// Configured ceiling.
        max: usize,
    },
    /// Frame checksum mismatch (corruption in header or payload).
    BadCrc,
    /// The stream or buffer ended mid-frame.
    Truncated,
    /// Structurally invalid frame payload (or chunk accounting).
    Malformed(String),
    /// Transport error while reading.
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            WireError::UnknownOpcode(b) => write!(f, "unknown opcode {b:#04x}"),
            WireError::Oversized { len, max } => {
                write!(f, "frame payload {len} B exceeds ceiling {max} B")
            }
            WireError::BadCrc => write!(f, "frame CRC mismatch"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<WireError> for Error {
    fn from(e: WireError) -> Self {
        Error::InvalidInput(format!("wire: {e}"))
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Frame type.
    pub opcode: Opcode,
    /// Flag bits (bit 0 = [`FLAG_LAST`]).
    pub flags: u16,
    /// Request id (client-assigned, connection-scoped; 0 for
    /// connection-level frames).
    pub id: u64,
    /// Opcode-specific payload bytes.
    pub payload: Vec<u8>,
}

impl Frame {
    /// A payload-free frame (control opcodes).
    pub fn control(opcode: Opcode, id: u64) -> Frame {
        Frame {
            opcode,
            flags: 0,
            id,
            payload: Vec::new(),
        }
    }

    /// A frame carrying an encoded message payload.
    pub fn message(opcode: Opcode, id: u64, payload: Vec<u8>) -> Frame {
        Frame {
            opcode,
            flags: 0,
            id,
            payload,
        }
    }
}

/// CRC32 (IEEE 802.3, reflected, poly `0xEDB88320`) over the
/// concatenation of `parts`.
pub fn crc32(parts: &[&[u8]]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for part in parts {
        for &b in *part {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    !c
}

const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Serialize a frame to its wire bytes.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + frame.payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(frame.opcode as u8);
    out.extend_from_slice(&frame.flags.to_le_bytes());
    out.extend_from_slice(&frame.id.to_le_bytes());
    out.extend_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    let crc = crc32(&[&out[0..20], &frame.payload]);
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&frame.payload);
    out
}

/// Decode one frame from the front of `buf`; returns the frame and the
/// number of bytes consumed. `max_len` bounds the payload length
/// *before* it is trusted.
pub fn decode_frame(buf: &[u8], max_len: usize) -> Result<(Frame, usize), WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated);
    }
    if buf[0..4] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if buf[4] != VERSION {
        return Err(WireError::BadVersion(buf[4]));
    }
    let len = u32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]) as usize;
    if len > max_len {
        return Err(WireError::Oversized { len, max: max_len });
    }
    let total = HEADER_LEN + len;
    if buf.len() < total {
        return Err(WireError::Truncated);
    }
    let stored = u32::from_le_bytes([buf[20], buf[21], buf[22], buf[23]]);
    let payload = &buf[HEADER_LEN..total];
    if crc32(&[&buf[0..20], payload]) != stored {
        return Err(WireError::BadCrc);
    }
    let opcode = Opcode::from_u8(buf[5]).ok_or(WireError::UnknownOpcode(buf[5]))?;
    let flags = u16::from_le_bytes([buf[6], buf[7]]);
    let id = u64::from_le_bytes([
        buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
    ]);
    Ok((
        Frame {
            opcode,
            flags,
            id,
            payload: payload.to_vec(),
        },
        total,
    ))
}

/// Read one frame from a stream. `Ok(None)` means the stream closed
/// cleanly *at a frame boundary*; closing mid-frame is
/// [`WireError::Truncated`]. The payload buffer is allocated only after
/// the declared length passes the `max_len` ceiling.
pub fn read_frame(r: &mut impl std::io::Read, max_len: usize) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    // First byte separately: a clean EOF here is an orderly close.
    let mut got = 0usize;
    while got == 0 {
        match r.read(&mut header[0..1]) {
            Ok(0) => return Ok(None),
            Ok(n) => got = n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    read_exact_or(r, &mut header[1..])?;
    if header[0..4] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if header[4] != VERSION {
        return Err(WireError::BadVersion(header[4]));
    }
    let len = u32::from_le_bytes([header[16], header[17], header[18], header[19]]) as usize;
    if len > max_len {
        return Err(WireError::Oversized { len, max: max_len });
    }
    let mut payload = vec![0u8; len];
    read_exact_or(r, &mut payload)?;
    let stored = u32::from_le_bytes([header[20], header[21], header[22], header[23]]);
    if crc32(&[&header[0..20], &payload]) != stored {
        return Err(WireError::BadCrc);
    }
    let opcode = Opcode::from_u8(header[5]).ok_or(WireError::UnknownOpcode(header[5]))?;
    let flags = u16::from_le_bytes([header[6], header[7]]);
    let id = u64::from_le_bytes([
        header[8], header[9], header[10], header[11], header[12], header[13], header[14],
        header[15],
    ]);
    Ok(Some(Frame {
        opcode,
        flags,
        id,
        payload,
    }))
}

fn read_exact_or(r: &mut impl std::io::Read, buf: &mut [u8]) -> Result<(), WireError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    })
}

/// Write one frame to a stream (single `write_all` of the encoding).
pub fn write_frame(w: &mut impl std::io::Write, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode_frame(frame))
}

/// Split `bytes` into chunked frames of at most `chunk` payload bytes
/// each; the final frame carries [`FLAG_LAST`]. Empty input yields no
/// frames (a zero-key request is just `SortBegin` + `Commit`).
pub fn chunk_frames(opcode: Opcode, id: u64, bytes: &[u8], chunk: usize) -> Vec<Frame> {
    let chunk = chunk.max(1);
    let mut frames: Vec<Frame> = bytes
        .chunks(chunk)
        .map(|c| Frame {
            opcode,
            flags: 0,
            id,
            payload: c.to_vec(),
        })
        .collect();
    if let Some(last) = frames.last_mut() {
        last.flags |= FLAG_LAST;
    }
    frames
}

// ---------------------------------------------------------------------------
// Typed payload messages
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian payload reader; every overrun is a
/// [`WireError::Malformed`].
struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| WireError::Malformed("payload too short".into()))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str_u16(&mut self) -> Result<String, WireError> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("non-UTF-8 string".into()))
    }

    fn done(&self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::Malformed(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )))
        }
    }
}

fn push_str_u16(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    let len = bytes.len().min(u16::MAX as usize);
    out.extend_from_slice(&(len as u16).to_le_bytes());
    out.extend_from_slice(&bytes[..len]);
}

/// Client handshake payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HelloMsg {
    /// The largest frame payload the *client* is willing to receive;
    /// the server clamps its response chunks to this.
    pub max_frame_len: u32,
    /// Client session id for idempotent resubmission. The server keys
    /// its bounded window of completed responses on
    /// `(session, request id)`, so a reconnecting client that replays
    /// an id it already submitted gets the cached response frames back
    /// instead of a re-execution. `0` disables the window (request ids
    /// are then only meaningful within one connection).
    pub session: u64,
}

impl HelloMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12);
        out.extend_from_slice(&self.max_frame_len.to_le_bytes());
        out.extend_from_slice(&self.session.to_le_bytes());
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = HelloMsg {
            max_frame_len: r.u32()?,
            session: r.u64()?,
        };
        r.done()?;
        Ok(msg)
    }
}

/// Server handshake payload: the connection's credit window and the
/// server's frame ceiling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HelloAckMsg {
    /// Initial admission credits for this connection.
    pub credits: u32,
    /// The largest frame payload the *server* is willing to receive.
    pub max_frame_len: u32,
    /// Per-request key-count ceiling (larger requests are shed with a
    /// `TooLarge` error frame).
    pub max_request_keys: u64,
}

impl HelloAckMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.extend_from_slice(&self.credits.to_le_bytes());
        out.extend_from_slice(&self.max_frame_len.to_le_bytes());
        out.extend_from_slice(&self.max_request_keys.to_le_bytes());
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = HelloAckMsg {
            credits: r.u32()?,
            max_frame_len: r.u32()?,
            max_request_keys: r.u64()?,
        };
        r.done()?;
        Ok(msg)
    }
}

const BEGIN_DESCENDING: u8 = 1;
const BEGIN_SELF_CHECK: u8 = 2;
const BEGIN_HAS_PAYLOAD: u8 = 4;
const BEGIN_HAS_TAG: u8 = 8;

/// `SortBegin` payload: everything about the request except the bulk
/// key/payload bytes (those stream as chunks).
#[derive(Debug, Clone, PartialEq)]
pub struct SortBeginMsg {
    /// Key type of the streamed key bytes.
    pub key_type: KeyType,
    /// Sort direction.
    pub descending: bool,
    /// Ask the service to verify the response before returning it.
    pub self_check: bool,
    /// Whether `PayloadChunk` frames follow (u64 per key).
    pub has_payload: bool,
    /// Declared key count — chunk accounting is validated against it.
    pub total_keys: u64,
    /// Optional diagnostic tag, echoed in the response.
    pub tag: Option<String>,
}

impl SortBeginMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.push(key_type_to_u8(self.key_type));
        let mut flags = 0u8;
        if self.descending {
            flags |= BEGIN_DESCENDING;
        }
        if self.self_check {
            flags |= BEGIN_SELF_CHECK;
        }
        if self.has_payload {
            flags |= BEGIN_HAS_PAYLOAD;
        }
        if self.tag.is_some() {
            flags |= BEGIN_HAS_TAG;
        }
        out.push(flags);
        out.extend_from_slice(&self.total_keys.to_le_bytes());
        if let Some(tag) = &self.tag {
            push_str_u16(&mut out, tag);
        }
        out
    }

    /// Deserialize. Unknown flag bits are rejected (strict decoding:
    /// silently dropping them would make round-trips unfaithful and
    /// future extensions ambiguous).
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let key_type = key_type_from_u8(r.u8()?)?;
        let flags = r.u8()?;
        let known = BEGIN_DESCENDING | BEGIN_SELF_CHECK | BEGIN_HAS_PAYLOAD | BEGIN_HAS_TAG;
        if flags & !known != 0 {
            return Err(WireError::Malformed(format!(
                "unknown SortBegin flag bits {flags:#04x}"
            )));
        }
        let total_keys = r.u64()?;
        let tag = if flags & BEGIN_HAS_TAG != 0 {
            Some(r.str_u16()?)
        } else {
            None
        };
        r.done()?;
        Ok(SortBeginMsg {
            key_type,
            descending: flags & BEGIN_DESCENDING != 0,
            self_check: flags & BEGIN_SELF_CHECK != 0,
            has_payload: flags & BEGIN_HAS_PAYLOAD != 0,
            total_keys,
            tag,
        })
    }
}

/// `SortHeader` payload: response metadata ahead of the result chunks.
#[derive(Debug, Clone, PartialEq)]
pub struct SortHeaderMsg {
    /// Key type of the streamed result bytes.
    pub key_type: KeyType,
    /// Result key count.
    pub total_keys: u64,
    /// Whether `ResultPayloadChunk` frames follow.
    pub has_payload: bool,
    /// Engine that served the request.
    pub engine: crate::config::EngineKind,
    /// Worker index that executed the batch.
    pub worker: u32,
    /// Number of requests in the executed batch.
    pub batch_size: u32,
    /// Milliseconds the request waited in the queue.
    pub queue_ms: f64,
    /// Milliseconds of engine service time.
    pub service_ms: f64,
    /// Tag echoed from the request.
    pub tag: Option<String>,
}

impl SortHeaderMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(40);
        out.push(key_type_to_u8(self.key_type));
        let mut flags = 0u8;
        if self.has_payload {
            flags |= BEGIN_HAS_PAYLOAD;
        }
        if self.tag.is_some() {
            flags |= BEGIN_HAS_TAG;
        }
        out.push(flags);
        out.push(engine_to_u8(self.engine));
        out.extend_from_slice(&self.total_keys.to_le_bytes());
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.batch_size.to_le_bytes());
        out.extend_from_slice(&self.queue_ms.to_bits().to_le_bytes());
        out.extend_from_slice(&self.service_ms.to_bits().to_le_bytes());
        if let Some(tag) = &self.tag {
            push_str_u16(&mut out, tag);
        }
        out
    }

    /// Deserialize. Unknown flag bits are rejected (strict decoding).
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let key_type = key_type_from_u8(r.u8()?)?;
        let flags = r.u8()?;
        if flags & !(BEGIN_HAS_PAYLOAD | BEGIN_HAS_TAG) != 0 {
            return Err(WireError::Malformed(format!(
                "unknown SortHeader flag bits {flags:#04x}"
            )));
        }
        let engine = engine_from_u8(r.u8()?)?;
        let total_keys = r.u64()?;
        let worker = r.u32()?;
        let batch_size = r.u32()?;
        let queue_ms = r.f64()?;
        let service_ms = r.f64()?;
        let tag = if flags & BEGIN_HAS_TAG != 0 {
            Some(r.str_u16()?)
        } else {
            None
        };
        r.done()?;
        Ok(SortHeaderMsg {
            key_type,
            total_keys,
            has_payload: flags & BEGIN_HAS_PAYLOAD != 0,
            engine,
            worker,
            batch_size,
            queue_ms,
            service_ms,
            tag,
        })
    }
}

/// Error classes carried in [`Opcode::ErrorFrame`] payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Backpressure load-shed: admission queue (or credit window) full.
    Busy,
    /// Request exceeds a hard size limit.
    TooLarge,
    /// Request failed validation.
    Invalid,
    /// The peer sent a protocol-violating frame sequence.
    Malformed,
    /// The server is draining; no new work is admitted.
    Shutdown,
    /// Any other server-side failure.
    Internal,
    /// The request's per-request deadline expired before it completed.
    Timeout,
}

impl ErrorCode {
    /// Every code (for exhaustive property tests).
    pub const ALL: [ErrorCode; 7] = [
        ErrorCode::Busy,
        ErrorCode::TooLarge,
        ErrorCode::Invalid,
        ErrorCode::Malformed,
        ErrorCode::Shutdown,
        ErrorCode::Internal,
        ErrorCode::Timeout,
    ];

    /// Stable wire name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::Busy => "busy",
            ErrorCode::TooLarge => "too_large",
            ErrorCode::Invalid => "invalid",
            ErrorCode::Malformed => "malformed",
            ErrorCode::Shutdown => "shutdown",
            ErrorCode::Internal => "internal",
            ErrorCode::Timeout => "timeout",
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            ErrorCode::Busy => 0,
            ErrorCode::TooLarge => 1,
            ErrorCode::Invalid => 2,
            ErrorCode::Malformed => 3,
            ErrorCode::Shutdown => 4,
            ErrorCode::Internal => 5,
            ErrorCode::Timeout => 6,
        }
    }

    fn from_u8(b: u8) -> Result<ErrorCode, WireError> {
        ErrorCode::ALL
            .into_iter()
            .find(|c| c.to_u8() == b)
            .ok_or_else(|| WireError::Malformed(format!("unknown error code {b}")))
    }
}

/// `ErrorFrame` payload.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorMsg {
    /// Failure class.
    pub code: ErrorCode,
    /// Human-readable server-side message.
    pub message: String,
}

impl ErrorMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(3 + self.message.len());
        out.push(self.code.to_u8());
        push_str_u16(&mut out, &self.message);
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = ErrorMsg {
            code: ErrorCode::from_u8(r.u8()?)?,
            message: r.str_u16()?,
        };
        r.done()?;
        Ok(msg)
    }
}

/// `Credit` payload: admission credits returned to the client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CreditMsg {
    /// Number of credits granted.
    pub credits: u32,
}

impl CreditMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        self.credits.to_le_bytes().to_vec()
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = CreditMsg {
            credits: r.u32()?,
        };
        r.done()?;
        Ok(msg)
    }
}

/// `Register` / `Deregister` payload: the node's advertised sort
/// address (what *clients* should dial — not the registry connection's
/// peer address, which may be a loopback or NAT artifact).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegisterMsg {
    /// Advertised `host:port` of the node's sort listener.
    pub addr: String,
}

impl RegisterMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + self.addr.len());
        push_str_u16(&mut out, &self.addr);
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = RegisterMsg { addr: r.str_u16()? };
        r.done()?;
        Ok(msg)
    }
}

/// `Heartbeat` payload: lease renewal plus the load the registry
/// advertises to routing clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HeartbeatMsg {
    /// Advertised `host:port` (doubles as implicit re-registration if
    /// the registry restarted and lost the membership table).
    pub addr: String,
    /// Requests currently executing or queued on the node.
    pub inflight: u32,
    /// Unused admission credits across the node's connections.
    pub credit_headroom: u32,
}

impl HeartbeatMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(10 + self.addr.len());
        push_str_u16(&mut out, &self.addr);
        out.extend_from_slice(&self.inflight.to_le_bytes());
        out.extend_from_slice(&self.credit_headroom.to_le_bytes());
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = HeartbeatMsg {
            addr: r.str_u16()?,
            inflight: r.u32()?,
            credit_headroom: r.u32()?,
        };
        r.done()?;
        Ok(msg)
    }
}

/// `RegisterAck` payload: the lease the registry granted. The node
/// paces its heartbeats from `heartbeat_ms` (registry config wins over
/// any node-side default), and knows that `lease_ms` of silence gets it
/// evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegisterAckMsg {
    /// Interval the registry expects between heartbeats.
    pub heartbeat_ms: u64,
    /// Milliseconds of missed heartbeats before the node is evicted
    /// (`heartbeat_ms × evict_misses`). `0` on a deregister ack — the
    /// lease is gone.
    pub lease_ms: u64,
}

impl RegisterAckMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.extend_from_slice(&self.heartbeat_ms.to_le_bytes());
        out.extend_from_slice(&self.lease_ms.to_le_bytes());
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let msg = RegisterAckMsg {
            heartbeat_ms: r.u64()?,
            lease_ms: r.u64()?,
        };
        r.done()?;
        Ok(msg)
    }
}

/// One routable node in a [`NodeListMsg`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeEntry {
    /// Advertised `host:port` of the node's sort listener.
    pub addr: String,
    /// Last heartbeat's in-flight count.
    pub inflight: u32,
    /// Last heartbeat's credit headroom.
    pub credit_headroom: u32,
}

/// `NodeListReply` payload: every node currently holding a live lease
/// (suspect and evicted nodes are excluded — the registry stops routing
/// before the node is gone).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeListMsg {
    /// Routable nodes with their last-advertised load.
    pub nodes: Vec<NodeEntry>,
}

impl NodeListMsg {
    /// Serialize.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + self.nodes.len() * 16);
        let count = self.nodes.len().min(u16::MAX as usize);
        out.extend_from_slice(&(count as u16).to_le_bytes());
        for node in &self.nodes[..count] {
            push_str_u16(&mut out, &node.addr);
            out.extend_from_slice(&node.inflight.to_le_bytes());
            out.extend_from_slice(&node.credit_headroom.to_le_bytes());
        }
        out
    }

    /// Deserialize.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = ByteReader::new(buf);
        let count = r.u16()? as usize;
        let mut nodes = Vec::with_capacity(count.min(1024));
        for _ in 0..count {
            nodes.push(NodeEntry {
                addr: r.str_u16()?,
                inflight: r.u32()?,
                credit_headroom: r.u32()?,
            });
        }
        r.done()?;
        Ok(NodeListMsg { nodes })
    }
}

/// Build an [`Opcode::ErrorFrame`] for `id`.
pub fn error_frame(id: u64, code: ErrorCode, message: &str) -> Frame {
    Frame::message(
        Opcode::ErrorFrame,
        id,
        ErrorMsg {
            code,
            message: message.to_string(),
        }
        .encode(),
    )
}

/// Server-side classification of a service [`Error`] into a wire code.
pub fn classify_error(e: &Error) -> ErrorCode {
    match e {
        Error::Busy(_) => ErrorCode::Busy,
        Error::TooLarge(_) | Error::DeviceOom { .. } => ErrorCode::TooLarge,
        Error::InvalidInput(_) | Error::InvalidParams(_) => ErrorCode::Invalid,
        Error::Coordinator(m) if m.contains("stopped") || m.contains("shutdown") => {
            ErrorCode::Shutdown
        }
        Error::Timeout(_) => ErrorCode::Timeout,
        _ => ErrorCode::Internal,
    }
}

/// Client-side mapping of a wire error code back to a typed [`Error`],
/// so remote failures match on the same classes as in-process ones
/// (`Busy` stays [`Error::Busy`], etc.).
pub fn error_from_wire(code: ErrorCode, message: String) -> Error {
    match code {
        ErrorCode::Busy => Error::Busy(message),
        ErrorCode::TooLarge => Error::TooLarge(message),
        ErrorCode::Invalid => Error::InvalidInput(message),
        ErrorCode::Shutdown => Error::Coordinator(message),
        ErrorCode::Timeout => Error::Timeout(message),
        ErrorCode::Malformed | ErrorCode::Internal => Error::Remote {
            code: code.as_str().to_string(),
            message,
        },
    }
}

// ---------------------------------------------------------------------------
// Key / payload byte serialization
// ---------------------------------------------------------------------------

/// Wire tag of a [`KeyType`].
pub fn key_type_to_u8(kt: KeyType) -> u8 {
    match kt {
        KeyType::U32 => 0,
        KeyType::U64 => 1,
        KeyType::I32 => 2,
        KeyType::I64 => 3,
        KeyType::F32 => 4,
    }
}

/// Parse a [`KeyType`] wire tag.
pub fn key_type_from_u8(b: u8) -> Result<KeyType, WireError> {
    match b {
        0 => Ok(KeyType::U32),
        1 => Ok(KeyType::U64),
        2 => Ok(KeyType::I32),
        3 => Ok(KeyType::I64),
        4 => Ok(KeyType::F32),
        other => Err(WireError::Malformed(format!("unknown key type {other}"))),
    }
}

/// Serialize typed keys to little-endian bytes (`f32` by raw IEEE bit
/// pattern, so NaN payload bits survive the round trip exactly).
pub fn key_data_to_bytes(keys: &KeyData) -> Vec<u8> {
    match keys {
        KeyData::U32(v) => {
            let mut out = Vec::with_capacity(v.len() * 4);
            for k in v {
                out.extend_from_slice(&k.to_le_bytes());
            }
            out
        }
        KeyData::U64(v) => {
            let mut out = Vec::with_capacity(v.len() * 8);
            for k in v {
                out.extend_from_slice(&k.to_le_bytes());
            }
            out
        }
        KeyData::I32(v) => {
            let mut out = Vec::with_capacity(v.len() * 4);
            for k in v {
                out.extend_from_slice(&k.to_le_bytes());
            }
            out
        }
        KeyData::I64(v) => {
            let mut out = Vec::with_capacity(v.len() * 8);
            for k in v {
                out.extend_from_slice(&k.to_le_bytes());
            }
            out
        }
        KeyData::F32(v) => {
            let mut out = Vec::with_capacity(v.len() * 4);
            for k in v {
                // Inherent f32::to_bits — raw IEEE-754 bits, not the
                // SortKey order-preserving mapping.
                out.extend_from_slice(&k.to_bits().to_le_bytes());
            }
            out
        }
    }
}

/// Deserialize typed keys from little-endian bytes. The byte count must
/// be an exact multiple of the key width.
pub fn key_data_from_bytes(kt: KeyType, bytes: &[u8]) -> Result<KeyData, WireError> {
    let width = kt.width_bytes();
    if bytes.len() % width != 0 {
        return Err(WireError::Malformed(format!(
            "{} key bytes are not a multiple of width {width}",
            bytes.len()
        )));
    }
    Ok(match kt {
        KeyType::U32 => KeyData::U32(
            bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        KeyType::U64 => KeyData::U64(
            bytes
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                .collect(),
        ),
        KeyType::I32 => KeyData::I32(
            bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ),
        KeyType::I64 => KeyData::I64(
            bytes
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                .collect(),
        ),
        KeyType::F32 => KeyData::F32(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
                .collect(),
        ),
    })
}

/// Serialize a `u64` payload vector to little-endian bytes.
pub fn payload_to_bytes(payload: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() * 8);
    for p in payload {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out
}

/// Deserialize a `u64` payload vector from little-endian bytes.
pub fn payload_from_bytes(bytes: &[u8]) -> Result<Vec<u64>, WireError> {
    if bytes.len() % 8 != 0 {
        return Err(WireError::Malformed(format!(
            "{} payload bytes are not a multiple of 8",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

fn engine_to_u8(e: crate::config::EngineKind) -> u8 {
    match e {
        crate::config::EngineKind::Native => 0,
        crate::config::EngineKind::Sim => 1,
        crate::config::EngineKind::Pjrt => 2,
        crate::config::EngineKind::Sharded => 3,
    }
}

fn engine_from_u8(b: u8) -> Result<crate::config::EngineKind, WireError> {
    match b {
        0 => Ok(crate::config::EngineKind::Native),
        1 => Ok(crate::config::EngineKind::Sim),
        2 => Ok(crate::config::EngineKind::Pjrt),
        3 => Ok(crate::config::EngineKind::Sharded),
        other => Err(WireError::Malformed(format!("unknown engine tag {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc_known_vector() {
        // The canonical IEEE CRC32 check value.
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[b"1234", b"56789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[]), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let f = Frame {
            opcode: Opcode::KeyChunk,
            flags: FLAG_LAST,
            id: 42,
            payload: vec![1, 2, 3, 4, 5],
        };
        let bytes = encode_frame(&f);
        assert_eq!(bytes.len(), HEADER_LEN + 5);
        let (back, used) = decode_frame(&bytes, 1 << 20).unwrap();
        assert_eq!(back, f);
        assert_eq!(used, bytes.len());
        // Streaming path agrees.
        let mut cur = std::io::Cursor::new(bytes);
        assert_eq!(read_frame(&mut cur, 1 << 20).unwrap().unwrap(), f);
        assert!(read_frame(&mut cur, 1 << 20).unwrap().is_none());
    }

    #[test]
    fn decoder_rejects_corruption() {
        let f = Frame::control(Opcode::Ping, 7);
        let good = encode_frame(&f);

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(
            decode_frame(&bad, 1 << 20),
            Err(WireError::BadMagic)
        ));

        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(
            decode_frame(&bad, 1 << 20),
            Err(WireError::BadVersion(99))
        ));

        let mut bad = good.clone();
        bad[8] ^= 0xFF; // id byte: caught by CRC
        assert!(matches!(decode_frame(&bad, 1 << 20), Err(WireError::BadCrc)));

        // Oversized length prefix rejected before allocation.
        let mut bad = good.clone();
        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_frame(&bad, 1 << 20),
            Err(WireError::Oversized { .. })
        ));

        // Truncation at every prefix length.
        for cut in 0..good.len() {
            assert!(matches!(
                decode_frame(&good[..cut], 1 << 20),
                Err(WireError::Truncated)
            ));
        }
    }

    #[test]
    fn mid_frame_eof_is_truncated() {
        let f = Frame {
            opcode: Opcode::KeyChunk,
            flags: 0,
            id: 1,
            payload: vec![9; 100],
        };
        let bytes = encode_frame(&f);
        let mut cur = std::io::Cursor::new(&bytes[..50]);
        assert!(matches!(
            read_frame(&mut cur, 1 << 20),
            Err(WireError::Truncated)
        ));
    }

    #[test]
    fn message_roundtrips() {
        let begin = SortBeginMsg {
            key_type: KeyType::F32,
            descending: true,
            self_check: false,
            has_payload: true,
            total_keys: 12345,
            tag: Some("bench".into()),
        };
        assert_eq!(SortBeginMsg::decode(&begin.encode()).unwrap(), begin);

        let header = SortHeaderMsg {
            key_type: KeyType::U64,
            total_keys: 99,
            has_payload: false,
            engine: crate::config::EngineKind::Native,
            worker: 3,
            batch_size: 7,
            queue_ms: 0.25,
            service_ms: 1.5,
            tag: None,
        };
        assert_eq!(SortHeaderMsg::decode(&header.encode()).unwrap(), header);

        let err = ErrorMsg {
            code: ErrorCode::Busy,
            message: "queue full — backpressure".into(),
        };
        assert_eq!(ErrorMsg::decode(&err.encode()).unwrap(), err);

        let hello = HelloMsg {
            max_frame_len: 4096,
            session: 0xDEAD_BEEF_F00D,
        };
        assert_eq!(HelloMsg::decode(&hello.encode()).unwrap(), hello);
        let ack = HelloAckMsg {
            credits: 8,
            max_frame_len: 1 << 20,
            max_request_keys: 1 << 26,
        };
        assert_eq!(HelloAckMsg::decode(&ack.encode()).unwrap(), ack);
        let credit = CreditMsg { credits: 2 };
        assert_eq!(CreditMsg::decode(&credit.encode()).unwrap(), credit);
    }

    #[test]
    fn registry_message_roundtrips() {
        let reg = RegisterMsg {
            addr: "10.0.0.7:4750".into(),
        };
        assert_eq!(RegisterMsg::decode(&reg.encode()).unwrap(), reg);

        let hb = HeartbeatMsg {
            addr: "10.0.0.7:4750".into(),
            inflight: 3,
            credit_headroom: 13,
        };
        assert_eq!(HeartbeatMsg::decode(&hb.encode()).unwrap(), hb);

        let ack = RegisterAckMsg {
            heartbeat_ms: 100,
            lease_ms: 600,
        };
        assert_eq!(RegisterAckMsg::decode(&ack.encode()).unwrap(), ack);

        let list = NodeListMsg {
            nodes: vec![
                NodeEntry {
                    addr: "a:1".into(),
                    inflight: 0,
                    credit_headroom: 16,
                },
                NodeEntry {
                    addr: "b:2".into(),
                    inflight: 9,
                    credit_headroom: 0,
                },
            ],
        };
        assert_eq!(NodeListMsg::decode(&list.encode()).unwrap(), list);
        let empty = NodeListMsg { nodes: vec![] };
        assert_eq!(NodeListMsg::decode(&empty.encode()).unwrap(), empty);
        // Truncated entry tables are malformed, not a panic.
        let mut bytes = list.encode();
        bytes.truncate(bytes.len() - 3);
        assert!(NodeListMsg::decode(&bytes).is_err());
    }

    #[test]
    fn key_bytes_roundtrip_bitwise() {
        let data = KeyData::F32(vec![0.5, -0.0, f32::NAN, f32::INFINITY, -3.25]);
        let bytes = key_data_to_bytes(&data);
        let back = key_data_from_bytes(KeyType::F32, &bytes).unwrap();
        // NaN != NaN under PartialEq, so compare the byte images.
        assert_eq!(key_data_to_bytes(&back), bytes);
        assert!(key_data_from_bytes(KeyType::U64, &bytes[..12]).is_err());

        let p = vec![u64::MAX, 0, 42];
        assert_eq!(payload_from_bytes(&payload_to_bytes(&p)).unwrap(), p);
        assert!(payload_from_bytes(&[1, 2, 3]).is_err());
    }

    #[test]
    fn chunking_marks_last() {
        let frames = chunk_frames(Opcode::KeyChunk, 5, &[0u8; 10], 4);
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0].payload.len(), 4);
        assert_eq!(frames[2].payload.len(), 2);
        assert_eq!(frames[0].flags & FLAG_LAST, 0);
        assert_eq!(frames[2].flags & FLAG_LAST, FLAG_LAST);
        assert!(chunk_frames(Opcode::KeyChunk, 5, &[], 4).is_empty());
    }

    #[test]
    fn error_mapping_is_symmetric_enough() {
        let busy = Error::Busy("queue full — backpressure".into());
        assert_eq!(classify_error(&busy), ErrorCode::Busy);
        let back = error_from_wire(ErrorCode::Busy, busy.to_string());
        assert!(back.is_busy());
        assert!(back.to_string().contains("backpressure"));
        assert_eq!(
            classify_error(&Error::Coordinator("service stopped".into())),
            ErrorCode::Shutdown
        );
        assert_eq!(
            classify_error(&Error::Runtime("boom".into())),
            ErrorCode::Internal
        );
        assert_eq!(
            classify_error(&Error::Timeout("50 ms deadline".into())),
            ErrorCode::Timeout
        );
        assert!(matches!(
            error_from_wire(ErrorCode::Timeout, "50 ms deadline".into()),
            Error::Timeout(_)
        ));
        for code in ErrorCode::ALL {
            // Wire tags round-trip.
            assert_eq!(ErrorCode::from_u8(code.to_u8()).unwrap(), code);
        }
    }
}

//! TCP client for a remote sort server: a pool of pipelined
//! connections behind the same `submit`/`sort` surface as the
//! in-process [`SortClient`](crate::coordinator::SortClient).
//!
//! Each pooled connection runs one reader thread and keeps many
//! requests in flight (pipelining) up to the credit window the server
//! granted at handshake — `submit` blocks only when every credit of
//! the chosen connection is spent, which mirrors the service's bounded
//! admission queue ("the client cannot out-run the scheduler"). Remote
//! failures come back as the *same* typed [`Error`] classes as
//! in-process ones: a load-shed is [`Error::Busy`], an oversized
//! request [`Error::TooLarge`], a drain-time rejection a
//! "service stopped"-style [`Error::Coordinator`].
//!
//! # Disconnects and recovery
//!
//! A connection that dies with requests in flight fails them with a
//! typed [`Error::ConnectionLost`] naming every lost request id — the
//! caller knows exactly what was pending, not just that "something
//! closed". With [`ClientOptions::reconnect`] the client instead
//! recovers end to end: the reader thread that observes the dead
//! socket reconnects with capped exponential backoff
//! ([`Backoff::RECONNECT`]) and *resubmits* every in-flight request on
//! the new socket under its original wire id, reusing the original
//! response channels — callers blocked in [`NetClient::sort`] never
//! notice. Request ids are allocated client-wide (unique across
//! reconnects) and the handshake carries a per-client session id, so
//! the server's dedup window can replay responses it already
//! completed instead of re-executing; a re-execution is byte-identical
//! anyway (sorting is deterministic), which is what makes blind
//! resubmission idempotent.

use super::credit::CreditGate;
use super::wire::{
    chunk_frames, encode_frame, error_from_wire, key_data_from_bytes, key_data_to_bytes,
    payload_from_bytes, payload_to_bytes, read_frame, write_frame, CreditMsg, ErrorMsg, Frame,
    HelloAckMsg, HelloMsg, Opcode, SortBeginMsg, SortHeaderMsg,
};
use crate::config::NetConfig;
use crate::coordinator::{SortRequest, SortResponse};
use crate::error::{Error, Result};
use crate::sim::fault::FaultInjector;
use crate::util::backoff::{self, Backoff};
use crate::util::sync::{
    self as sync, lock_unpoisoned, Arc, AtomicBool, AtomicU64, AtomicUsize, Mutex, Ordering,
};
use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc;

use sync::thread::JoinHandle;

/// How many times a dead slot is re-dialed (with [`Backoff::RECONNECT`]
/// pacing) before its in-flight requests fail with
/// [`Error::ConnectionLost`].
const RECONNECT_MAX_ATTEMPTS: u32 = 5;

/// How many reconnects a single request may ride through before it
/// fails instead of resubmitting again (guards against a server that
/// accepts connections only to drop them mid-request forever).
const MAX_RESUBMITS: u32 = 3;

/// Response channel of one in-flight sort.
type SortSender = mpsc::Sender<Result<SortResponse>>;

/// One request awaiting frames from the server.
enum Pending {
    /// An in-flight sort: response frames accumulate here until
    /// `ResultEnd` (or an error frame) resolves the oneshot.
    Sort {
        tx: SortSender,
        /// The submitted request, kept only when reconnection is on —
        /// it is what gets resubmitted on the replacement socket.
        request: Option<SortRequest>,
        /// Reconnects this request has already ridden through.
        attempts: u32,
        header: Option<SortHeaderMsg>,
        key_bytes: Vec<u8>,
        payload_bytes: Vec<u8>,
    },
    /// A control round trip (`Ping`→`Pong`, `Drain`→`DrainAck`).
    Control(mpsc::Sender<()>),
}

/// Client-wide state shared by every connection (and every replacement
/// connection): the dial target, the session identity, the request-id
/// allocator and the recovery counters.
struct ClientShared {
    addr: String,
    net: NetConfig,
    /// Nonzero session id sent in every `Hello`; keys the server's
    /// idempotency window together with the request id.
    session: u64,
    reconnect: bool,
    /// Probed at the socket-cut / frame-corrupt injection points.
    faults: Option<Arc<FaultInjector>>,
    /// Request ids are allocated here — client-wide, so an id is never
    /// reused across reconnects (the server dedup window depends on
    /// that).
    next_id: AtomicU64,
    reconnects: AtomicU64,
    resubmits: AtomicU64,
}

/// Recovery/fault options for [`NetClient::connect_with`].
#[derive(Clone, Debug, Default)]
pub struct ClientOptions {
    /// Reconnect dead connections automatically (capped exponential
    /// backoff) and idempotently resubmit in-flight requests on the
    /// replacement socket. Off by default: plain
    /// [`NetClient::connect`] fails in-flight requests with a typed
    /// [`Error::ConnectionLost`] instead.
    pub reconnect: bool,
    /// Optional fault injector probed before each submission write
    /// (`socket_cut`, `frame_corrupt` points). Chaos tests pass the
    /// service's own injector here so client-side injections land in
    /// the same `fault_injected_*` totals the service exports.
    pub faults: Option<Arc<FaultInjector>>,
}

/// The pending-request table and the liveness flag, behind one mutex.
/// The credit window lives in the connection's [`CreditGate`], which
/// keeps its *own* dead flag — retirement sets this one first (so
/// in-flight `submit`s re-checking under this lock bounce), then kills
/// the gate (so blocked credit waiters wake with a refusal).
struct ConnState {
    dead: bool,
    pending: HashMap<u64, Pending>,
}

/// One pool slot: holds the slot's live connection (if any) and is the
/// lock recovery and submission serialize on when replacing it.
struct Slot {
    index: usize,
    shared: Arc<ClientShared>,
    conn: Mutex<Option<Arc<Conn>>>,
}

struct Conn {
    shared: Arc<ClientShared>,
    /// Slot index — the `target` the fault plan's `socket_cut` /
    /// `frame_corrupt` rules match on.
    index: usize,
    /// Kept for `Shutdown::Both` on close (unblocks the reader).
    stream: TcpStream,
    writer: Mutex<TcpStream>,
    state: Mutex<ConnState>,
    /// Admission credits granted by the server's handshake.
    gate: CreditGate,
    /// Request chunk size: ours clamped to the server's frame ceiling.
    chunk: usize,
    max_frame_len: usize,
    /// Set by an orderly [`Conn::close`] so the reader's recovery pass
    /// knows not to reconnect.
    closing: AtomicBool,
    reader: Mutex<Option<JoinHandle<()>>>,
}

impl Conn {
    /// Dial, handshake and spawn the reader. The caller installs the
    /// returned connection into `slot` — the reader's recovery pass
    /// serializes on the slot lock, so open-then-install races resolve
    /// there.
    fn open(slot: &Arc<Slot>) -> Result<Arc<Conn>> {
        let shared = &slot.shared;
        let stream = TcpStream::connect(&shared.addr)?;
        let _ = stream.set_nodelay(true);
        let mut write_half = stream.try_clone()?;
        // Synchronous handshake before the reader thread exists.
        write_frame(
            &mut write_half,
            &Frame::message(
                Opcode::Hello,
                0,
                HelloMsg {
                    max_frame_len: shared.net.max_frame_len as u32,
                    session: shared.session,
                }
                .encode(),
            ),
        )?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let frame = read_frame(&mut reader, shared.net.max_frame_len)?
            .ok_or_else(|| Error::Coordinator("server closed during handshake".into()))?;
        let ack = match frame.opcode {
            Opcode::HelloAck => HelloAckMsg::decode(&frame.payload)?,
            Opcode::ErrorFrame => {
                let msg = ErrorMsg::decode(&frame.payload)?;
                return Err(error_from_wire(msg.code, msg.message));
            }
            other => {
                return Err(Error::Coordinator(format!(
                    "unexpected handshake reply {other:?}"
                )))
            }
        };
        let conn = Arc::new(Conn {
            shared: shared.clone(),
            index: slot.index,
            stream,
            writer: Mutex::new(write_half),
            state: Mutex::new(ConnState {
                dead: false,
                pending: HashMap::new(),
            }),
            gate: CreditGate::new(ack.credits),
            chunk: shared
                .net
                .chunk_bytes
                .min((ack.max_frame_len as usize).max(64))
                .max(1),
            max_frame_len: shared.net.max_frame_len,
            closing: AtomicBool::new(false),
            reader: Mutex::new(None),
        });
        let rd_conn = conn.clone();
        let rd_slot = slot.clone();
        let handle = sync::thread::spawn_named("gbs-net-client".into(), move || {
            reader_loop(&rd_conn, reader);
            recover(&rd_slot, &rd_conn);
        });
        *lock_unpoisoned(&conn.reader) = Some(handle);
        Ok(conn)
    }

    fn is_dead(&self) -> bool {
        lock_unpoisoned(&self.state).dead
    }

    /// Block until an admission credit is free (or the connection dies).
    fn acquire_credit(&self) -> Result<()> {
        if self.gate.acquire() {
            Ok(())
        } else {
            Err(Error::Coordinator("connection closed".into()))
        }
    }

    /// Mark the connection dead, kill the credit gate and hand back
    /// every pending entry. Idempotent: a second caller gets nothing.
    fn retire(&self) -> Vec<(u64, Pending)> {
        // Order matters: the state flag first (so a `submit` that
        // already holds a credit bounces at its re-check), then the
        // gate kill (so blocked credit waiters wake with a refusal).
        let mut st = lock_unpoisoned(&self.state);
        st.dead = true;
        let entries: Vec<(u64, Pending)> = st.pending.drain().collect();
        drop(st);
        self.gate.kill();
        entries
    }

    /// Retire and fail every pending sort with a typed
    /// [`Error::ConnectionLost`] naming all lost ids; control waiters
    /// resolve by sender drop.
    fn fail_disconnected(&self) {
        fail_with_connection_lost(self.retire());
    }

    fn submit(&self, request: SortRequest) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        request.validate()?;
        self.acquire_credit()?;
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.send_request(id, request, &tx, 0)?;
        Ok(rx)
    }

    /// Resubmission path of the recovery pass: same wire id, original
    /// response channel, bumped attempt counter.
    fn resubmit(&self, id: u64, request: SortRequest, tx: &SortSender, attempts: u32) -> Result<()> {
        self.acquire_credit()?;
        self.send_request(id, request, tx, attempts)
    }

    /// Register `id` in the pending table and stream the submission
    /// frames (begin + chunks + commit in one buffered write, so they
    /// never interleave with another thread's frames).
    fn send_request(
        &self,
        id: u64,
        request: SortRequest,
        tx: &SortSender,
        attempts: u32,
    ) -> Result<()> {
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.dead {
                return Err(Error::Coordinator("connection closed".into()));
            }
            st.pending.insert(
                id,
                Pending::Sort {
                    tx: tx.clone(),
                    request: self.shared.reconnect.then(|| request.clone()),
                    attempts,
                    header: None,
                    key_bytes: Vec::new(),
                    payload_bytes: Vec::new(),
                },
            );
        }
        let begin = SortBeginMsg {
            key_type: request.keys.key_type(),
            descending: request.descending,
            self_check: request.self_check,
            has_payload: request.payload.is_some(),
            total_keys: request.keys.len() as u64,
            tag: request.tag.clone(),
        };
        let mut buf = encode_frame(&Frame::message(Opcode::SortBegin, id, begin.encode()));
        for f in chunk_frames(
            Opcode::KeyChunk,
            id,
            &key_data_to_bytes(&request.keys),
            self.chunk,
        ) {
            buf.extend_from_slice(&encode_frame(&f));
        }
        if let Some(p) = &request.payload {
            for f in chunk_frames(Opcode::PayloadChunk, id, &payload_to_bytes(p), self.chunk) {
                buf.extend_from_slice(&encode_frame(&f));
            }
        }
        buf.extend_from_slice(&encode_frame(&Frame::control(Opcode::Commit, id)));
        // Fault probes, in wire order: corrupt one byte of the
        // submission (the server's CRC check rejects it and closes the
        // connection with a typed error) or cut the socket outright.
        // Both drive the full disconnect→reconnect→resubmit path.
        if let Some(inj) = &self.shared.faults {
            if inj.frame_corrupt(self.index) {
                if let Some(last) = buf.last_mut() {
                    *last ^= 0xFF;
                }
            }
            if inj.socket_cut(self.index) {
                let _ = self.stream.shutdown(Shutdown::Both);
            }
        }
        let wrote = {
            let mut w = lock_unpoisoned(&self.writer);
            w.write_all(&buf)
        };
        if let Err(e) = wrote {
            if self.shared.reconnect {
                // Leave the request pending: the reader observes the
                // dead socket and the recovery pass resubmits it on
                // the replacement connection.
                let _ = self.stream.shutdown(Shutdown::Both);
                return Ok(());
            }
            self.fail_disconnected();
            return Err(Error::Io(e));
        }
        Ok(())
    }

    /// A control round trip: send `opcode`, wait for its echo-id reply.
    fn control(&self, opcode: Opcode) -> Result<()> {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.dead {
                return Err(Error::Coordinator("connection closed".into()));
            }
            st.pending.insert(id, Pending::Control(tx));
        }
        let wrote = {
            let mut w = lock_unpoisoned(&self.writer);
            w.write_all(&encode_frame(&Frame::control(opcode, id)))
        };
        if let Err(e) = wrote {
            self.fail_disconnected();
            return Err(Error::Io(e));
        }
        rx.recv()
            .map_err(|_| Error::Coordinator("connection closed".into()))
    }

    fn close(&self) {
        // Orderly close: flag first, so the reader's recovery pass
        // fails any stragglers instead of reconnecting.
        self.closing.store(true, Ordering::SeqCst);
        {
            // Best-effort orderly goodbye; the socket shutdown below is
            // what actually unblocks the reader.
            let mut w = lock_unpoisoned(&self.writer);
            let _ = w.write_all(&encode_frame(&Frame::control(Opcode::Goodbye, 0)));
        }
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = lock_unpoisoned(&self.reader).take() {
            let _ = h.join();
        }
    }
}

/// Fail every pending sort in `entries` with one
/// [`Error::ConnectionLost`] carrying the full list of lost ids.
fn fail_with_connection_lost(entries: Vec<(u64, Pending)>) {
    let ids: Vec<u64> = entries
        .iter()
        .filter(|(_, p)| matches!(p, Pending::Sort { .. }))
        .map(|(id, _)| *id)
        .collect();
    for (_, p) in entries {
        if let Pending::Sort { tx, .. } = p {
            let _ = tx.send(Err(Error::ConnectionLost {
                request_ids: ids.clone(),
            }));
        }
        // Control entries resolve by sender drop (RecvError).
    }
}

fn reader_loop(conn: &Arc<Conn>, mut reader: BufReader<TcpStream>) {
    loop {
        match read_frame(&mut reader, conn.max_frame_len) {
            Ok(Some(frame)) => {
                if handle_frame(conn, frame).is_err() {
                    break;
                }
            }
            Ok(None) => break,
            Err(_) => break,
        }
    }
}

/// Reader-exit recovery: retire the dead connection, then either
/// reconnect-and-resubmit (when [`ClientOptions::reconnect`] is on) or
/// fail every in-flight request with a typed
/// [`Error::ConnectionLost`].
fn recover(slot: &Arc<Slot>, dead: &Arc<Conn>) {
    let entries = dead.retire();
    let shared = &slot.shared;
    if dead.closing.load(Ordering::SeqCst) || !shared.reconnect {
        fail_with_connection_lost(entries);
        return;
    }
    let mut sorts = Vec::new();
    let mut kept: Vec<(u64, Pending)> = Vec::new();
    for (id, p) in entries {
        match p {
            Pending::Sort {
                tx,
                request: Some(req),
                attempts,
                ..
            } if attempts < MAX_RESUBMITS => sorts.push((id, tx, req, attempts)),
            other => kept.push((id, other)),
        }
    }
    // Entries that cannot ride another reconnect fail now.
    fail_with_connection_lost(kept);
    // Replace the connection, serialized on the slot lock (concurrent
    // submits to this slot wait here instead of racing the re-dial).
    let mut guard = lock_unpoisoned(&slot.conn);
    let target = match guard.as_ref() {
        // Another path (an inline `pick` reconnect) already replaced it.
        Some(c) if !Arc::ptr_eq(c, dead) && !c.is_dead() => c.clone(),
        _ => {
            let mut attempt = 0u32;
            loop {
                if attempt >= RECONNECT_MAX_ATTEMPTS {
                    *guard = None;
                    drop(guard);
                    let entries = sorts
                        .into_iter()
                        .map(|(id, tx, req, attempts)| {
                            (
                                id,
                                Pending::Sort {
                                    tx,
                                    request: Some(req),
                                    attempts,
                                    header: None,
                                    key_bytes: Vec::new(),
                                    payload_bytes: Vec::new(),
                                },
                            )
                        })
                        .collect();
                    fail_with_connection_lost(entries);
                    return;
                }
                backoff::sleep_backoff(&Backoff::RECONNECT, attempt);
                attempt += 1;
                match Conn::open(slot) {
                    Ok(c) => {
                        shared.reconnects.fetch_add(1, Ordering::Relaxed);
                        *guard = Some(c.clone());
                        break c;
                    }
                    Err(_) => continue,
                }
            }
        }
    };
    drop(guard);
    // Idempotent resubmission: same wire id, same request, original
    // response channel. The server's dedup window replays responses it
    // already completed; anything else re-executes — byte-identical
    // either way, because sorting is deterministic.
    for (id, tx, request, attempts) in sorts {
        shared.resubmits.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = target.resubmit(id, request, &tx, attempts + 1) {
            let _ = tx.send(Err(e));
        }
    }
}

/// Dispatch one server frame; `Err` is fatal for the connection.
fn handle_frame(conn: &Conn, frame: Frame) -> Result<()> {
    match frame.opcode {
        Opcode::SortHeader => {
            let hdr = SortHeaderMsg::decode(&frame.payload)?;
            let mut st = lock_unpoisoned(&conn.state);
            if let Some(Pending::Sort { header, .. }) = st.pending.get_mut(&frame.id) {
                *header = Some(hdr);
            }
        }
        Opcode::ResultKeyChunk | Opcode::ResultPayloadChunk => {
            let mut st = lock_unpoisoned(&conn.state);
            if let Some(Pending::Sort {
                key_bytes,
                payload_bytes,
                ..
            }) = st.pending.get_mut(&frame.id)
            {
                if frame.opcode == Opcode::ResultKeyChunk {
                    key_bytes.extend_from_slice(&frame.payload);
                } else {
                    payload_bytes.extend_from_slice(&frame.payload);
                }
            }
        }
        Opcode::ResultEnd => {
            let entry = lock_unpoisoned(&conn.state).pending.remove(&frame.id);
            if let Some(Pending::Sort {
                tx,
                header,
                key_bytes,
                payload_bytes,
                ..
            }) = entry
            {
                let _ = tx.send(assemble_response(frame.id, header, key_bytes, payload_bytes));
            }
        }
        Opcode::ErrorFrame => {
            let msg = ErrorMsg::decode(&frame.payload)?;
            if frame.id == 0 {
                // Connection-level error: the server is about to close
                // this socket; the recovery pass takes it from here.
                return Err(error_from_wire(msg.code, msg.message));
            }
            let entry = lock_unpoisoned(&conn.state).pending.remove(&frame.id);
            if let Some(Pending::Sort { tx, .. }) = entry {
                let _ = tx.send(Err(error_from_wire(msg.code, msg.message)));
            }
        }
        Opcode::Credit => {
            let msg = CreditMsg::decode(&frame.payload)?;
            conn.gate.grant(msg.credits);
        }
        Opcode::Pong | Opcode::DrainAck => {
            let entry = lock_unpoisoned(&conn.state).pending.remove(&frame.id);
            if let Some(Pending::Control(tx)) = entry {
                let _ = tx.send(());
            }
        }
        // Unknown-but-authentic server frames are ignored for forward
        // compatibility.
        _ => {}
    }
    Ok(())
}

fn assemble_response(
    id: u64,
    header: Option<SortHeaderMsg>,
    key_bytes: Vec<u8>,
    payload_bytes: Vec<u8>,
) -> Result<SortResponse> {
    let header = header.ok_or_else(|| Error::Remote {
        code: "internal".into(),
        message: "result completed without a header".into(),
    })?;
    let keys = key_data_from_bytes(header.key_type, &key_bytes)?;
    if keys.len() as u64 != header.total_keys {
        return Err(Error::Remote {
            code: "internal".into(),
            message: format!(
                "result carried {} keys, header declared {}",
                keys.len(),
                header.total_keys
            ),
        });
    }
    let payload = if header.has_payload {
        Some(payload_from_bytes(&payload_bytes)?)
    } else if payload_bytes.is_empty() {
        None
    } else {
        return Err(Error::Remote {
            code: "internal".into(),
            message: "payload chunks without has_payload".into(),
        });
    };
    Ok(SortResponse {
        id,
        keys,
        payload,
        tag: header.tag,
        engine: header.engine,
        worker: header.worker as usize,
        batch_size: header.batch_size as usize,
        queue_ms: header.queue_ms,
        service_ms: header.service_ms,
    })
}

/// A nonzero session id for the server's idempotency window. Wall-clock
/// nanoseconds mixed with a heap address: two clients of one server
/// would have to collide on both to share a window — and even then the
/// window only ever replays *completed* responses under ids the
/// colliding client resubmits.
fn fresh_session() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x5eed);
    let probe = Box::new(0u8);
    let salt = (&*probe as *const u8) as u64;
    drop(probe);
    (nanos ^ salt.rotate_left(32)) | 1
}

/// A pooled, pipelined client for a remote sort server.
///
/// Requests round-robin across `connections` sockets; each socket
/// pipelines up to its server-granted credit window. Dropping the
/// client sends `Goodbye` on every connection and joins the readers.
pub struct NetClient {
    shared: Arc<ClientShared>,
    slots: Vec<Arc<Slot>>,
    next: AtomicUsize,
}

impl NetClient {
    /// Connect a pool of `connections` (≥ 1) sockets to `addr` (e.g.
    /// `"127.0.0.1:4750"`). `net` carries the client-side frame ceiling
    /// and preferred chunk size; the admission credit window comes from
    /// the server's handshake reply. Reconnection is off: a dead
    /// connection fails its in-flight requests with a typed
    /// [`Error::ConnectionLost`].
    pub fn connect(addr: &str, connections: usize, net: NetConfig) -> Result<NetClient> {
        Self::connect_with(addr, connections, net, ClientOptions::default())
    }

    /// [`NetClient::connect`] with explicit [`ClientOptions`]
    /// (auto-reconnect, fault injection).
    pub fn connect_with(
        addr: &str,
        connections: usize,
        net: NetConfig,
        opts: ClientOptions,
    ) -> Result<NetClient> {
        net.validate()?;
        let shared = Arc::new(ClientShared {
            addr: addr.to_string(),
            net,
            session: fresh_session(),
            reconnect: opts.reconnect,
            faults: opts.faults,
            next_id: AtomicU64::new(1),
            reconnects: AtomicU64::new(0),
            resubmits: AtomicU64::new(0),
        });
        let mut slots = Vec::new();
        for index in 0..connections.max(1) {
            let slot = Arc::new(Slot {
                index,
                shared: shared.clone(),
                conn: Mutex::new(None),
            });
            let conn = Conn::open(&slot)?;
            *lock_unpoisoned(&slot.conn) = Some(conn);
            slots.push(slot);
        }
        Ok(NetClient {
            shared,
            slots,
            next: AtomicUsize::new(0),
        })
    }

    /// Number of pooled connections.
    pub fn connections(&self) -> usize {
        self.slots.len()
    }

    /// Successful automatic reconnects performed so far.
    pub fn reconnects(&self) -> u64 {
        self.shared.reconnects.load(Ordering::Relaxed)
    }

    /// In-flight requests resubmitted across a reconnect so far.
    pub fn resubmits(&self) -> u64 {
        self.shared.resubmits.load(Ordering::Relaxed)
    }

    fn pick(&self) -> Result<Arc<Conn>> {
        let n = self.slots.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for k in 0..n {
            let slot = &self.slots[(start + k) % n];
            let conn = lock_unpoisoned(&slot.conn).clone();
            if let Some(c) = conn {
                if !c.is_dead() {
                    return Ok(c);
                }
            }
        }
        if self.shared.reconnect {
            // Every connection is down: re-dial one slot inline. The
            // slot lock serializes this with reader-driven recovery —
            // whoever wins installs, the other reuses.
            let slot = &self.slots[start % n];
            let mut guard = lock_unpoisoned(&slot.conn);
            if let Some(c) = guard.as_ref() {
                if !c.is_dead() {
                    return Ok(c.clone());
                }
            }
            let c = Conn::open(slot)?;
            self.shared.reconnects.fetch_add(1, Ordering::Relaxed);
            *guard = Some(c.clone());
            return Ok(c);
        }
        Err(Error::Coordinator("every pooled connection closed".into()))
    }

    /// Submit without blocking on the response; returns the response
    /// channel (same shape as the in-process
    /// [`SortClient::submit`](crate::coordinator::SortClient::submit)).
    /// Blocks only while the chosen connection is out of admission
    /// credits.
    pub fn submit(&self, request: SortRequest) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        self.pick()?.submit(request)
    }

    /// Submit a request and block until its response arrives.
    pub fn sort(&self, request: SortRequest) -> Result<SortResponse> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("connection closed".into()))?
    }

    /// Liveness probe: one `Ping`→`Pong` round trip.
    pub fn ping(&self) -> Result<()> {
        self.pick()?.control(Opcode::Ping)
    }

    /// Ask the server to drain gracefully; returns once the server has
    /// acknowledged (the drain itself proceeds after the ack).
    pub fn drain_server(&self) -> Result<()> {
        self.pick()?.control(Opcode::Drain)
    }
}

impl Drop for NetClient {
    fn drop(&mut self) {
        for slot in &self.slots {
            // Closing a connection joins its reader, whose recovery
            // pass may have installed a replacement meanwhile — close
            // that too. Recovery never reinstalls once `closing` is
            // set on the connection it retired, so this terminates.
            loop {
                let conn = lock_unpoisoned(&slot.conn).take();
                match conn {
                    Some(c) => c.close(),
                    None => break,
                }
            }
        }
    }
}

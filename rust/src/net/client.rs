//! TCP client for a remote sort server: a pool of pipelined
//! connections behind the same `submit`/`sort` surface as the
//! in-process [`SortClient`](crate::coordinator::SortClient).
//!
//! Each pooled connection runs one reader thread and keeps many
//! requests in flight (pipelining) up to the credit window the server
//! granted at handshake — `submit` blocks only when every credit of
//! the chosen connection is spent, which mirrors the service's bounded
//! admission queue ("the client cannot out-run the scheduler"). Remote
//! failures come back as the *same* typed [`Error`] classes as
//! in-process ones: a load-shed is [`Error::Busy`], an oversized
//! request [`Error::TooLarge`], a drain-time rejection a
//! "service stopped"-style [`Error::Coordinator`].

use super::credit::CreditGate;
use super::wire::{
    chunk_frames, encode_frame, error_from_wire, key_data_from_bytes, key_data_to_bytes,
    payload_from_bytes, payload_to_bytes, read_frame, write_frame, CreditMsg, ErrorMsg, Frame,
    HelloAckMsg, HelloMsg, Opcode, SortBeginMsg, SortHeaderMsg,
};
use crate::config::NetConfig;
use crate::coordinator::{SortRequest, SortResponse};
use crate::error::{Error, Result};
use crate::util::sync::{
    self as sync, lock_unpoisoned, Arc, AtomicU64, AtomicUsize, Mutex, Ordering,
};
use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc;

use sync::thread::JoinHandle;

/// One request awaiting frames from the server.
enum Pending {
    /// An in-flight sort: response frames accumulate here until
    /// `ResultEnd` (or an error frame) resolves the oneshot.
    Sort {
        tx: mpsc::Sender<Result<SortResponse>>,
        header: Option<SortHeaderMsg>,
        key_bytes: Vec<u8>,
        payload_bytes: Vec<u8>,
    },
    /// A control round trip (`Ping`→`Pong`, `Drain`→`DrainAck`).
    Control(mpsc::Sender<()>),
}

/// The pending-request table and the liveness flag, behind one mutex.
/// The credit window lives in the connection's [`CreditGate`], which
/// keeps its *own* dead flag — [`Conn::fail_all`] sets this one first
/// (so in-flight `submit`s re-checking under this lock bounce), then
/// kills the gate (so credit waiters wake with a refusal).
struct ConnState {
    dead: bool,
    pending: HashMap<u64, Pending>,
}

struct Conn {
    /// Kept for `Shutdown::Both` on close (unblocks the reader).
    stream: TcpStream,
    writer: Mutex<TcpStream>,
    state: Mutex<ConnState>,
    /// Admission credits granted by the server's handshake.
    gate: CreditGate,
    next_id: AtomicU64,
    /// Request chunk size: ours clamped to the server's frame ceiling.
    chunk: usize,
    max_frame_len: usize,
    reader: Mutex<Option<JoinHandle<()>>>,
}

impl Conn {
    fn open(addr: &str, net: &NetConfig) -> Result<Arc<Conn>> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let mut write_half = stream.try_clone()?;
        // Synchronous handshake before the reader thread exists.
        write_frame(
            &mut write_half,
            &Frame::message(
                Opcode::Hello,
                0,
                HelloMsg {
                    max_frame_len: net.max_frame_len as u32,
                }
                .encode(),
            ),
        )?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let frame = read_frame(&mut reader, net.max_frame_len)?
            .ok_or_else(|| Error::Coordinator("server closed during handshake".into()))?;
        let ack = match frame.opcode {
            Opcode::HelloAck => HelloAckMsg::decode(&frame.payload)?,
            Opcode::ErrorFrame => {
                let msg = ErrorMsg::decode(&frame.payload)?;
                return Err(error_from_wire(msg.code, msg.message));
            }
            other => {
                return Err(Error::Coordinator(format!(
                    "unexpected handshake reply {other:?}"
                )))
            }
        };
        let conn = Arc::new(Conn {
            stream,
            writer: Mutex::new(write_half),
            state: Mutex::new(ConnState {
                dead: false,
                pending: HashMap::new(),
            }),
            gate: CreditGate::new(ack.credits),
            next_id: AtomicU64::new(1),
            chunk: net
                .chunk_bytes
                .min((ack.max_frame_len as usize).max(64))
                .max(1),
            max_frame_len: net.max_frame_len,
            reader: Mutex::new(None),
        });
        let rd_conn = conn.clone();
        let handle = sync::thread::spawn_named("gbs-net-client".into(), move || {
            reader_loop(rd_conn, reader)
        });
        *lock_unpoisoned(&conn.reader) = Some(handle);
        Ok(conn)
    }

    fn is_dead(&self) -> bool {
        lock_unpoisoned(&self.state).dead
    }

    /// Block until an admission credit is free (or the connection dies).
    fn acquire_credit(&self) -> Result<()> {
        if self.gate.acquire() {
            Ok(())
        } else {
            Err(Error::Coordinator("connection closed".into()))
        }
    }

    /// Mark the connection dead and fail every pending request with a
    /// fresh typed error from `mk`; wakes all credit waiters.
    fn fail_all(&self, mk: &dyn Fn() -> Error) {
        // Order matters: the state flag first (so a `submit` that
        // already holds a credit bounces at its re-check), then the
        // gate kill (so blocked credit waiters wake with a refusal).
        let mut st = lock_unpoisoned(&self.state);
        st.dead = true;
        for (_, p) in st.pending.drain() {
            if let Pending::Sort { tx, .. } = p {
                let _ = tx.send(Err(mk()));
            }
            // Control entries resolve by sender drop (RecvError).
        }
        drop(st);
        self.gate.kill();
    }

    fn submit(&self, request: SortRequest) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        request.validate()?;
        self.acquire_credit()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.dead {
                return Err(Error::Coordinator("connection closed".into()));
            }
            st.pending.insert(
                id,
                Pending::Sort {
                    tx,
                    header: None,
                    key_bytes: Vec::new(),
                    payload_bytes: Vec::new(),
                },
            );
        }
        let begin = SortBeginMsg {
            key_type: request.keys.key_type(),
            descending: request.descending,
            self_check: request.self_check,
            has_payload: request.payload.is_some(),
            total_keys: request.keys.len() as u64,
            tag: request.tag.clone(),
        };
        // One buffered write for the whole submission: begin + chunks +
        // commit never interleave with another thread's frames.
        let mut buf = encode_frame(&Frame::message(Opcode::SortBegin, id, begin.encode()));
        for f in chunk_frames(
            Opcode::KeyChunk,
            id,
            &key_data_to_bytes(&request.keys),
            self.chunk,
        ) {
            buf.extend_from_slice(&encode_frame(&f));
        }
        if let Some(p) = &request.payload {
            for f in chunk_frames(Opcode::PayloadChunk, id, &payload_to_bytes(p), self.chunk) {
                buf.extend_from_slice(&encode_frame(&f));
            }
        }
        buf.extend_from_slice(&encode_frame(&Frame::control(Opcode::Commit, id)));
        let wrote = {
            let mut w = lock_unpoisoned(&self.writer);
            w.write_all(&buf)
        };
        if let Err(e) = wrote {
            self.fail_all(&|| Error::Coordinator("connection closed".into()));
            return Err(Error::Io(e));
        }
        Ok(rx)
    }

    /// A control round trip: send `opcode`, wait for its echo-id reply.
    fn control(&self, opcode: Opcode) -> Result<()> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_unpoisoned(&self.state);
            if st.dead {
                return Err(Error::Coordinator("connection closed".into()));
            }
            st.pending.insert(id, Pending::Control(tx));
        }
        let wrote = {
            let mut w = lock_unpoisoned(&self.writer);
            w.write_all(&encode_frame(&Frame::control(opcode, id)))
        };
        if let Err(e) = wrote {
            self.fail_all(&|| Error::Coordinator("connection closed".into()));
            return Err(Error::Io(e));
        }
        rx.recv()
            .map_err(|_| Error::Coordinator("connection closed".into()))
    }

    fn close(&self) {
        {
            // Best-effort orderly goodbye; the socket shutdown below is
            // what actually unblocks the reader.
            let mut w = lock_unpoisoned(&self.writer);
            let _ = w.write_all(&encode_frame(&Frame::control(Opcode::Goodbye, 0)));
        }
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = lock_unpoisoned(&self.reader).take() {
            let _ = h.join();
        }
    }
}

fn reader_loop(conn: Arc<Conn>, mut reader: BufReader<TcpStream>) {
    let fatal: String = loop {
        match read_frame(&mut reader, conn.max_frame_len) {
            Ok(Some(frame)) => {
                if let Err(e) = handle_frame(&conn, frame) {
                    break e.to_string();
                }
            }
            Ok(None) => break "connection closed".into(),
            Err(e) => break format!("connection failed: {e}"),
        }
    };
    conn.fail_all(&|| Error::Coordinator(fatal.clone()));
}

/// Dispatch one server frame; `Err` is fatal for the connection.
fn handle_frame(conn: &Conn, frame: Frame) -> Result<()> {
    match frame.opcode {
        Opcode::SortHeader => {
            let hdr = SortHeaderMsg::decode(&frame.payload)?;
            let mut st = lock_unpoisoned(&conn.state);
            if let Some(Pending::Sort { header, .. }) = st.pending.get_mut(&frame.id) {
                *header = Some(hdr);
            }
        }
        Opcode::ResultKeyChunk | Opcode::ResultPayloadChunk => {
            let mut st = lock_unpoisoned(&conn.state);
            if let Some(Pending::Sort {
                key_bytes,
                payload_bytes,
                ..
            }) = st.pending.get_mut(&frame.id)
            {
                if frame.opcode == Opcode::ResultKeyChunk {
                    key_bytes.extend_from_slice(&frame.payload);
                } else {
                    payload_bytes.extend_from_slice(&frame.payload);
                }
            }
        }
        Opcode::ResultEnd => {
            let entry = lock_unpoisoned(&conn.state).pending.remove(&frame.id);
            if let Some(Pending::Sort {
                tx,
                header,
                key_bytes,
                payload_bytes,
            }) = entry
            {
                let _ = tx.send(assemble_response(frame.id, header, key_bytes, payload_bytes));
            }
        }
        Opcode::ErrorFrame => {
            let msg = ErrorMsg::decode(&frame.payload)?;
            if frame.id == 0 {
                // Connection-level error: the server is about to close
                // this socket; surface the typed failure everywhere.
                return Err(error_from_wire(msg.code, msg.message));
            }
            let entry = lock_unpoisoned(&conn.state).pending.remove(&frame.id);
            if let Some(Pending::Sort { tx, .. }) = entry {
                let _ = tx.send(Err(error_from_wire(msg.code, msg.message)));
            }
        }
        Opcode::Credit => {
            let msg = CreditMsg::decode(&frame.payload)?;
            conn.gate.grant(msg.credits);
        }
        Opcode::Pong | Opcode::DrainAck => {
            let entry = lock_unpoisoned(&conn.state).pending.remove(&frame.id);
            if let Some(Pending::Control(tx)) = entry {
                let _ = tx.send(());
            }
        }
        // Unknown-but-authentic server frames are ignored for forward
        // compatibility.
        _ => {}
    }
    Ok(())
}

fn assemble_response(
    id: u64,
    header: Option<SortHeaderMsg>,
    key_bytes: Vec<u8>,
    payload_bytes: Vec<u8>,
) -> Result<SortResponse> {
    let header = header.ok_or_else(|| Error::Remote {
        code: "internal".into(),
        message: "result completed without a header".into(),
    })?;
    let keys = key_data_from_bytes(header.key_type, &key_bytes)?;
    if keys.len() as u64 != header.total_keys {
        return Err(Error::Remote {
            code: "internal".into(),
            message: format!(
                "result carried {} keys, header declared {}",
                keys.len(),
                header.total_keys
            ),
        });
    }
    let payload = if header.has_payload {
        Some(payload_from_bytes(&payload_bytes)?)
    } else if payload_bytes.is_empty() {
        None
    } else {
        return Err(Error::Remote {
            code: "internal".into(),
            message: "payload chunks without has_payload".into(),
        });
    };
    Ok(SortResponse {
        id,
        keys,
        payload,
        tag: header.tag,
        engine: header.engine,
        worker: header.worker as usize,
        batch_size: header.batch_size as usize,
        queue_ms: header.queue_ms,
        service_ms: header.service_ms,
    })
}

/// A pooled, pipelined client for a remote sort server.
///
/// Requests round-robin across `connections` sockets; each socket
/// pipelines up to its server-granted credit window. Dropping the
/// client sends `Goodbye` on every connection and joins the readers.
pub struct NetClient {
    conns: Vec<Arc<Conn>>,
    next: AtomicUsize,
}

impl NetClient {
    /// Connect a pool of `connections` (≥ 1) sockets to `addr` (e.g.
    /// `"127.0.0.1:4750"`). `net` carries the client-side frame ceiling
    /// and preferred chunk size; the admission credit window comes from
    /// the server's handshake reply.
    pub fn connect(addr: &str, connections: usize, net: NetConfig) -> Result<NetClient> {
        net.validate()?;
        let mut conns = Vec::new();
        for _ in 0..connections.max(1) {
            conns.push(Conn::open(addr, &net)?);
        }
        Ok(NetClient {
            conns,
            next: AtomicUsize::new(0),
        })
    }

    /// Number of pooled connections.
    pub fn connections(&self) -> usize {
        self.conns.len()
    }

    fn pick(&self) -> Result<&Arc<Conn>> {
        let n = self.conns.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for k in 0..n {
            let c = &self.conns[(start + k) % n];
            if !c.is_dead() {
                return Ok(c);
            }
        }
        Err(Error::Coordinator("every pooled connection closed".into()))
    }

    /// Submit without blocking on the response; returns the response
    /// channel (same shape as the in-process
    /// [`SortClient::submit`](crate::coordinator::SortClient::submit)).
    /// Blocks only while the chosen connection is out of admission
    /// credits.
    pub fn submit(&self, request: SortRequest) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        self.pick()?.submit(request)
    }

    /// Submit a request and block until its response arrives.
    pub fn sort(&self, request: SortRequest) -> Result<SortResponse> {
        let rx = self.submit(request)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("connection closed".into()))?
    }

    /// Liveness probe: one `Ping`→`Pong` round trip.
    pub fn ping(&self) -> Result<()> {
        self.pick()?.control(Opcode::Ping)
    }

    /// Ask the server to drain gracefully; returns once the server has
    /// acknowledged (the drain itself proceeds after the ack).
    pub fn drain_server(&self) -> Result<()> {
        self.pick()?.control(Opcode::Drain)
    }
}

impl Drop for NetClient {
    fn drop(&mut self) {
        for c in &self.conns {
            c.close();
        }
    }
}

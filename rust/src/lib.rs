//! # GPU Bucket Sort — Deterministic Sample Sort For GPUs
//!
//! A full reproduction of *Dehne & Zaboli, "Deterministic Sample Sort
//! For GPUs" (2010)* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the sort *service*: request router, dynamic
//!   batcher, phase scheduler over a pool of "virtual SMs", a PJRT runtime
//!   that executes AOT-compiled JAX/Pallas artifacts, a GPU cost-model
//!   simulator calibrated to the paper's Table 1 hardware, native
//!   implementations of GPU Bucket Sort and all the paper's baselines
//!   (randomized sample sort, Thrust Merge, radix), the six input
//!   distributions of Leischner et al., and the benchmark harness that
//!   regenerates every table and figure of the paper.
//! * **L2 (python/compile/model.py)** — Algorithm 1 as a jitted JAX
//!   pipeline, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (tile bitonic sort, bucket ranks, prefix sums, relocation).
//!
//! Python never runs on the request path: `make artifacts` emits
//! `artifacts/*.hlo.txt` once; the rust binary is then self-contained.
//!
//! Beyond the paper, [`algos::sharded`] shards one sort across a
//! [`sim::DevicePool`] of heterogeneous simulated GPUs — the same
//! deterministic splitter discipline applied between devices — which
//! removes the single-device memory ceilings of Figures 6 & 7 (≥ 512M
//! keys over a 4-device pool). It serves requests as the coordinator's
//! `sharded` engine.
//!
//! Sorting is **typed**: the comparison-based algorithms are generic
//! over [`SortKey`] (`u32`, `u64`, `i32`, `i64`, `f32` under IEEE-754
//! total order) and carry optional key–value payloads through the
//! rank/relocation machinery via [`Record`]; see [`key`] and the
//! coordinator's `SortRequest` builder. The classic `u32`, key-only
//! path is the `SortKey` special case with identity bit mapping and is
//! byte-identical to the pre-typed API.
//!
//! The full request path (client → batcher → multi-worker scheduler →
//! engines → sim ledger → cost model), the Execute vs. Analytic
//! accounting modes, and the sharded-sort design are documented in
//! `docs/ARCHITECTURE.md`; the repository README covers the layer map
//! and quickstart commands.
//!
//! ## Quick start
//!
//! ```no_run
//! use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
//! use gpu_bucket_sort::sim::{GpuSim, GpuModel};
//!
//! let mut keys: Vec<u32> = (0..10_000u32).rev().collect();
//! let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
//! let sorter = BucketSort::new(BucketSortParams::default());
//! let report = sorter.sort(&mut keys, &mut sim).unwrap();
//! assert!(keys.windows(2).all(|w| w[0] <= w[1]));
//! assert!(report.total_estimated_ms(sim.spec()) > 0.0);
//! ```

pub mod algos;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod key;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

pub use algos::{ExecContext, KernelKind};
pub use error::{Error, FailureClass, Result};
pub use key::{KeyData, KeyType, Record, Segmented, SortKey, TypedKeys};

/// The paper's key type (32-bit keys, 4-byte data items) — kept as the
/// classic alias of the typed [`SortKey`] surface. New code should be
/// generic over [`SortKey`] or carry a [`KeyData`]; `Key` remains for
/// the u32-only baselines (radix, Thrust Merge) and the fixed-shape
/// artifact path. The padding-sentinel reservation formerly documented
/// here lives at [`SortKey::PAD`].
pub type Key = u32;

/// Bytes per `u32` key — the classic width. Width-sensitive accounting
/// now flows from [`SortKey::WIDTH_BYTES`] (`KEY_BYTES` equals
/// `<Key as SortKey>::WIDTH_BYTES` and remains for the u32-only paths).
pub const KEY_BYTES: usize = std::mem::size_of::<Key>();

/// Check that a slice is sorted in non-decreasing order under the
/// key's total order.
pub fn is_sorted<K: SortKey>(keys: &[K]) -> bool {
    keys.windows(2).all(|w| w[0].key_le(&w[1]))
}

/// Verify `out` is a sorted permutation of `inp` (O(n log n), for tests
/// and the service's optional self-check mode). Permutation equality is
/// checked on bit patterns, so `f32` NaN payloads must survive too.
pub fn is_sorted_permutation<K: SortKey>(inp: &[K], out: &[K]) -> bool {
    if inp.len() != out.len() || !is_sorted(out) {
        return false;
    }
    let mut a: Vec<K::Bits> = inp.iter().map(|k| k.to_bits()).collect();
    let mut b: Vec<K::Bits> = out.iter().map(|k| k.to_bits()).collect();
    a.sort_unstable();
    b.sort_unstable();
    a == b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_detection() {
        assert!(is_sorted::<u32>(&[]));
        assert!(is_sorted::<u32>(&[1]));
        assert!(is_sorted::<u32>(&[1, 1, 2, 3]));
        assert!(!is_sorted::<u32>(&[2, 1]));
    }

    #[test]
    fn sorted_permutation_detection() {
        assert!(is_sorted_permutation::<u32>(&[3, 1, 2], &[1, 2, 3]));
        assert!(!is_sorted_permutation::<u32>(&[3, 1, 2], &[1, 2, 4]));
        assert!(!is_sorted_permutation::<u32>(&[3, 1], &[1, 2, 3]));
        assert!(!is_sorted_permutation::<u32>(&[3, 1, 2], &[3, 1, 2]));
    }

    #[test]
    fn typed_sorted_detection() {
        assert!(is_sorted(&[-3i64, -1, 0, 5]));
        assert!(!is_sorted(&[0i32, -1]));
        // f32 total order: -0.0 < +0.0 < NaN, and NaN sorts last.
        assert!(is_sorted(&[-1.0f32, -0.0, 0.0, 1.0, f32::NAN]));
        assert!(!is_sorted(&[f32::NAN, 0.0f32]));
        assert!(is_sorted_permutation(
            &[0.5f32, f32::NAN, -2.0],
            &[-2.0f32, 0.5, f32::NAN]
        ));
    }
}

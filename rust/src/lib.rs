//! # GPU Bucket Sort — Deterministic Sample Sort For GPUs
//!
//! A full reproduction of *Dehne & Zaboli, "Deterministic Sample Sort For
//! GPUs" (2010)* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the sort *service*: request router, dynamic
//!   batcher, phase scheduler over a pool of "virtual SMs", a PJRT runtime
//!   that executes AOT-compiled JAX/Pallas artifacts, a GPU cost-model
//!   simulator calibrated to the paper's Table 1 hardware, native
//!   implementations of GPU Bucket Sort and all the paper's baselines
//!   (randomized sample sort, Thrust Merge, radix), the six input
//!   distributions of Leischner et al., and the benchmark harness that
//!   regenerates every table and figure of the paper.
//! * **L2 (python/compile/model.py)** — Algorithm 1 as a jitted JAX
//!   pipeline, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (tile bitonic sort, bucket ranks, prefix sums, relocation).
//!
//! Python never runs on the request path: `make artifacts` emits
//! `artifacts/*.hlo.txt` once; the rust binary is then self-contained.
//!
//! Beyond the paper, [`algos::sharded`] shards one sort across a
//! [`sim::DevicePool`] of heterogeneous simulated GPUs — the same
//! deterministic splitter discipline applied between devices — which
//! removes the single-device memory ceilings of Figures 6 & 7 (≥ 512M
//! keys over a 4-device pool). It serves requests as the coordinator's
//! `sharded` engine.
//!
//! The full request path (client → batcher → multi-worker scheduler →
//! engines → sim ledger → cost model), the Execute vs. Analytic
//! accounting modes, and the sharded-sort design are documented in
//! `docs/ARCHITECTURE.md`; the repository README covers the layer map
//! and quickstart commands.
//!
//! ## Quick start
//!
//! ```no_run
//! use gpu_bucket_sort::algos::bucket_sort::{BucketSort, BucketSortParams};
//! use gpu_bucket_sort::sim::{GpuSim, GpuModel};
//!
//! let mut keys: Vec<u32> = (0..10_000u32).rev().collect();
//! let mut sim = GpuSim::new(GpuModel::Gtx285_2G.spec());
//! let sorter = BucketSort::new(BucketSortParams::default());
//! let report = sorter.sort(&mut keys, &mut sim).unwrap();
//! assert!(keys.windows(2).all(|w| w[0] <= w[1]));
//! assert!(report.total_estimated_ms(sim.spec()) > 0.0);
//! ```

pub mod algos;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

pub use error::{Error, Result};

/// The key type the paper sorts: 32-bit keys (the paper's experiments use
/// 4-byte data items). `u32::MAX` is reserved as a padding sentinel by the
/// fixed-shape (XLA) pipeline; the native pipelines have no such
/// restriction.
pub type Key = u32;

/// Bytes per key, used throughout the memory/traffic accounting.
pub const KEY_BYTES: usize = std::mem::size_of::<Key>();

/// Check that a slice is sorted in non-decreasing order.
pub fn is_sorted(keys: &[Key]) -> bool {
    keys.windows(2).all(|w| w[0] <= w[1])
}

/// Verify `out` is a sorted permutation of `inp` (O(n log n), for tests
/// and the service's optional self-check mode).
pub fn is_sorted_permutation(inp: &[Key], out: &[Key]) -> bool {
    if inp.len() != out.len() || !is_sorted(out) {
        return false;
    }
    let mut a = inp.to_vec();
    a.sort_unstable();
    a == out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_detection() {
        assert!(is_sorted(&[]));
        assert!(is_sorted(&[1]));
        assert!(is_sorted(&[1, 1, 2, 3]));
        assert!(!is_sorted(&[2, 1]));
    }

    #[test]
    fn sorted_permutation_detection() {
        assert!(is_sorted_permutation(&[3, 1, 2], &[1, 2, 3]));
        assert!(!is_sorted_permutation(&[3, 1, 2], &[1, 2, 4]));
        assert!(!is_sorted_permutation(&[3, 1], &[1, 2, 3]));
        assert!(!is_sorted_permutation(&[3, 1, 2], &[3, 1, 2]));
    }
}

//! Coalesced dispatch: many small same-shaped requests, ONE kernel
//! invocation.
//!
//! The batcher already groups requests into engine dispatches, but the
//! engines historically sorted each job separately — for the
//! many-small-users serving scenario that means paying the per-job
//! costs (pool wake-ups, PSRS setup, planner sketch, arena checkouts)
//! once per request. The coalescer composes a group of small requests
//! that share a key type and payload shape into a single
//! [`Segmented`]-keyed job:
//!
//! ```text
//! requests  [r0: k…] [r1: k…] [r2: k…]
//! composed  [(seg=0,k)… (seg=1,k)… (seg=2,k)…]   — one sort
//! sorted    [seg 0 sorted | seg 1 sorted | seg 2 sorted]
//! split     [r0 sorted] [r1 sorted] [r2 sorted]
//! ```
//!
//! Because the segment id is the most significant comparison position,
//! each request's keys come back sorted and contiguous, and splitting
//! by the known lengths yields responses **byte-identical** to sorting
//! each request alone (the sorted sequence of a key multiset is
//! unique; key–value groups sort `Record<Segmented<K>>`, whose global
//! tie-breaking index restricted to one segment is the request's own
//! submission order — so per-request stability is preserved too).
//! Property-tested in `rust/tests/prop_kernels.rs` and
//! `rust/tests/service_integration.rs`.
//!
//! Grouping policy: a request joins a group iff its key count is at
//! most `max_request_keys` (`config.batch.coalesce_max_keys`, 0 =
//! disabled) and at least one other eligible request of the same
//! `(key type, has-payload)` shape is in the batch. Oversized or
//! lone-shaped requests dispatch as before. Units (groups and singles)
//! run in parallel on the worker pool; result order is the submission
//! order either way.

use super::request::JobData;
use crate::error::{Error, Result};
use crate::key::{Segmented, TypedKeys};
use crate::util::pool;
use crate::{KeyType, SortKey};

/// The per-engine sort primitive the coalescer drives: sort one typed
/// key vector (with an optional payload) ascending by key bits.
/// `&self` because units are dispatched concurrently — engines expose
/// their internally-synchronized fast path here (the native engine's
/// `sort`/`sort_pairs`).
pub trait JobSorter: Sync {
    /// Sort `keys` in place (and permute `payload` with them).
    fn sort_vec<K: SortKey>(&self, keys: &mut [K], payload: Option<&mut Vec<u64>>) -> Result<()>;
}

/// What one `sort_batch` pass coalesced.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoalesceStats {
    /// Composed kernel invocations executed.
    pub groups: u64,
    /// Requests that rode inside a composed invocation.
    pub requests: u64,
}

/// One dispatch unit: the original job indices it covers plus their
/// jobs (singleton, or a coalesced group of ≥ 2).
struct Unit {
    indices: Vec<usize>,
    jobs: Vec<JobData>,
}

/// Sort a batch with coalescing: group, compose, dispatch units in
/// parallel, split, and hand back per-job results in submission order.
pub fn sort_batch<S: JobSorter>(
    sorter: &S,
    jobs: Vec<JobData>,
    max_request_keys: usize,
    workers: usize,
) -> (Vec<Result<JobData>>, CoalesceStats) {
    let n = jobs.len();
    let units = plan_units(jobs, max_request_keys);
    let mut stats = CoalesceStats::default();
    for u in &units {
        if u.indices.len() > 1 {
            stats.groups += 1;
            stats.requests += u.indices.len() as u64;
        }
    }
    let done: Vec<(Vec<usize>, Vec<Result<JobData>>)> =
        pool::parallel_map(units, workers, |unit| {
            let Unit { indices, jobs } = unit;
            let results = if indices.len() > 1 {
                sort_group(sorter, jobs)
            } else {
                jobs.into_iter().map(|j| sort_single(sorter, j)).collect()
            };
            (indices, results)
        });
    let mut slots: Vec<Option<Result<JobData>>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    for (indices, results) in done {
        for (i, r) in indices.into_iter().zip(results) {
            slots[i] = Some(r);
        }
    }
    let results = slots
        .into_iter()
        .map(|s| s.expect("every unit answers its jobs"))
        .collect();
    (results, stats)
}

/// Partition a batch into dispatch units, preserving submission order
/// within each group.
fn plan_units(jobs: Vec<JobData>, max_request_keys: usize) -> Vec<Unit> {
    // Shape → group position in `units`, for eligible jobs.
    let mut shape_unit: Vec<((KeyType, bool), usize)> = Vec::new();
    let mut units: Vec<Unit> = Vec::new();
    for (i, job) in jobs.into_iter().enumerate() {
        let eligible = max_request_keys > 0 && job.len() <= max_request_keys && !job.is_empty();
        if !eligible {
            units.push(Unit {
                indices: vec![i],
                jobs: vec![job],
            });
            continue;
        }
        let shape = (job.keys.key_type(), job.payload.is_some());
        match shape_unit.iter().find(|(s, _)| *s == shape) {
            Some(&(_, u)) => {
                units[u].indices.push(i);
                units[u].jobs.push(job);
            }
            None => {
                shape_unit.push((shape, units.len()));
                units.push(Unit {
                    indices: vec![i],
                    jobs: vec![job],
                });
            }
        }
    }
    units
}

fn sort_single<S: JobSorter>(sorter: &S, mut job: JobData) -> Result<JobData> {
    crate::key::for_each_key_vec_mut!(job.keys, v => sorter.sort_vec(v, job.payload.as_mut()))?;
    Ok(job)
}

/// Sort one coalesced group as a single segment-tagged invocation.
fn sort_group<S: JobSorter>(sorter: &S, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
    match jobs[0].keys.key_type() {
        KeyType::U32 => sort_group_typed::<u32, S>(sorter, jobs),
        KeyType::U64 => sort_group_typed::<u64, S>(sorter, jobs),
        KeyType::I32 => sort_group_typed::<i32, S>(sorter, jobs),
        KeyType::I64 => sort_group_typed::<i64, S>(sorter, jobs),
        KeyType::F32 => sort_group_typed::<f32, S>(sorter, jobs),
    }
}

fn sort_group_typed<K: TypedKeys, S: JobSorter>(
    sorter: &S,
    jobs: Vec<JobData>,
) -> Vec<Result<JobData>> {
    let count = jobs.len();
    let has_payload = jobs[0].payload.is_some();
    let total: usize = jobs.iter().map(JobData::len).sum();

    // Compose: tag every key with its request's segment id. Submission
    // order is the segment order, so the split below is a linear walk.
    let mut composed: Vec<Segmented<K>> = Vec::with_capacity(total);
    let mut payload: Vec<u64> = Vec::with_capacity(if has_payload { total } else { 0 });
    let mut lens: Vec<usize> = Vec::with_capacity(count);
    for (seg, job) in jobs.into_iter().enumerate() {
        lens.push(job.len());
        debug_assert_eq!(job.payload.is_some(), has_payload, "mixed group shape");
        if let Some(p) = job.payload {
            payload.extend_from_slice(&p);
        }
        let keys = K::from_key_data(job.keys).expect("group shares one key type");
        composed.extend(keys.into_iter().map(|key| Segmented {
            seg: seg as u32,
            key,
        }));
    }

    let sorted = sorter.sort_vec(&mut composed, has_payload.then_some(&mut payload));
    if let Err(e) = sorted {
        // The composed invocation failed as a whole (e.g. the record
        // index space overflowed); every member reports it.
        let msg = format!("coalesced dispatch failed: {e}");
        return (0..count)
            .map(|_| Err(Error::Coordinator(msg.clone())))
            .collect();
    }

    // Split: segment-major order means request seg's keys are exactly
    // the next lens[seg] elements.
    let mut results = Vec::with_capacity(count);
    let mut offset = 0usize;
    for (seg, len) in lens.into_iter().enumerate() {
        let range = offset..offset + len;
        let keys: Vec<K> = composed[range.clone()]
            .iter()
            .map(|sk| {
                debug_assert_eq!(sk.seg as usize, seg, "segments must come back contiguous");
                sk.key
            })
            .collect();
        results.push(Ok(JobData {
            keys: K::into_key_data(keys),
            payload: has_payload.then(|| payload[range].to_vec()),
        }));
        offset += len;
    }
    debug_assert_eq!(offset, total);
    results
}

/// Blanket adapter: the native engine is the production coalescing
/// target (its `sort`/`sort_pairs` take `&self` and parallelize
/// internally).
impl JobSorter for crate::exec::NativeEngine {
    fn sort_vec<K: SortKey>(&self, keys: &mut [K], payload: Option<&mut Vec<u64>>) -> Result<()> {
        match payload {
            None => {
                self.sort(keys);
                Ok(())
            }
            Some(vals) => {
                self.sort_pairs(keys, vals)?;
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{NativeEngine, NativeParams};
    use crate::workload::Distribution;
    use crate::KeyData;

    fn engine() -> NativeEngine {
        NativeEngine::new(NativeParams {
            workers: 4,
            sequential_cutoff: 1 << 10,
            ..Default::default()
        })
        .unwrap()
    }

    fn solo(e: &NativeEngine, job: &JobData) -> JobData {
        let mut j = job.clone();
        crate::key::for_each_key_vec_mut!(j.keys, v => e.sort_vec(v, j.payload.as_mut()))
            .unwrap();
        j
    }

    #[test]
    fn coalesced_results_match_solo_sorts() {
        let e = engine();
        let jobs: Vec<JobData> = (0..12u64)
            .map(|i| JobData::new(Distribution::Uniform.generate(500 + 137 * i as usize, i)))
            .collect();
        let expect: Vec<JobData> = jobs.iter().map(|j| solo(&e, j)).collect();
        let (results, stats) = sort_batch(&e, jobs, 1 << 16, 4);
        assert_eq!(stats.groups, 1);
        assert_eq!(stats.requests, 12);
        for (got, want) in results.iter().zip(&expect) {
            let got = got.as_ref().unwrap();
            assert_eq!(got.keys, want.keys);
            assert_eq!(got.payload, want.payload);
        }
    }

    #[test]
    fn mixed_shapes_group_separately() {
        let e = engine();
        let u32_job = |seed: u64| JobData::new(Distribution::Uniform.generate(400, seed));
        let u64_job = |seed: u64| {
            JobData::new(
                Distribution::Uniform
                    .generate(300, seed)
                    .into_iter()
                    .map(|x| (x as u64) << 13 | 5)
                    .collect::<Vec<u64>>(),
            )
        };
        let kv_job = |seed: u64| {
            let keys = Distribution::Uniform.generate(200, seed);
            let payload = (0..keys.len() as u64).collect();
            JobData {
                keys: KeyData::U32(keys),
                payload: Some(payload),
            }
        };
        let big = JobData::new(Distribution::Uniform.generate(5_000, 99));
        let jobs = vec![
            u32_job(1),
            u64_job(2),
            kv_job(3),
            big.clone(),
            u32_job(4),
            u64_job(5),
            kv_job(6),
        ];
        let expect: Vec<JobData> = jobs.iter().map(|j| solo(&e, j)).collect();
        // Cap below `big`: it must dispatch alone.
        let (results, stats) = sort_batch(&e, jobs, 1_000, 4);
        assert_eq!(stats.groups, 3, "u32, u64 and key–value groups");
        assert_eq!(stats.requests, 6);
        for (i, (got, want)) in results.iter().zip(&expect).enumerate() {
            let got = got.as_ref().unwrap();
            assert_eq!(got.keys, want.keys, "job {i}");
            assert_eq!(got.payload, want.payload, "job {i}");
        }
    }

    #[test]
    fn zero_cap_disables_coalescing() {
        let e = engine();
        let jobs: Vec<JobData> = (0..4u64)
            .map(|i| JobData::new(Distribution::Uniform.generate(100, i)))
            .collect();
        let (results, stats) = sort_batch(&e, jobs, 0, 4);
        assert_eq!(stats, CoalesceStats::default());
        for r in &results {
            assert!(r.is_ok());
        }
    }

    #[test]
    fn key_value_coalescing_preserves_per_request_stability() {
        // Heavy ties: within each request, equal keys must keep their
        // submission (payload) order — the per-request stable contract.
        let e = engine();
        let jobs: Vec<JobData> = (0..6u64)
            .map(|i| {
                let keys: Vec<u32> = Distribution::Uniform
                    .generate(800, i)
                    .into_iter()
                    .map(|x| x % 8)
                    .collect();
                let payload = (0..keys.len() as u64).collect();
                JobData {
                    keys: KeyData::U32(keys),
                    payload: Some(payload),
                }
            })
            .collect();
        let expect: Vec<JobData> = jobs.iter().map(|j| solo(&e, j)).collect();
        let (results, stats) = sort_batch(&e, jobs, 1 << 16, 2);
        assert_eq!(stats.groups, 1);
        for (got, want) in results.iter().zip(&expect) {
            let got = got.as_ref().unwrap();
            assert_eq!(got.keys, want.keys);
            assert_eq!(got.payload, want.payload);
        }
    }

    #[test]
    fn empty_jobs_stay_single() {
        let e = engine();
        let jobs = vec![
            JobData::new(Vec::<u32>::new()),
            JobData::new(vec![3u32, 1, 2]),
            JobData::new(vec![9u32, 7]),
        ];
        let (results, stats) = sort_batch(&e, jobs, 1 << 16, 2);
        assert!(results[0].as_ref().unwrap().is_empty());
        assert_eq!(
            results[1].as_ref().unwrap().keys.as_u32().unwrap(),
            &[1, 2, 3]
        );
        assert_eq!(results[2].as_ref().unwrap().keys.as_u32().unwrap(), &[7, 9]);
        assert_eq!(stats.groups, 1, "the two non-empty u32 jobs coalesce");
        assert_eq!(stats.requests, 2);
    }
}

//! The L3 coordinator: a batched sort *service* around the paper's
//! algorithm.
//!
//! * [`request`] — job/outcome types and the pending-request envelope.
//! * [`batcher`] — FIFO dynamic batching with backpressure.
//! * [`engine`] — the backends (native multicore, simulated GPU,
//!   device-paced sim, PJRT/AOT, sharded multi-device) behind one
//!   [`engine::SortEngine`] trait.
//! * [`scheduler`] — the multi-worker pool: N engine workers behind a
//!   condvar-signalled bounded queue, out-of-order completion with
//!   byte-deterministic per-request results.
//! * [`service`] — the intake thread wiring client channels, the
//!   batcher and the scheduler together.
//!
//! Invariants (enforced by unit tests here and property tests in
//! `rust/tests/prop_coordinator.rs`):
//! * responses carry the same request id and tag as the submission;
//! * each response is the sorted permutation of its own request's keys
//!   (never a batch-mate's), byte-identical for any worker count;
//! * FIFO dispatch order (batches may *complete* out of order across
//!   workers);
//! * admission never exceeds the queue/key budgets.

pub mod batcher;
pub mod engine;
pub mod request;
pub mod scheduler;
pub mod service;

pub use batcher::Batcher;
pub use engine::{
    build_engine, build_worker_engine, NativeSortEngine, PacedSimEngine, PjrtSortEngine,
    ShardedSortEngine, SimSortEngine, SortEngine,
};
pub use request::{Batch, PendingRequest, RequestId, SortJob, SortOutcome};
pub use scheduler::{DispatchError, Scheduler};
pub use service::{SortClient, SortService};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BatchConfig, ServiceConfig};
    use crate::workload::Distribution;

    fn test_config() -> ServiceConfig {
        ServiceConfig {
            verify: true,
            batch: BatchConfig {
                max_batch_keys: 1 << 20,
                max_batch_requests: 8,
                max_wait_ms: 1,
                queue_capacity: 64,
                max_queued_keys: 1 << 24,
            },
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_sort() {
        let client = SortService::start(test_config()).unwrap();
        let keys = Distribution::Uniform.generate(100_000, 1);
        let outcome = client.sort(SortJob::tagged(keys.clone(), "e2e")).unwrap();
        assert!(crate::is_sorted_permutation(&keys, &outcome.keys));
        assert_eq!(outcome.tag.as_deref(), Some("e2e"));
        assert!(outcome.batch_size >= 1);
        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_completed"], 1);
    }

    #[test]
    fn concurrent_requests_get_own_results() {
        // A 20 ms batching window and burst submission: requests must
        // share batches, and every response must be the caller's own.
        let cfg = ServiceConfig {
            batch: BatchConfig {
                max_wait_ms: 20,
                ..test_config().batch
            },
            ..test_config()
        };
        let client = SortService::start(cfg).unwrap();
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..16u64 {
            let keys = Distribution::Uniform.generate(10_000 + i as usize, i);
            rxs.push(client.submit(SortJob::new(keys.clone())).unwrap());
            inputs.push(keys);
        }
        let mut any_batched = false;
        for (i, (rx, input)) in rxs.into_iter().zip(inputs).enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert!(crate::is_sorted_permutation(&input, &out.keys), "req {i}");
            any_batched |= out.batch_size > 1;
        }
        assert!(any_batched, "dynamic batching never engaged");
        client.shutdown();
    }

    #[test]
    fn multi_worker_end_to_end() {
        let cfg = ServiceConfig {
            workers: 4,
            ..test_config()
        };
        let client = SortService::start(cfg).unwrap();
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..24u64 {
            let keys = Distribution::Uniform.generate(5_000 + (i as usize) * 131, i);
            rxs.push(client.submit(SortJob::new(keys.clone())).unwrap());
            inputs.push(keys);
        }
        for (i, (rx, input)) in rxs.into_iter().zip(inputs).enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert!(crate::is_sorted_permutation(&input, &out.keys), "req {i}");
            assert!(out.worker < 4, "worker id {} out of range", out.worker);
        }
        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_completed"], 24);
        assert_eq!(snap.counters["requests_received"], 24);
    }

    #[test]
    fn single_engine_injection_requires_one_worker() {
        struct Noop;
        impl SortEngine for Noop {
            fn kind(&self) -> crate::config::EngineKind {
                crate::config::EngineKind::Native
            }
            fn sort_batch(
                &mut self,
                jobs: Vec<Vec<crate::Key>>,
            ) -> Vec<crate::error::Result<Vec<crate::Key>>> {
                jobs.into_iter().map(Ok).collect()
            }
        }
        let cfg = ServiceConfig {
            workers: 2,
            ..test_config()
        };
        let err = SortService::start_with_engine(cfg, Noop).unwrap_err();
        assert!(err.to_string().contains("1 worker"), "{err}");
    }

    #[test]
    fn empty_job_completes_without_engine() {
        let client = SortService::start(test_config()).unwrap();
        let out = client.sort(SortJob::new(vec![])).unwrap();
        assert!(out.keys.is_empty());
        let snap = client.shutdown();
        assert!(!snap.counters.contains_key("requests_completed"));
    }

    #[test]
    fn sim_engine_service_and_oom_rejection() {
        use crate::algos::bucket_sort::BucketSortParams;
        use crate::sim::{GpuModel, GpuSpec};
        let cfg = ServiceConfig {
            sort: BucketSortParams { tile: 256, s: 16 },
            ..test_config()
        };
        // Tiny 1 MB device: small jobs pass, big jobs OOM.
        let spec = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20,
            ..GpuModel::Gtx260.spec()
        };
        let engine = SimSortEngine::from_parts(spec, cfg.sort).unwrap();
        let client = SortService::start_with_engine(cfg, engine).unwrap();

        let small = Distribution::Uniform.generate(10_000, 3);
        let out = client.sort(SortJob::new(small.clone())).unwrap();
        assert!(crate::is_sorted_permutation(&small, &out.keys));

        let big = Distribution::Uniform.generate(300_000, 4);
        let err = client.sort(SortJob::new(big)).unwrap_err();
        assert!(err.is_oom(), "{err}");

        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_failed"], 1);
    }

    #[test]
    fn shutdown_drains_queued_work() {
        let client = SortService::start(test_config()).unwrap();
        // Submit asynchronously, then shut down immediately: everything
        // admitted must still complete.
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..8u64 {
            let keys = Distribution::Uniform.generate(50_000, i);
            rxs.push(client.submit(SortJob::new(keys.clone())).unwrap());
            inputs.push(keys);
        }
        let snap = client.shutdown();
        let mut done = 0;
        for (rx, input) in rxs.into_iter().zip(inputs) {
            match rx.recv() {
                Ok(Ok(out)) => {
                    assert!(crate::is_sorted_permutation(&input, &out.keys));
                    done += 1;
                }
                Ok(Err(e)) => panic!("admitted request failed: {e}"),
                Err(_) => panic!("admitted request dropped"),
            }
        }
        assert_eq!(done, 8);
        // The shutdown ack is signalled only after the scheduler joins
        // its workers, so the final snapshot is complete — no race.
        assert_eq!(snap.counters["requests_completed"], 8);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        use std::sync::{Arc, Condvar, Mutex};
        use std::time::Duration;
        // An engine that blocks until released — condvar-gated, no
        // sleep-polling — so the queue can fill.
        struct SlowEngine(Arc<(Mutex<bool>, Condvar)>);
        impl SlowEngine {
            fn release(gate: &(Mutex<bool>, Condvar)) {
                *gate.0.lock().unwrap() = true;
                gate.1.notify_all();
            }
        }
        impl SortEngine for SlowEngine {
            fn kind(&self) -> crate::config::EngineKind {
                crate::config::EngineKind::Native
            }
            fn sort_batch(
                &mut self,
                jobs: Vec<Vec<crate::Key>>,
            ) -> Vec<crate::error::Result<Vec<crate::Key>>> {
                let (lock, cv) = &*self.0;
                let mut released = lock.lock().unwrap();
                while !*released {
                    released = cv.wait(released).unwrap();
                }
                jobs.into_iter()
                    .map(|mut k| {
                        k.sort_unstable();
                        Ok(k)
                    })
                    .collect()
            }
        }

        let release = Arc::new((Mutex::new(false), Condvar::new()));
        let cfg = ServiceConfig {
            verify: false,
            batch: BatchConfig {
                max_batch_keys: 10,
                max_batch_requests: 1,
                max_wait_ms: 0,
                queue_capacity: 2,
                max_queued_keys: 1 << 20,
            },
            ..Default::default()
        };
        let client =
            SortService::start_with_engine(cfg, SlowEngine(release.clone())).unwrap();

        // Saturate: 1 executing + 2 in the scheduler queue + 2 in the
        // batcher; further submissions must be rejected with
        // backpressure.
        let mut rxs = Vec::new();
        for _ in 0..12 {
            rxs.push(client.submit(SortJob::new(vec![2, 1])).unwrap());
            std::thread::sleep(Duration::from_millis(2));
        }
        SlowEngine::release(&release);
        let mut rejected = 0;
        let mut completed = 0;
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(out)) => {
                    assert_eq!(out.keys, vec![1, 2]);
                    completed += 1;
                }
                Ok(Err(e)) => {
                    assert!(e.to_string().contains("backpressure"), "{e}");
                    rejected += 1;
                }
                Err(_) => panic!("dropped"),
            }
        }
        assert!(completed >= 4, "completed={completed}");
        assert!(rejected >= 1, "rejected={rejected}");
        client.shutdown();
    }
}

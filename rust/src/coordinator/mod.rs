//! The L3 coordinator: a batched sort *service* around the paper's
//! algorithm.
//!
//! * [`request`] — the typed job API ([`SortRequest`] builder with any
//!   [`crate::KeyType`], key–value payloads, sort direction, per-request
//!   self-check; typed [`SortResponse`]) and the pending-request
//!   envelope.
//! * [`batcher`] — FIFO dynamic batching with backpressure.
//! * [`coalesce`] — segment-tagged request coalescing: a batch of
//!   small same-shaped requests becomes one composed kernel
//!   invocation, split back into byte-identical per-request responses.
//! * [`engine`] — the backends (native multicore, simulated GPU,
//!   device-paced sim, PJRT/AOT, sharded multi-device) behind one
//!   [`engine::SortEngine`] trait.
//! * [`queue`] — the generic bounded MPMC dispatch queue the scheduler
//!   wraps; extracted so the loom models can exhaustively check its
//!   submit / drain / shutdown orderings.
//! * [`scheduler`] — the multi-worker pool: N engine workers behind a
//!   condvar-signalled bounded queue, out-of-order completion with
//!   byte-deterministic per-request results.
//! * [`service`] — the intake thread wiring client channels, the
//!   batcher and the scheduler together.
//!
//! Invariants (enforced by unit tests here and property tests in
//! `rust/tests/prop_coordinator.rs`):
//! * responses carry the same request id and tag as the submission;
//! * each response is the sorted permutation of its own request's keys
//!   (never a batch-mate's), byte-identical for any worker count;
//! * FIFO dispatch order (batches may *complete* out of order across
//!   workers);
//! * admission never exceeds the queue/key budgets.

pub mod batcher;
pub mod coalesce;
pub mod engine;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod service;

pub use batcher::Batcher;
pub use coalesce::CoalesceStats;
pub use engine::{
    build_engine, build_engine_with_faults, build_worker_engine, verify_outcome, FaultTotals,
    NativeSortEngine, PacedSimEngine, PjrtSortEngine, ShardedSortEngine, SimSortEngine,
    SortEngine,
};
pub use request::{
    Batch, JobData, PendingRequest, RequestId, SortJob, SortOutcome, SortRequest,
    SortRequestBuilder, SortResponse,
};
pub use scheduler::{DispatchError, Scheduler};
pub use service::{SortClient, SortService};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BatchConfig, ServiceConfig};
    use crate::workload::Distribution;

    fn test_config() -> ServiceConfig {
        ServiceConfig {
            verify: true,
            batch: BatchConfig {
                max_batch_keys: 1 << 20,
                max_batch_requests: 8,
                max_wait_ms: 1,
                queue_capacity: 64,
                max_queued_keys: 1 << 24,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_sort() {
        let client = SortService::start(test_config()).unwrap();
        let keys = Distribution::Uniform.generate(100_000, 1);
        let outcome = client
            .sort(SortRequest::tagged(keys.clone(), "e2e"))
            .unwrap();
        assert!(crate::is_sorted_permutation(&keys, outcome.keys_u32()));
        assert_eq!(outcome.tag.as_deref(), Some("e2e"));
        assert!(outcome.batch_size >= 1);
        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_completed"], 1);
    }

    #[test]
    fn typed_requests_end_to_end() {
        // u64, i64 and NaN-containing f32 requests — with payloads,
        // descending order and per-request self-check — through the
        // default native service.
        let client = SortService::start(test_config()).unwrap();

        let keys64: Vec<u64> = (0..50_000u64)
            .map(|x| x.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let out = client.sort(SortRequest::new(keys64.clone())).unwrap();
        match &out.keys {
            crate::KeyData::U64(v) => {
                assert!(crate::is_sorted_permutation(&keys64, v))
            }
            other => panic!("wrong key type back: {:?}", other.key_type()),
        }

        let keys_i64: Vec<i64> = (0..30_000i64).map(|x| 1 - x * 2654435761).collect();
        let out = client
            .sort(
                SortRequest::builder(keys_i64.clone())
                    .descending(true)
                    .self_check(true)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        assert!(out.keys.is_sorted(true));
        assert_eq!(out.keys.len(), keys_i64.len());

        let mut fkeys: Vec<f32> = (0..20_000u32)
            .map(|x| x.wrapping_mul(2654435761) as f32 - 2e9)
            .collect();
        fkeys[5] = f32::NAN;
        fkeys[6] = -0.0;
        let payload: Vec<u64> = (0..fkeys.len() as u64).collect();
        let out = client
            .sort(
                SortRequest::builder(fkeys.clone())
                    .payload(payload)
                    .self_check(true)
                    .build()
                    .unwrap(),
            )
            .unwrap();
        let sorted = match &out.keys {
            crate::KeyData::F32(v) => v,
            other => panic!("wrong key type back: {:?}", other.key_type()),
        };
        assert!(crate::is_sorted_permutation(&fkeys, sorted));
        for (k, p) in sorted.iter().zip(out.payload.as_ref().unwrap()) {
            assert_eq!(
                f32::to_bits(fkeys[*p as usize]),
                f32::to_bits(*k),
                "payload no longer points at its key"
            );
        }

        // A mismatched payload is rejected with a clear error even
        // without the builder's validation.
        let bad = SortRequest {
            keys: crate::KeyData::U32(vec![1, 2, 3]),
            payload: Some(vec![1]),
            ..Default::default()
        };
        let err = client.sort(bad).unwrap_err();
        assert!(err.to_string().contains("payload length"), "{err}");

        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_completed"], 3);
        assert_eq!(snap.counters["requests_rejected"], 1);
    }

    #[test]
    fn concurrent_requests_get_own_results() {
        // A 20 ms batching window and burst submission: requests must
        // share batches, and every response must be the caller's own.
        let cfg = ServiceConfig {
            batch: BatchConfig {
                max_wait_ms: 20,
                ..test_config().batch
            },
            ..test_config()
        };
        let client = SortService::start(cfg).unwrap();
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..16u64 {
            let keys = Distribution::Uniform.generate(10_000 + i as usize, i);
            rxs.push(client.submit(SortRequest::new(keys.clone())).unwrap());
            inputs.push(keys);
        }
        let mut any_batched = false;
        for (i, (rx, input)) in rxs.into_iter().zip(inputs).enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert!(
                crate::is_sorted_permutation(&input, out.keys_u32()),
                "req {i}"
            );
            any_batched |= out.batch_size > 1;
        }
        assert!(any_batched, "dynamic batching never engaged");
        client.shutdown();
    }

    #[test]
    fn multi_worker_end_to_end() {
        let cfg = ServiceConfig {
            workers: 4,
            ..test_config()
        };
        let client = SortService::start(cfg).unwrap();
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..24u64 {
            let keys = Distribution::Uniform.generate(5_000 + (i as usize) * 131, i);
            rxs.push(client.submit(SortRequest::new(keys.clone())).unwrap());
            inputs.push(keys);
        }
        for (i, (rx, input)) in rxs.into_iter().zip(inputs).enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert!(
                crate::is_sorted_permutation(&input, out.keys_u32()),
                "req {i}"
            );
            assert!(out.worker < 4, "worker id {} out of range", out.worker);
        }
        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_completed"], 24);
        assert_eq!(snap.counters["requests_received"], 24);
    }

    #[test]
    fn single_engine_injection_requires_one_worker() {
        struct Noop;
        impl SortEngine for Noop {
            fn kind(&self) -> crate::config::EngineKind {
                crate::config::EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<crate::error::Result<JobData>> {
                jobs.into_iter().map(Ok).collect()
            }
        }
        let cfg = ServiceConfig {
            workers: 2,
            ..test_config()
        };
        let err = SortService::start_with_engine(cfg, Noop).unwrap_err();
        assert!(err.to_string().contains("1 worker"), "{err}");
    }

    #[test]
    fn empty_job_completes_without_engine() {
        let client = SortService::start(test_config()).unwrap();
        let out = client.sort(SortRequest::new(Vec::<u32>::new())).unwrap();
        assert!(out.keys.is_empty());
        // The key type is echoed even for empty typed jobs.
        let out = client.sort(SortRequest::new(Vec::<f32>::new())).unwrap();
        assert_eq!(out.keys.key_type(), crate::KeyType::F32);
        let snap = client.shutdown();
        assert!(!snap.counters.contains_key("requests_completed"));
    }

    #[test]
    fn sim_engine_service_and_oom_rejection() {
        use crate::algos::bucket_sort::BucketSortParams;
        use crate::sim::{GpuModel, GpuSpec};
        let cfg = ServiceConfig {
            sort: BucketSortParams { tile: 256, s: 16 },
            ..test_config()
        };
        // Tiny 1 MB device: small jobs pass, big jobs OOM.
        let spec = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20,
            ..GpuModel::Gtx260.spec()
        };
        let engine = SimSortEngine::from_parts(spec, cfg.sort).unwrap();
        let client = SortService::start_with_engine(cfg, engine).unwrap();

        let small = Distribution::Uniform.generate(10_000, 3);
        let out = client.sort(SortRequest::new(small.clone())).unwrap();
        assert!(crate::is_sorted_permutation(&small, out.keys_u32()));

        let big = Distribution::Uniform.generate(300_000, 4);
        let err = client.sort(SortRequest::new(big)).unwrap_err();
        assert!(err.is_oom(), "{err}");

        let snap = client.shutdown();
        assert_eq!(snap.counters["requests_failed"], 1);
    }

    #[test]
    fn shutdown_drains_queued_work() {
        let client = SortService::start(test_config()).unwrap();
        // Submit asynchronously, then shut down immediately: everything
        // admitted must still complete.
        let mut rxs = Vec::new();
        let mut inputs = Vec::new();
        for i in 0..8u64 {
            let keys = Distribution::Uniform.generate(50_000, i);
            rxs.push(client.submit(SortRequest::new(keys.clone())).unwrap());
            inputs.push(keys);
        }
        let snap = client.shutdown();
        let mut done = 0;
        for (rx, input) in rxs.into_iter().zip(inputs) {
            match rx.recv() {
                Ok(Ok(out)) => {
                    assert!(crate::is_sorted_permutation(&input, out.keys_u32()));
                    done += 1;
                }
                Ok(Err(e)) => panic!("admitted request failed: {e}"),
                Err(_) => panic!("admitted request dropped"),
            }
        }
        assert_eq!(done, 8);
        // The shutdown ack is signalled only after the scheduler joins
        // its workers, so the final snapshot is complete — no race.
        assert_eq!(snap.counters["requests_completed"], 8);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        use std::sync::{Arc, Condvar, Mutex};
        use std::time::Duration;
        // An engine that blocks until released — condvar-gated, no
        // sleep-polling — so the queue can fill.
        struct SlowEngine(Arc<(Mutex<bool>, Condvar)>);
        impl SlowEngine {
            fn release(gate: &(Mutex<bool>, Condvar)) {
                *gate.0.lock().unwrap() = true;
                gate.1.notify_all();
            }
        }
        impl SortEngine for SlowEngine {
            fn kind(&self) -> crate::config::EngineKind {
                crate::config::EngineKind::Native
            }
            fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<crate::error::Result<JobData>> {
                let (lock, cv) = &*self.0;
                let mut released = lock.lock().unwrap();
                while !*released {
                    released = cv.wait(released).unwrap();
                }
                jobs.into_iter()
                    .map(|mut j| {
                        if let crate::KeyData::U32(v) = &mut j.keys {
                            v.sort_unstable();
                        }
                        Ok(j)
                    })
                    .collect()
            }
        }

        let release = Arc::new((Mutex::new(false), Condvar::new()));
        let cfg = ServiceConfig {
            verify: false,
            batch: BatchConfig {
                max_batch_keys: 10,
                max_batch_requests: 1,
                max_wait_ms: 0,
                queue_capacity: 2,
                max_queued_keys: 1 << 20,
                ..Default::default()
            },
            ..Default::default()
        };
        let client =
            SortService::start_with_engine(cfg, SlowEngine(release.clone())).unwrap();

        // Saturate: 1 executing + 2 in the scheduler queue + 2 in the
        // batcher; further submissions must be rejected with
        // backpressure.
        let mut rxs = Vec::new();
        for _ in 0..12 {
            rxs.push(client.submit(SortRequest::new(vec![2u32, 1])).unwrap());
            std::thread::sleep(Duration::from_millis(2));
        }
        SlowEngine::release(&release);
        let mut rejected = 0;
        let mut completed = 0;
        for rx in rxs {
            match rx.recv() {
                Ok(Ok(out)) => {
                    assert_eq!(out.keys_u32(), &[1, 2]);
                    completed += 1;
                }
                Ok(Err(e)) => {
                    assert!(e.to_string().contains("backpressure"), "{e}");
                    rejected += 1;
                }
                Err(_) => panic!("dropped"),
            }
        }
        assert!(completed >= 4, "completed={completed}");
        assert!(rejected >= 1, "rejected={rejected}");
        client.shutdown();
    }
}

//! The dynamic batcher: groups queued sort requests into engine
//! dispatches under key-count and request-count budgets.
//!
//! Policy (FIFO, no reordering — request identity and fairness beat
//! packing efficiency for a sort service):
//! * a batch is **ready** when it reaches `max_batch_keys` or
//!   `max_batch_requests`, or when the oldest queued request has waited
//!   `max_wait_ms`;
//! * an oversized single request (> `max_batch_keys`) always forms its
//!   own batch — it can never become ready by accumulation;
//! * **admission control**: the queue rejects new work beyond
//!   `queue_capacity` requests or `max_queued_keys` keys (backpressure,
//!   sized to the engine's memory budget).
//!
//! Pure synchronous state machine — the async service drives it; tests
//! drive it directly with a mock clock.

use super::request::{Batch, PendingRequest};
use crate::config::BatchConfig;
use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Queue + assembly state.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatchConfig,
    queue: VecDeque<PendingRequest>,
    queued_keys: usize,
}

impl Batcher {
    /// New empty batcher.
    pub fn new(cfg: BatchConfig) -> Self {
        Batcher {
            cfg,
            queue: VecDeque::new(),
            queued_keys: 0,
        }
    }

    /// Queue depth in requests.
    pub fn queued_requests(&self) -> usize {
        self.queue.len()
    }

    /// Queue depth in keys.
    pub fn queued_keys(&self) -> usize {
        self.queued_keys
    }

    /// Check whether a request of `len` keys can be admitted right now.
    pub fn can_admit(&self, len: usize) -> Result<()> {
        if self.queue.len() >= self.cfg.queue_capacity {
            return Err(Error::Busy(format!(
                "queue full ({} requests) — backpressure",
                self.queue.len()
            )));
        }
        if self.queued_keys + len > self.cfg.max_queued_keys && !self.queue.is_empty() {
            return Err(Error::Busy(format!(
                "queued key budget exceeded ({} + {} > {}) — backpressure",
                self.queued_keys,
                len,
                self.cfg.max_queued_keys
            )));
        }
        Ok(())
    }

    /// Admit a request, or reject it with a backpressure error. The
    /// rejected request comes back with the error so the caller can
    /// answer its response channel instead of dropping it.
    pub fn admit(
        &mut self,
        req: PendingRequest,
    ) -> std::result::Result<(), (Error, PendingRequest)> {
        if let Err(e) = self.can_admit(req.len()) {
            return Err((e, req));
        }
        self.queued_keys += req.len();
        self.queue.push_back(req);
        Ok(())
    }

    /// Deadline by which [`Batcher::poll`] should be called again (the
    /// oldest request's wait expiry), if any work is queued.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue
            .front()
            .map(|r| r.admitted_at + Duration::from_millis(self.cfg.max_wait_ms))
    }

    /// Assemble the next batch if one is ready at time `now`.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.queue.front()?;
        let waited = now.saturating_duration_since(oldest.admitted_at);
        let wait_expired = waited >= Duration::from_millis(self.cfg.max_wait_ms);
        if !wait_expired && !self.budget_reached() {
            return None;
        }
        Some(self.take_batch())
    }

    /// Put an assembled batch back at the queue front (the engine
    /// channel was full). Order is preserved.
    pub fn restore_front(&mut self, batch: Batch) {
        for req in batch.requests.into_iter().rev() {
            self.queued_keys += req.len();
            self.queue.push_front(req);
        }
    }

    /// Assemble whatever is queued right now (shutdown drain).
    pub fn drain(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.take_batch())
        }
    }

    /// True when the queued front already fills a batch budget.
    fn budget_reached(&self) -> bool {
        if self.queue.len() >= self.cfg.max_batch_requests {
            return true;
        }
        let mut keys = 0usize;
        for (i, r) in self.queue.iter().enumerate() {
            keys += r.len();
            if keys >= self.cfg.max_batch_keys {
                return true;
            }
            if i + 1 >= self.cfg.max_batch_requests {
                return true;
            }
        }
        false
    }

    /// Pop the FIFO prefix that fits the budgets (always ≥ 1 request).
    fn take_batch(&mut self) -> Batch {
        let mut requests = Vec::new();
        let mut total_keys = 0usize;
        while let Some(front) = self.queue.front() {
            let would_be = total_keys + front.len();
            let fits = requests.is_empty()
                || (would_be <= self.cfg.max_batch_keys
                    && requests.len() < self.cfg.max_batch_requests);
            if !fits {
                break;
            }
            let req = self.queue.pop_front().expect("front exists");
            self.queued_keys -= req.len();
            total_keys += req.len();
            requests.push(req);
        }
        Batch {
            requests,
            total_keys,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SortRequest;

    fn cfg() -> BatchConfig {
        BatchConfig {
            max_batch_keys: 100,
            max_batch_requests: 4,
            max_wait_ms: 10,
            queue_capacity: 8,
            max_queued_keys: 1000,
            ..Default::default()
        }
    }

    type OutcomeRx =
        std::sync::mpsc::Receiver<crate::error::Result<crate::coordinator::request::SortResponse>>;

    fn req(id: u64, n: usize, at: Instant) -> (PendingRequest, OutcomeRx) {
        let (tx, rx) = std::sync::mpsc::channel();
        (
            PendingRequest {
                id,
                request: SortRequest::new(vec![0u32; n]),
                admitted_at: at,
                respond_to: tx,
            },
            rx,
        )
    }

    #[test]
    fn waits_for_company_until_deadline() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let (r, _rx) = req(1, 10, t0);
        b.admit(r).unwrap();
        // Not ready immediately…
        assert!(b.poll(t0).is_none());
        assert!(b.poll(t0 + Duration::from_millis(5)).is_none());
        // …ready once the wait expires.
        let batch = b.poll(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(b.queued_requests(), 0);
    }

    #[test]
    fn key_budget_triggers_immediately() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let (r1, _x1) = req(1, 60, t0);
        let (r2, _x2) = req(2, 50, t0);
        b.admit(r1).unwrap();
        b.admit(r2).unwrap();
        // 60 + 50 ≥ 100 → ready without waiting; but the second request
        // doesn't fit the key budget, so the batch carries only the first.
        let batch = b.poll(t0).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.total_keys, 60);
        // Remainder stays queued.
        assert_eq!(b.queued_requests(), 1);
        assert_eq!(b.queued_keys(), 50);
    }

    #[test]
    fn request_budget_triggers() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i, 1, t0);
            b.admit(r).unwrap();
            rxs.push(rx);
        }
        let batch = b.poll(t0).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.total_keys, 4);
    }

    #[test]
    fn oversized_request_forms_own_batch() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let (r, _x) = req(1, 500, t0);
        b.admit(r).unwrap();
        let batch = b.poll(t0).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.total_keys, 500);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 10, t0);
            b.admit(r).unwrap();
            rxs.push(rx);
        }
        let batch = b.poll(t0 + Duration::from_millis(10)).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn backpressure_on_request_count() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (r, rx) = req(i, 1, t0);
            b.admit(r).unwrap();
            rxs.push(rx);
        }
        let (r, _x) = req(99, 1, t0);
        let (err, rejected) = b.admit(r).unwrap_err();
        assert!(matches!(err, Error::Busy(_)));
        assert!(err.is_busy());
        assert!(err.to_string().contains("backpressure"));
        // The rejected request comes back intact for a typed reply.
        assert_eq!(rejected.id, 99);
    }

    #[test]
    fn backpressure_on_key_budget() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let (r1, _x1) = req(1, 900, t0);
        b.admit(r1).unwrap();
        let (r2, _x2) = req(2, 200, t0);
        assert!(b.admit(r2).is_err());
        // But an oversized request is admitted when the queue is empty.
        let mut b2 = Batcher::new(cfg());
        let (big, _x3) = req(3, 5000, t0);
        b2.admit(big).unwrap();
    }

    #[test]
    fn drain_takes_everything_within_budget() {
        let mut b = Batcher::new(cfg());
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 10, t0);
            b.admit(r).unwrap();
            rxs.push(rx);
        }
        let batch = b.drain().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(b.drain().is_none());
    }

    #[test]
    fn deadline_tracks_oldest() {
        let mut b = Batcher::new(cfg());
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        let (r, _x) = req(1, 1, t0);
        b.admit(r).unwrap();
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(10));
    }
}

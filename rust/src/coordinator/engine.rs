//! Engine abstraction: the four backends a batch can be dispatched to.
//!
//! * [`NativeEngine`]-backed — the real multicore path (production).
//! * Sim-backed — Algorithm 1 over a simulated Table-1 GPU (capacity
//!   limits and the traffic ledger apply; used by experiments and for
//!   failure-injection tests via tiny simulated devices).
//! * PJRT-backed — the AOT JAX/Pallas pipeline via the XLA CPU client
//!   (fixed shapes from `artifacts/manifest.json`; serves the classic
//!   `u32`, key-only jobs only — see [`crate::SortKey`] on the
//!   fixed-shape sentinel restriction).
//! * Sharded — Algorithm 1 per device across a [`DevicePool`] with a
//!   deterministic cross-device combine; accepts jobs beyond any single
//!   device's memory ceiling.
//!
//! Every engine consumes typed [`JobData`] (any [`crate::KeyType`],
//! optional key–value payload) and sorts **ascending by key bits**; the
//! scheduler applies the requested direction afterwards, uniformly.

use super::coalesce::{self, CoalesceStats};
use crate::algos::adaptive;
use crate::algos::bucket_sort::{BucketSort, BucketSortParams};
use crate::algos::sharded::{ShardedSort, ShardedSortParams};
use crate::algos::ExecContext;
use crate::config::{EngineKind, ServiceConfig};
use crate::error::{Error, Result};
use crate::exec::NativeEngine;
use crate::key::for_each_key_vec_mut;
use crate::runtime::PjrtRuntime;
use crate::sim::fault::FaultInjector;
use crate::sim::{DeviceLease, DevicePool, GpuModel, GpuSim, GpuSpec};
use crate::{KeyData, SortKey};
use std::sync::Arc;

/// Lifetime fault-recovery totals of an engine, polled by the scheduler
/// after each batch (delta-style, like [`CoalesceStats`]) to export the
/// `failover_*` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTotals {
    /// Device-lost failovers survived: each one marked a device
    /// unhealthy and re-planned the affected job over the survivors.
    pub failovers: u64,
    /// Devices currently marked unhealthy in this engine's pool.
    pub devices_lost: u64,
}

/// A sort backend able to process a batch of independent jobs.
///
/// One engine instance is owned by exactly one scheduler worker thread —
/// it is *constructed on that thread* (see `SortService::start`) — so
/// implementations may hold non-`Send`/non-`Sync` state (the PJRT
/// client's `Rc` internals in particular).
pub trait SortEngine {
    /// Which configuration enum this engine realizes.
    fn kind(&self) -> EngineKind;

    /// Sort every job of the batch ascending by key bits, keeping each
    /// job's payload paired with its keys; one result per job, order
    /// preserved. Jobs fail individually (e.g. a simulated OOM, or an
    /// unsupported key type on a fixed-shape engine) without failing
    /// the batch.
    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>>;

    /// Largest single job this engine accepts, if bounded (in keys, at
    /// the classic `u32` width — wider jobs may OOM earlier and fail
    /// individually).
    fn max_job_keys(&self) -> Option<usize> {
        None
    }

    /// Lifetime totals of coalesced dispatch on this engine, if it
    /// coalesces at all (see [`coalesce`]). The scheduler polls this
    /// after each batch to export `coalesced_requests` /
    /// `coalesced_groups` metrics.
    fn coalesced_totals(&self) -> Option<CoalesceStats> {
        None
    }

    /// Lifetime totals of the adaptive front-end's plan decisions, if
    /// this engine runs the front-end at all (today: the native engine
    /// under [`crate::KernelKind::Adaptive`]). The scheduler polls this
    /// after each batch to export `adaptive_*` metrics.
    fn plan_totals(&self) -> Option<adaptive::PlanTotals> {
        None
    }

    /// The most recent [`adaptive::PlanChoice`] this engine recorded,
    /// if any — surfaced in the service response tag on request (see
    /// the scheduler's `#plan` tag suffix).
    fn last_plan_choice(&self) -> Option<adaptive::PlanChoice> {
        None
    }

    /// Lifetime fault-recovery totals, if this engine can survive
    /// device loss at all (today: the sharded engine). The scheduler
    /// polls this after each batch to export `failover_*` metrics.
    fn fault_totals(&self) -> Option<FaultTotals> {
        None
    }
}

pub use super::request::JobData;

/// Native multicore backend: small same-shaped jobs are **coalesced**
/// into one segment-tagged kernel invocation
/// (`cfg.batch.coalesce_max_keys`, see [`coalesce`]); remaining units
/// run concurrently on the virtual-SM pool, each internally parallel.
pub struct NativeSortEngine {
    engine: NativeEngine,
    coalesce_max_keys: usize,
    coalesced: CoalesceStats,
}

impl NativeSortEngine {
    /// Build from config: the inner engine holds a persistent
    /// [`ExecContext`] (kernel + planner digit width from the config,
    /// arena warm across batches), so repeated batches of similar
    /// shapes allocate nothing.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        let ctx = ExecContext::new(cfg.kernel, 0)
            .with_digit_bits(cfg.digit_bits)
            .with_cost_model(adaptive::CostModel::resolve(&cfg.cost_model)?);
        Ok(NativeSortEngine {
            engine: NativeEngine::with_context(cfg.native, ctx)?,
            coalesce_max_keys: cfg.batch.coalesce_max_keys,
            coalesced: CoalesceStats::default(),
        })
    }

    /// Access the inner engine (reports, tests).
    pub fn inner(&self) -> &NativeEngine {
        &self.engine
    }
}

impl SortEngine for NativeSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Native
    }

    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
        // Small same-shaped jobs coalesce into one composed invocation;
        // everything else dispatches per job. Units run in parallel
        // with each other (dynamic queue — sizes vary); the engine
        // parallelizes internally for large ones.
        let (results, stats) = coalesce::sort_batch(
            &self.engine,
            jobs,
            self.coalesce_max_keys,
            self.engine.workers(),
        );
        self.coalesced.groups += stats.groups;
        self.coalesced.requests += stats.requests;
        results
    }

    fn coalesced_totals(&self) -> Option<CoalesceStats> {
        Some(self.coalesced)
    }

    fn plan_totals(&self) -> Option<adaptive::PlanTotals> {
        Some(self.engine.plan_totals())
    }

    fn last_plan_choice(&self) -> Option<adaptive::PlanChoice> {
        self.engine.last_plan_choice()
    }
}

/// Simulated-GPU backend: Algorithm 1 with full traffic accounting and
/// the device's memory ceiling (which key–value and wide-key jobs reach
/// proportionally sooner). The simulator and the execution context are
/// engine-resident: each job resets the sim's ledger/allocation state
/// instead of constructing a fresh one, and all host working buffers
/// come from the warm arena.
pub struct SimSortEngine {
    spec: GpuSpec,
    sorter: BucketSort,
    sim: GpuSim,
    ctx: ExecContext,
}

impl SimSortEngine {
    /// Build from config.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        let mut engine = Self::from_parts(cfg.device.spec(), cfg.sort)?;
        engine.ctx.kernel = cfg.kernel;
        engine.ctx.digit_bits = cfg.digit_bits;
        engine.ctx.cost = adaptive::CostModel::resolve(&cfg.cost_model)?;
        Ok(engine)
    }

    /// Build directly from a spec and params (tests, experiments).
    pub fn from_parts(spec: GpuSpec, params: BucketSortParams) -> Result<Self> {
        Ok(SimSortEngine {
            sim: GpuSim::new(spec.clone()),
            spec,
            sorter: BucketSort::try_new(params)?,
            ctx: ExecContext::default(),
        })
    }
}

fn sim_job<K: SortKey>(
    sorter: &BucketSort,
    sim: &mut GpuSim,
    ctx: &ExecContext,
    keys: &mut [K],
    payload: &mut Option<Vec<u64>>,
) -> Result<()> {
    sim.reset();
    match payload {
        None => {
            sorter.sort_in(keys, sim, ctx)?;
        }
        Some(vals) => {
            sorter.sort_pairs_in(keys, vals, sim, ctx)?;
        }
    }
    Ok(())
}

impl SortEngine for SimSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sim
    }

    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
        jobs.into_iter()
            .map(|mut job| {
                for_each_key_vec_mut!(
                    job.keys,
                    v => sim_job(&self.sorter, &mut self.sim, &self.ctx, v, &mut job.payload)
                )?;
                Ok(job)
            })
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.spec.max_sortable_keys())
    }
}

/// Sharded multi-device backend: Algorithm 1 per simulated device over
/// a capacity-weighted partition, plus the deterministic cross-device
/// combine of [`crate::algos::sharded`]. The device pool and execution
/// context are engine-resident: each job resets the pool's sims instead
/// of rebuilding it, and shard/exchange/merge buffers come from the
/// warm arena.
pub struct ShardedSortEngine {
    models: Vec<GpuModel>,
    sorter: ShardedSort,
    pool: DevicePool,
    ctx: ExecContext,
    /// Lifetime device-lost failovers survived across all jobs.
    failovers: u64,
    /// Held when the devices were checked out of a shared
    /// [`crate::sim::DeviceRegistry`] (multi-worker schedulers); the
    /// devices return to the registry when the engine drops.
    lease: Option<DeviceLease>,
}

impl ShardedSortEngine {
    /// Build from config (`cfg.devices` + `cfg.sort` + `cfg.kernel` +
    /// `cfg.digit_bits`).
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        let mut engine = Self::from_parts(
            cfg.devices.clone(),
            ShardedSortParams {
                sort: cfg.sort,
                ..Default::default()
            },
        )?;
        engine.ctx.kernel = cfg.kernel;
        engine.ctx.digit_bits = cfg.digit_bits;
        engine.ctx.cost = adaptive::CostModel::resolve(&cfg.cost_model)?;
        Ok(engine)
    }

    /// Build directly from a device list and parameters (tests,
    /// experiments).
    pub fn from_parts(models: Vec<GpuModel>, params: ShardedSortParams) -> Result<Self> {
        if models.is_empty() {
            return Err(Error::Config(
                "sharded engine needs at least one device".into(),
            ));
        }
        Ok(ShardedSortEngine {
            pool: DevicePool::new(&models)?,
            models,
            sorter: ShardedSort::try_new(params)?,
            ctx: ExecContext::default(),
            failovers: 0,
            lease: None,
        })
    }

    /// Build over devices leased from a shared registry — the
    /// multi-worker path, where each scheduler worker holds a disjoint
    /// subset of the configured pool. `kernel`, `digit_bits` and `cost`
    /// are the executed tile/bucket kernel selection (`cfg.kernel` /
    /// `cfg.digit_bits` / the resolved `cfg.cost_model`), passed
    /// explicitly so the lease path cannot silently diverge from
    /// [`ShardedSortEngine::new`].
    pub fn with_lease(
        lease: DeviceLease,
        params: ShardedSortParams,
        kernel: crate::KernelKind,
        digit_bits: u32,
        cost: adaptive::CostModel,
    ) -> Result<Self> {
        let mut engine = Self::from_parts(lease.models().to_vec(), params)?;
        engine.ctx.kernel = kernel;
        engine.ctx.digit_bits = digit_bits;
        engine.ctx.cost = cost;
        engine.lease = Some(lease);
        Ok(engine)
    }

    /// The device models backing each job's pool.
    pub fn models(&self) -> &[GpuModel] {
        &self.models
    }

    /// Arm (or disarm) deterministic fault injection for every
    /// subsequent job. `None` is the production state: the probes in
    /// [`crate::algos::sharded`] are a single `Option` check.
    pub fn set_fault_injector(&mut self, faults: Option<Arc<FaultInjector>>) {
        self.ctx.faults = faults;
    }

    /// Push this engine's pool-health verdicts out to the shared
    /// registry (multi-worker schedulers), so replacement engines built
    /// later skip devices already known dead.
    fn propagate_health(&self) {
        let Some(lease) = &self.lease else { return };
        for d in 0..self.models.len() {
            if !self.pool.is_healthy(d) {
                lease.mark_unhealthy(d);
            }
        }
    }
}

fn sharded_job<K: SortKey>(
    sorter: &ShardedSort,
    pool: &mut DevicePool,
    ctx: &ExecContext,
    keys: &mut [K],
    payload: &mut Option<Vec<u64>>,
) -> Result<u32> {
    pool.reset();
    let report = match payload {
        None => sorter.sort_in(keys, pool, ctx)?,
        Some(vals) => sorter.sort_pairs_in(keys, vals, pool, ctx)?,
    };
    Ok(report.failovers)
}

impl SortEngine for ShardedSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sharded
    }

    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
        let results = jobs
            .into_iter()
            .map(|mut job| {
                let failovers = for_each_key_vec_mut!(
                    job.keys,
                    v => sharded_job(&self.sorter, &mut self.pool, &self.ctx, v, &mut job.payload)
                )?;
                self.failovers += u64::from(failovers);
                Ok(job)
            })
            .collect();
        // Health marks persist across jobs (a lost device stays lost),
        // so surviving jobs keep planning over the survivors; tell the
        // shared registry, if any, so it stops handing the device out.
        self.propagate_health();
        results
    }

    fn max_job_keys(&self) -> Option<usize> {
        // Advertise the *healthy* capacity: after a failover the pool
        // is smaller, and admission control must track that.
        Some(self.pool.max_sortable_keys())
    }

    fn fault_totals(&self) -> Option<FaultTotals> {
        Some(FaultTotals {
            failovers: self.failovers,
            devices_lost: (self.models.len() - self.pool.healthy_count()) as u64,
        })
    }
}

/// PJRT backend: the AOT-compiled fixed-shape pipeline. The artifact
/// set is compiled for `u32` keys, key-only, ascending — typed or
/// key–value jobs fail individually with a descriptive error (route
/// them to the native/sim/sharded engines instead).
pub struct PjrtSortEngine {
    runtime: PjrtRuntime,
}

impl PjrtSortEngine {
    /// Load artifacts and warm the executable cache.
    pub fn new(cfg: &ServiceConfig) -> Result<Self> {
        let mut runtime = PjrtRuntime::new(cfg.artifacts_dir.clone())?;
        runtime.warm_up()?;
        Ok(PjrtSortEngine { runtime })
    }

    /// The underlying runtime.
    pub fn runtime(&self) -> &PjrtRuntime {
        &self.runtime
    }
}

impl SortEngine for PjrtSortEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Pjrt
    }

    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
        jobs.into_iter()
            .map(|mut job| {
                if job.payload.is_some() {
                    return Err(Error::InvalidInput(
                        "the fixed-shape PJRT engine does not support key–value payloads"
                            .into(),
                    ));
                }
                let KeyData::U32(ref keys) = job.keys else {
                    return Err(Error::InvalidInput(format!(
                        "the fixed-shape PJRT engine serves u32 keys only (got {})",
                        job.keys.key_type()
                    )));
                };
                let (sorted, _cap) = self.runtime.sort(keys)?;
                job.keys = KeyData::U32(sorted);
                Ok(job)
            })
            .collect()
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.runtime.manifest().max_sort_capacity())
    }
}

/// Device-paced simulated engine: output computed on the host with a
/// fast comparison sort, *occupancy* priced by the analytic cost model
/// of the simulated device — the worker stays busy for the device's
/// estimated wall time, like a real accelerator-attached engine waiting
/// on its stream. This is what makes multi-worker throughput studies
/// honest on a small host: each worker stands in for one GPU, and
/// aggregate throughput scales with simulated devices, not host cores.
///
/// Jobs beyond the device's memory ceiling fail with the same OOM as
/// [`SimSortEngine`] (the pricing pass performs the capacity
/// accounting, at the job's actual element width — key bytes plus 4 for
/// a key–value payload index).
pub struct PacedSimEngine {
    spec: GpuSpec,
    sorter: BucketSort,
    sim: GpuSim,
    time_scale: f64,
}

impl PacedSimEngine {
    /// Build over one simulated device. `time_scale` stretches or
    /// shrinks the priced device time (1.0 = Table 1 calibration; 0
    /// disables pacing entirely — pure correctness tests).
    pub fn new(model: GpuModel, params: BucketSortParams, time_scale: f64) -> Result<Self> {
        if !time_scale.is_finite() || time_scale < 0.0 {
            return Err(Error::InvalidParams(
                "time_scale must be finite and non-negative".into(),
            ));
        }
        let spec = model.spec();
        Ok(PacedSimEngine {
            sim: GpuSim::new(spec.clone()),
            spec,
            sorter: BucketSort::try_new(params)?,
            time_scale,
        })
    }
}

fn paced_host_sort<K: SortKey>(keys: &mut [K], payload: &mut Option<Vec<u64>>) -> Result<()> {
    match payload {
        None => keys.sort_unstable_by(K::key_cmp),
        Some(vals) => {
            // Same per-job shape contract as the other engines'
            // sort_pairs: fail the job, never panic the worker.
            crate::key::validate_key_value(keys.len(), vals.len())?;
            // Record sort: ties break by original position, so the
            // payload pairing is stable and byte-deterministic.
            let mut recs = crate::key::tag_records(keys)?;
            recs.sort_unstable_by(<crate::Record<K>>::key_cmp);
            crate::key::untag_records(&recs, keys, vals);
        }
    }
    Ok(())
}

impl SortEngine for PacedSimEngine {
    fn kind(&self) -> EngineKind {
        EngineKind::Sim
    }

    fn sort_batch(&mut self, jobs: Vec<JobData>) -> Vec<Result<JobData>> {
        let started = std::time::Instant::now();
        let mut device_ms = 0.0;
        let results: Vec<Result<JobData>> = jobs
            .into_iter()
            .map(|mut job| {
                // Analytic pricing enforces the memory ceiling and
                // yields the deterministic device estimate at the job's
                // element width; the data work itself is a plain host
                // sort. The engine-resident sim is reset per job — no
                // per-job construction.
                self.sim.reset();
                let elem_bytes =
                    job.keys.width_bytes() + if job.payload.is_some() { 4 } else { 0 };
                self.sorter
                    .sort_analytic_bytes(job.keys.len(), elem_bytes, &mut self.sim)?;
                device_ms += self.sim.estimated_ms();
                for_each_key_vec_mut!(job.keys, v => paced_host_sort(v, &mut job.payload))?;
                Ok(job)
            })
            .collect();
        // Hold the worker for the rest of the simulated device time —
        // a batch is one stream, so job estimates add up.
        let budget_ms = device_ms * self.time_scale;
        let host_ms = started.elapsed().as_secs_f64() * 1e3;
        if budget_ms > host_ms {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (budget_ms - host_ms) / 1e3,
            ));
        }
        results
    }

    fn max_job_keys(&self) -> Option<usize> {
        Some(self.spec.max_sortable_keys())
    }
}

/// Build the engine selected by `cfg.engine`, with fault injection
/// disarmed (the production path; see [`build_engine_with_faults`]).
pub fn build_engine(cfg: &ServiceConfig) -> Result<Box<dyn SortEngine>> {
    build_engine_with_faults(cfg, None)
}

/// Build the engine selected by `cfg.engine`, arming the sharded
/// engine's instrumented fault points when an injector is supplied
/// (resolved from `cfg.fault_plan` by the service). Engines without
/// instrumented points ignore the injector.
pub fn build_engine_with_faults(
    cfg: &ServiceConfig,
    faults: Option<Arc<FaultInjector>>,
) -> Result<Box<dyn SortEngine>> {
    match cfg.engine {
        EngineKind::Native => Ok(Box::new(NativeSortEngine::new(cfg)?)),
        EngineKind::Sim => Ok(Box::new(SimSortEngine::new(cfg)?)),
        EngineKind::Pjrt => Ok(Box::new(PjrtSortEngine::new(cfg)?)),
        EngineKind::Sharded => {
            let mut engine = ShardedSortEngine::new(cfg)?;
            engine.set_fault_injector(faults);
            Ok(Box::new(engine))
        }
    }
}

/// Build the engine for scheduler worker `worker` of `cfg.workers`.
///
/// Identical to [`build_engine_with_faults`] except for the sharded
/// engine in a multi-worker scheduler: there each worker checks its
/// share of `cfg.devices` out of the shared `registry`, so concurrent
/// workers hold disjoint device subsets (no oversubscription).
pub fn build_worker_engine(
    cfg: &ServiceConfig,
    worker: usize,
    registry: Option<&crate::sim::DeviceRegistry>,
    faults: Option<Arc<FaultInjector>>,
) -> Result<Box<dyn SortEngine>> {
    match (cfg.engine, registry) {
        (EngineKind::Sharded, Some(registry)) => {
            let share =
                crate::sim::DeviceRegistry::share_for(worker, cfg.workers, registry.total());
            let lease = registry.checkout(share)?;
            let mut engine = ShardedSortEngine::with_lease(
                lease,
                ShardedSortParams {
                    sort: cfg.sort,
                    ..Default::default()
                },
                cfg.kernel,
                cfg.digit_bits,
                adaptive::CostModel::resolve(&cfg.cost_model)?,
            )?;
            engine.set_fault_injector(faults);
            Ok(Box::new(engine))
        }
        _ => build_engine_with_faults(cfg, faults),
    }
}

/// Stall scheduler worker `worker` for an injected slow-device delay,
/// if the plan has one armed. This (and the [`PacedSimEngine`] stream
/// wait above) are the two sanctioned pacing sleeps outside
/// [`crate::util::backoff`] — pure test-time pacing, never a retry
/// loop, so determinism of *results* is unaffected.
pub fn pace_for_injected_slowdown(faults: Option<&FaultInjector>, worker: usize) {
    let Some(inj) = faults else { return };
    if let Some(ms) = inj.slow_device_ms(worker) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Shared post-condition check used by the service's verify/self-check
/// modes: `output` must hold the same key type as `input`, be sorted in
/// the requested direction, and be a permutation of the input's keys —
/// with every payload value still attached to its original key.
pub fn verify_outcome(input: &JobData, output: &JobData, descending: bool) -> Result<()> {
    fn check<K: SortKey>(
        inp: &[K],
        out: &[K],
        in_p: Option<&Vec<u64>>,
        out_p: Option<&Vec<u64>>,
    ) -> bool {
        if inp.len() != out.len() {
            return false;
        }
        match (in_p, out_p) {
            (None, None) => {
                let mut a: Vec<K::Bits> = inp.iter().map(|k| k.to_bits()).collect();
                let mut b: Vec<K::Bits> = out.iter().map(|k| k.to_bits()).collect();
                a.sort_unstable();
                b.sort_unstable();
                a == b
            }
            (Some(ip), Some(op)) => {
                if ip.len() != inp.len() || op.len() != out.len() {
                    return false;
                }
                // (key, payload) pair multiset equality — catches both
                // key corruption and payload divorce.
                let mut a: Vec<(K::Bits, u64)> =
                    inp.iter().zip(ip).map(|(k, &v)| (k.to_bits(), v)).collect();
                let mut b: Vec<(K::Bits, u64)> =
                    out.iter().zip(op).map(|(k, &v)| (k.to_bits(), v)).collect();
                a.sort_unstable();
                b.sort_unstable();
                a == b
            }
            _ => false,
        }
    }
    let in_p = input.payload.as_ref();
    let out_p = output.payload.as_ref();
    // Direction-aware sortedness has one definition: KeyData::is_sorted.
    let ok = output.keys.is_sorted(descending)
        && match (&input.keys, &output.keys) {
            (KeyData::U32(a), KeyData::U32(b)) => check(a, b, in_p, out_p),
            (KeyData::U64(a), KeyData::U64(b)) => check(a, b, in_p, out_p),
            (KeyData::I32(a), KeyData::I32(b)) => check(a, b, in_p, out_p),
            (KeyData::I64(a), KeyData::I64(b)) => check(a, b, in_p, out_p),
            (KeyData::F32(a), KeyData::F32(b)) => check(a, b, in_p, out_p),
            _ => false,
        };
    if ok {
        Ok(())
    } else {
        Err(Error::Coordinator(
            "verification failed: output is not a sorted permutation of the input".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GpuModel;

    fn kv_u32(keys: Vec<u32>, payload: Option<Vec<u64>>) -> JobData {
        JobData {
            keys: KeyData::U32(keys),
            payload,
        }
    }

    #[test]
    fn native_engine_sorts_batches() {
        let cfg = ServiceConfig::default();
        let mut e = NativeSortEngine::new(&cfg).unwrap();
        let jobs = vec![
            kv_u32(vec![3, 1, 2], None),
            kv_u32(vec![], None),
            kv_u32((0..10_000u32).rev().collect(), None),
        ];
        let results = e.sort_batch(jobs.clone());
        assert_eq!(results.len(), 3);
        for (inp, res) in jobs.iter().zip(&results) {
            let out = res.as_ref().unwrap();
            assert!(crate::is_sorted_permutation(
                inp.keys.as_u32().unwrap(),
                out.keys.as_u32().unwrap()
            ));
        }
        assert_eq!(e.kind(), EngineKind::Native);
        // The default kernel is Adaptive, so the front-end ran (the
        // small same-shaped jobs coalesce into one segment-tagged
        // invocation, so the totals count composed units, not jobs) and
        // the trait surface exposes its decisions.
        let totals = e.plan_totals().expect("native engine reports plan totals");
        assert!(totals.requests >= 1, "{totals:?}");
        assert!(e.last_plan_choice().is_some());
        // Engines without a front-end keep the default-None surface.
        let sim = SimSortEngine::new(&cfg).unwrap();
        assert!(sim.plan_totals().is_none());
        assert!(sim.last_plan_choice().is_none());
    }

    #[test]
    fn engines_serve_typed_and_key_value_jobs() {
        // Every general-purpose engine takes a u64 job and a u32
        // key–value job through the same sort_batch surface.
        let keys64: Vec<u64> = (0..20_000u64)
            .map(|x| x.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let kv_keys: Vec<u32> = (0..10_000u32).map(|x| x.wrapping_mul(2654435761) % 64).collect();
        let kv_payload: Vec<u64> = (0..kv_keys.len() as u64).collect();

        let cfg = ServiceConfig {
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut engines: Vec<Box<dyn SortEngine>> = vec![
            Box::new(NativeSortEngine::new(&cfg).unwrap()),
            Box::new(SimSortEngine::new(&cfg).unwrap()),
            Box::new(
                ShardedSortEngine::from_parts(
                    cfg.devices.clone(),
                    ShardedSortParams {
                        sort: cfg.sort,
                        ..Default::default()
                    },
                )
                .unwrap(),
            ),
            Box::new(PacedSimEngine::new(GpuModel::Gtx285_2G, cfg.sort, 0.0).unwrap()),
        ];
        for e in engines.iter_mut() {
            let jobs = vec![
                JobData::new(keys64.clone()),
                JobData {
                    keys: KeyData::U32(kv_keys.clone()),
                    payload: Some(kv_payload.clone()),
                },
            ];
            let inputs: Vec<JobData> = jobs.clone();
            let results = e.sort_batch(jobs);
            for (input, res) in inputs.iter().zip(&results) {
                let out = res.as_ref().unwrap();
                verify_outcome(input, out, false).unwrap();
            }
        }
    }

    #[test]
    fn sim_engine_respects_capacity() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sim,
            device: GpuModel::Gtx260,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut e = SimSortEngine::new(&cfg).unwrap();
        assert!(e.max_job_keys().unwrap() > 64 << 20);
        let results = e.sort_batch(vec![kv_u32(vec![5, 4, 3, 2, 1], None)]);
        assert_eq!(
            results[0].as_ref().unwrap().keys.as_u32().unwrap(),
            &[1, 2, 3, 4, 5]
        );
    }

    #[test]
    fn sim_engine_oom_fails_job_not_batch() {
        // A job over the tiny device's ceiling OOMs while its
        // batch-mates succeed (executing a >64M-key sort for real is
        // too slow for a unit test, so fabricate with a tiny device).
        let tiny = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20, // 1 MB
            ..GpuModel::Gtx260.spec()
        };
        let mut e_tiny =
            SimSortEngine::from_parts(tiny, BucketSortParams { tile: 256, s: 16 }).unwrap();
        let jobs = vec![kv_u32(vec![2, 1], None), kv_u32(vec![0; 200_000], None)];
        let results = e_tiny.sort_batch(jobs);
        assert!(results[0].is_ok());
        let err = results[1].as_ref().unwrap_err();
        assert!(err.is_oom(), "{err}");
    }

    #[test]
    fn key_value_jobs_hit_the_ceiling_sooner() {
        // The widened record (key + payload index) halves the capacity
        // headroom: a job that fits key-only OOMs as key–value on a
        // device sized in between.
        let tiny = GpuSpec {
            name: "tiny-3MB".into(),
            global_memory_bytes: 3 << 20,
            ..GpuModel::Gtx260.spec()
        };
        let mut e = SimSortEngine::from_parts(tiny, BucketSortParams { tile: 256, s: 16 })
            .unwrap();
        let n = 300_000;
        let keys: Vec<u32> = (0..n as u32).rev().collect();
        let results = e.sort_batch(vec![
            kv_u32(keys.clone(), None),
            kv_u32(keys, Some((0..n as u64).collect())),
        ]);
        assert!(results[0].is_ok(), "key-only fits");
        assert!(
            results[1].as_ref().unwrap_err().is_oom(),
            "key–value must OOM"
        );
    }

    #[test]
    fn verify_catches_corruption() {
        let input = kv_u32(vec![2, 1], None);
        assert!(verify_outcome(&input, &kv_u32(vec![1, 2], None), false).is_ok());
        assert!(verify_outcome(&input, &kv_u32(vec![2, 1], None), true).is_ok());
        assert!(verify_outcome(&input, &kv_u32(vec![1, 3], None), false).is_err());
        assert!(verify_outcome(&input, &kv_u32(vec![2, 1], None), false).is_err());
        // Direction matters.
        assert!(verify_outcome(&input, &kv_u32(vec![1, 2], None), true).is_err());
        // Key-type mismatch is corruption.
        assert!(
            verify_outcome(&input, &JobData::new(vec![1u64, 2]), false).is_err()
        );
        // Payload divorce is corruption even when the keys are right.
        let kv_in = kv_u32(vec![2, 1], Some(vec![20, 10]));
        assert!(verify_outcome(&kv_in, &kv_u32(vec![1, 2], Some(vec![10, 20])), false).is_ok());
        assert!(
            verify_outcome(&kv_in, &kv_u32(vec![1, 2], Some(vec![20, 10])), false).is_err()
        );
        // Dropping the payload is corruption too.
        assert!(verify_outcome(&kv_in, &kv_u32(vec![1, 2], None), false).is_err());
    }

    #[test]
    fn sharded_engine_sorts_and_advertises_pool_capacity() {
        let cfg = ServiceConfig {
            engine: EngineKind::Sharded,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let mut e = ShardedSortEngine::new(&cfg).unwrap();
        assert_eq!(e.kind(), EngineKind::Sharded);
        assert_eq!(e.models().len(), 4);
        // Pool capacity exceeds every single device's ceiling.
        assert!(e.max_job_keys().unwrap() > 512 << 20);
        let jobs = vec![
            kv_u32((0..50_000u32).rev().collect(), None),
            kv_u32(vec![], None),
            kv_u32(
                (0..10_000u32).map(|x| x.wrapping_mul(2654435761)).collect(),
                None,
            ),
        ];
        let results = e.sort_batch(jobs.clone());
        for (inp, res) in jobs.iter().zip(&results) {
            assert!(crate::is_sorted_permutation(
                inp.keys.as_u32().unwrap(),
                res.as_ref().unwrap().keys.as_u32().unwrap()
            ));
        }
        // Empty device lists are rejected up front.
        assert!(ShardedSortEngine::from_parts(vec![], ShardedSortParams::default()).is_err());
    }

    #[test]
    fn paced_sim_engine_sorts_and_respects_capacity() {
        // time_scale 0: no pacing sleep, pure correctness check.
        let mut e =
            PacedSimEngine::new(GpuModel::Gtx285_2G, BucketSortParams { tile: 256, s: 16 }, 0.0)
                .unwrap();
        assert_eq!(e.kind(), EngineKind::Sim);
        assert_eq!(
            e.max_job_keys(),
            Some(GpuModel::Gtx285_2G.spec().max_sortable_keys())
        );
        let jobs = vec![
            kv_u32((0..10_000u32).rev().collect(), None),
            kv_u32(vec![], None),
            kv_u32(vec![7, 7, 3, 3, 1], Some(vec![70, 71, 30, 31, 10])),
        ];
        let inputs = jobs.clone();
        let results = e.sort_batch(jobs);
        for (inp, res) in inputs.iter().zip(&results) {
            verify_outcome(inp, res.as_ref().unwrap(), false).unwrap();
        }
        // The key–value job is stable: equal keys keep payload order.
        assert_eq!(
            results[2].as_ref().unwrap().payload.as_deref(),
            Some(&[10u64, 30, 31, 70, 71][..])
        );
        // Over-ceiling jobs OOM exactly like the executing sim engine.
        let tiny = GpuSpec {
            name: "tiny".into(),
            global_memory_bytes: 1 << 20,
            ..GpuModel::Gtx260.spec()
        };
        let mut paced_tiny = PacedSimEngine {
            sim: GpuSim::new(tiny.clone()),
            spec: tiny,
            sorter: BucketSort::try_new(BucketSortParams { tile: 256, s: 16 }).unwrap(),
            time_scale: 0.0,
        };
        let results = paced_tiny.sort_batch(vec![
            kv_u32(vec![0; 300_000], None),
            kv_u32(vec![2, 1], None),
        ]);
        assert!(results[0].as_ref().unwrap_err().is_oom());
        assert_eq!(
            results[1].as_ref().unwrap().keys.as_u32().unwrap(),
            &[1, 2]
        );
        // Bad scales rejected.
        assert!(PacedSimEngine::new(GpuModel::Gtx260, BucketSortParams::default(), -1.0).is_err());
        assert!(
            PacedSimEngine::new(GpuModel::Gtx260, BucketSortParams::default(), f64::NAN).is_err()
        );
    }

    #[test]
    fn worker_engines_lease_disjoint_device_shares() {
        use crate::sim::DeviceRegistry;
        let cfg = ServiceConfig {
            engine: EngineKind::Sharded,
            workers: 2,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let registry = DeviceRegistry::new(cfg.devices.clone());
        let e0 = build_worker_engine(&cfg, 0, Some(&registry), None).unwrap();
        let e1 = build_worker_engine(&cfg, 1, Some(&registry), None).unwrap();
        assert_eq!(e0.kind(), EngineKind::Sharded);
        assert_eq!(e1.kind(), EngineKind::Sharded);
        // cfg.kernel must survive the lease path (regression: it used
        // to be dropped, leaving the worker engines on the default).
        let leased = ShardedSortEngine::with_lease(
            DeviceRegistry::new(cfg.devices.clone())
                .checkout(1)
                .unwrap(),
            ShardedSortParams::default(),
            crate::KernelKind::Bitonic,
            13,
            adaptive::CostModel::default(),
        )
        .unwrap();
        assert_eq!(leased.ctx.kernel, crate::KernelKind::Bitonic);
        assert_eq!(leased.ctx.digit_bits, 13);
        // 4 devices over 2 workers: both leases hold 2, none left over.
        assert_eq!(registry.available(), 0);
        // A third worker would oversubscribe and is refused.
        assert!(build_worker_engine(&cfg, 2, Some(&registry), None).is_err());
        // Dropping an engine returns its devices.
        drop(e0);
        assert_eq!(registry.available(), 2);
        drop(e1);
        assert_eq!(registry.available(), 4);
        // Without a registry the plain config path is used.
        let plain = build_worker_engine(&cfg, 0, None, None).unwrap();
        assert_eq!(plain.kind(), EngineKind::Sharded);
    }

    #[test]
    fn sharded_engine_survives_device_loss_and_reports_totals() {
        use crate::sim::{DeviceRegistry, FaultPlan};
        let cfg = ServiceConfig {
            engine: EngineKind::Sharded,
            workers: 1,
            sort: BucketSortParams { tile: 256, s: 16 },
            ..Default::default()
        };
        let plan = FaultPlan::parse(
            r#"{"version":1,"seed":7,"rules":[{"point":"device_lost","target":1,"count":1}]}"#,
        )
        .unwrap();
        let registry = DeviceRegistry::new(cfg.devices.clone());
        let mut e =
            build_worker_engine(&cfg, 0, Some(&registry), Some(plan.injector())).unwrap();
        let keys: Vec<u32> = (0..40_000u32).map(|x| x.wrapping_mul(2654435761)).collect();
        let results = e.sort_batch(vec![kv_u32(keys.clone(), None)]);
        let out = results[0].as_ref().unwrap();
        let mut want = keys;
        want.sort_unstable();
        assert_eq!(out.keys.as_u32().unwrap(), &want[..]);
        // One failover survived, one device lost — and the shared
        // registry learned about it.
        assert_eq!(
            e.fault_totals(),
            Some(FaultTotals {
                failovers: 1,
                devices_lost: 1,
            })
        );
        assert_eq!(registry.unhealthy_count(), 1);
        // Advertised capacity shrank to the healthy share.
        let full: usize = cfg
            .devices
            .iter()
            .map(|m| m.spec().max_sortable_keys())
            .sum();
        assert!(e.max_job_keys().unwrap() < full);
        // Follow-up jobs keep working on the degraded pool without
        // re-paying a failover (the rule is exhausted, the mark sticks).
        let results = e.sort_batch(vec![kv_u32(vec![3, 1, 2], None)]);
        assert_eq!(results[0].as_ref().unwrap().keys.as_u32().unwrap(), &[1, 2, 3]);
        assert_eq!(e.fault_totals().unwrap().failovers, 1);
        // Engines without fault instrumentation keep the default-None
        // surface.
        let native = NativeSortEngine::new(&ServiceConfig::default()).unwrap();
        assert!(native.fault_totals().is_none());
    }

    #[test]
    fn pace_helper_fires_only_for_targeted_worker() {
        use crate::sim::FaultPlan;
        // No injector: free no-op.
        pace_for_injected_slowdown(None, 0);
        let plan = FaultPlan::parse(
            r#"{"version":1,"seed":1,"rules":[{"point":"slow_device","target":0,"delay_ms":1}]}"#,
        )
        .unwrap();
        let inj = plan.injector();
        pace_for_injected_slowdown(Some(&inj), 1); // wrong worker: no stall
        assert_eq!(inj.injected().get("slow_device"), None);
        pace_for_injected_slowdown(Some(&inj), 0); // 1 ms stall, rule fires
        assert_eq!(inj.injected().get("slow_device"), Some(&1));
    }

    #[test]
    fn build_engine_dispatches() {
        let native = build_engine(&ServiceConfig::default()).unwrap();
        assert_eq!(native.kind(), EngineKind::Native);
        let sim = build_engine(&ServiceConfig {
            engine: EngineKind::Sim,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sim.kind(), EngineKind::Sim);
        let sharded = build_engine(&ServiceConfig {
            engine: EngineKind::Sharded,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(sharded.kind(), EngineKind::Sharded);
        // PJRT without artifacts → manifest error.
        let pjrt = build_engine(&ServiceConfig {
            engine: EngineKind::Pjrt,
            artifacts_dir: "/nonexistent".into(),
            ..Default::default()
        });
        assert!(pjrt.is_err());
    }
}
